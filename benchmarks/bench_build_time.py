"""Fig. 10 — CDMT index construction time vs content hashing time.

Paper: indexing (Alg. 1) is a small fraction of chunk hashing (boundary
scan + blake2b).  Also reports the Pallas-kernel-accelerated boundary scan
(interpret mode on CPU; compiled on TPU) for the DESIGN §4 adaptation.
"""

from __future__ import annotations

import time

from repro.core import cdc, hashing
from repro.core.cdmt import CDMT, CDMTParams

from benchmarks.common import Report, Timer
from benchmarks.corpus import corpus

CDC_PARAMS = cdc.CDCParams(mask_bits=11, min_size=256, max_size=16384)
CDMT_PARAMS = CDMTParams(window=8, rule_bits=2)


def run() -> Report:
    rep = Report("fig10_hash_vs_index_time")
    for app, versions in list(corpus().items()):
        hash_s = 0.0
        index_s = 0.0
        n_chunks = 0
        for v in versions:
            fps = []
            with Timer() as t:
                for layer in v.layers:
                    for c in cdc.chunk_bytes(layer, CDC_PARAMS):
                        fps.append(hashing.chunk_fingerprint(c))
            hash_s += t.s
            with Timer() as t:
                CDMT.build(fps, CDMT_PARAMS)
            index_s += t.s
            n_chunks += len(fps)
        rep.add(app=app, n_chunks=n_chunks, hash_s=hash_s, index_s=index_s,
                index_over_hash=index_s / hash_s if hash_s else 0.0)
    mean = sum(r["index_over_hash"] for r in rep.rows) / len(rep.rows)
    rep.add(app="_mean", n_chunks=0, hash_s=0.0, index_s=0.0,
            index_over_hash=mean)
    return rep


if __name__ == "__main__":
    run().print_csv()
