"""Synthetic container-image corpus reproducing the paper's Table I
version-evolution statistics (Docker Hub is unreachable offline).

15 applications × 8–19 versions; each image is a list of layers (byte
blobs).  Version evolution mimics real image churn:

  * PATCH versions edit a few spots in a few layers (config bumps,
    recompiled binaries) and occasionally insert/delete bytes — the
    insertions/deletions produce the *chunk-shift* events the paper studies;
  * MINOR versions additionally add/replace a whole layer (dependency
    upgrade);
  * content is zipf-distributed symbol text over per-app dictionaries, so
    gzip achieves realistic 2–3.5× (random bytes would be incompressible
    and kill the compression baseline the paper compares against).

Sizes are scaled down ~1000× from the paper (GBs → MBs) so the full
benchmark suite runs in minutes on one CPU; every *ratio* the paper reports
is scale-free.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import numpy as np

# name, n_versions, n_layers, total_scaled_KB  (Table I, scaled)
APPS: List[Tuple[str, int, int, int]] = [
    ("golang", 8, 5, 2500),
    ("node", 17, 3, 1300),
    ("tomcat", 17, 6, 3200),
    ("httpd", 17, 5, 2000),
    ("python", 18, 5, 1700),
    ("tensorflow", 10, 12, 8000),
    ("r-base", 9, 8, 6000),
    ("redis", 13, 6, 830),
    ("rails", 18, 9, 6000),
    ("nginx", 19, 3, 1100),
    ("postgres", 19, 9, 1100),
    ("django", 8, 8, 4200),
    ("pytorch", 10, 8, 9000),
    ("mysql", 16, 9, 7400),
    ("deepmind", 19, 9, 10000),
]

# per-app version-churn profile: (edits per patch, p_minor, churn_scale)
# high-similarity apps (deepmind, r-base, rails: dedup ratios .92–.95 in
# Table II) get tiny churn; low-similarity (golang: 0.34) get heavy churn.
CHURN: Dict[str, Tuple[int, float, float]] = {
    "golang": (12, 0.5, 0.30), "node": (6, 0.3, 0.08),
    "tomcat": (5, 0.25, 0.06), "httpd": (6, 0.3, 0.09),
    "python": (8, 0.35, 0.15), "tensorflow": (8, 0.3, 0.12),
    "r-base": (3, 0.1, 0.015), "redis": (5, 0.3, 0.08),
    "rails": (3, 0.15, 0.02), "nginx": (5, 0.25, 0.06),
    "postgres": (6, 0.3, 0.09), "django": (4, 0.2, 0.04),
    "pytorch": (5, 0.2, 0.05), "mysql": (6, 0.25, 0.06),
    "deepmind": (2, 0.1, 0.012),
}


def _text_block(rng: np.random.Generator, n: int, dictionary: np.ndarray
                ) -> bytes:
    """Container-layer-like bytes: zipf-weighted dictionary words (text,
    scripts, ELF symbol tables) interleaved with ~20% incompressible spans
    (compiled code, compressed assets) — calibrated so gzip lands in the
    paper's 2–3.5× range."""
    words = dictionary[rng.zipf(1.35, size=max(8, n // 12)) % len(dictionary)]
    blob = bytearray(b" ".join(w.tobytes() for w in words)[:n])
    if n >= 256:
        bin_frac = rng.uniform(0.12, 0.32)
        n_spans = max(1, int(n * bin_frac / 512))
        for _ in range(n_spans):
            pos = int(rng.integers(0, max(1, n - 512)))
            blob[pos:pos + 512] = rng.bytes(min(512, n - pos))
    return bytes(blob[:n])


@functools.lru_cache(maxsize=None)
def _dictionary(seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(97, 123, size=(512, 11), dtype=np.uint8)  # a-z words


@dataclasses.dataclass
class ImageVersion:
    app: str
    tag: str
    layers: List[bytes]

    @property
    def size(self) -> int:
        return sum(len(l) for l in self.layers)

    def tar(self) -> bytes:
        """The flattened byte stream (stand-in for the uncompressed tar)."""
        return b"".join(self.layers)


def generate_app(app: str, n_versions: int, n_layers: int, total_kb: int,
                 seed: int) -> List[ImageVersion]:
    rng = np.random.default_rng(seed)
    dictionary = _dictionary(seed % 7)
    edits, p_minor, churn = CHURN[app]
    layer_sizes = rng.dirichlet(np.ones(n_layers) * 2.0) * total_kb * 1024
    layers = [bytearray(_text_block(rng, max(2048, int(s)), dictionary))
              for s in layer_sizes]
    versions = [ImageVersion(app, "v0", [bytes(l) for l in layers])]

    for v in range(1, n_versions):
        minor = rng.random() < p_minor
        n_edit_layers = max(1, int(len(layers) * (0.5 if minor else 0.25)))
        for li in rng.choice(len(layers), size=n_edit_layers, replace=False):
            layer = layers[li]
            n_edits = max(1, int(edits * (2 if minor else 1)))
            for _ in range(n_edits):
                kind = rng.random()
                pos = int(rng.integers(0, max(1, len(layer) - 64)))
                size = int(rng.integers(16, max(32, int(len(layer) * churn / edits))))
                patch = _text_block(rng, size, dictionary)
                if kind < 0.6:                     # in-place modify
                    layer[pos:pos + size] = patch[:min(size, len(layer) - pos)]
                elif kind < 0.85:                  # insert (chunk shift!)
                    layer[pos:pos] = patch
                else:                              # delete (chunk shift!)
                    del layer[pos:pos + size]
        if minor and rng.random() < 0.7:           # add/replace a layer
            size = int(np.mean([len(l) for l in layers]) * rng.uniform(0.3, 1.0))
            newl = bytearray(_text_block(rng, size, dictionary))
            if rng.random() < 0.5 and len(layers) > 2:
                layers[int(rng.integers(0, len(layers)))] = newl
            else:
                layers.append(newl)
        versions.append(ImageVersion(app, f"v{v}", [bytes(l) for l in layers]))
    return versions


@functools.lru_cache(maxsize=1)
def corpus(scale: float = 1.0) -> Dict[str, List[ImageVersion]]:
    """The full 15-app corpus (cached).  ``scale`` shrinks sizes further."""
    out = {}
    for i, (app, n_versions, n_layers, kb) in enumerate(APPS):
        out[app] = generate_app(app, n_versions, n_layers,
                                max(64, int(kb * scale)), seed=1000 + i)
    return out


def corpus_stats() -> Dict[str, Dict]:
    c = corpus()
    return {
        app: {
            "versions": len(vs),
            "layers": np.mean([len(v.layers) for v in vs]),
            "total_mb": sum(v.size for v in vs) / 2**20,
        } for app, vs in c.items()
    }
