"""Ablation — CDMT window size and boundary rule (paper Sec. IV: "The
efficiency of the CDMT index depends upon an appropriately chosen window
size"; the paper lands on window 8).

Sweeps (window, rule_bits) over a corpus subsample and reports:
  * common-node detection between consecutive versions (robustness),
  * comparisons per changed chunk (Alg. 2 efficiency),
  * index size overhead and tree height.

Expected shape: tiny windows churn parent boundaries (hash window covers
few children ⇒ a changed child redraws its parent's cut more often); huge
windows converge toward position-sensitivity (every parent hash sees every
child, the plain-Merkle failure).  The paper's 8 sits on the plateau.
"""

from __future__ import annotations

from repro.core import cdc, hashing
from repro.core.cdmt import CDMT, CDMTParams, common_node_ratio, compare

from benchmarks.common import Report
from benchmarks.corpus import corpus

CDC_PARAMS = cdc.CDCParams(mask_bits=11, min_size=256, max_size=16384)
APPS = ("python", "nginx", "deepmind", "golang")      # churn spectrum


def _leaf_fps(version):
    fps = []
    for layer in version.layers:
        fps.extend(hashing.chunk_fingerprint(c)
                   for c in cdc.chunk_bytes(layer, CDC_PARAMS))
    return fps


def run() -> Report:
    rep = Report("cdmt_ablation_window_rule")
    series = {app: [_leaf_fps(v) for v in corpus()[app]] for app in APPS}
    for window in (2, 4, 8, 16, 32):
        for rule_bits in (1, 2, 3):
            params = CDMTParams(window=window, rule_bits=rule_bits)
            ratios, comps_per_change, sizes, heights = [], [], [], []
            for app, fps_list in series.items():
                prev = None
                for fps in fps_list:
                    t = CDMT.build(fps, params)
                    sizes.append(t.index_size_bytes() / max(1, len(fps)))
                    heights.append(t.height())
                    if prev is not None:
                        ratios.append(common_node_ratio(prev, t))
                        missing, comps = compare(prev, t)
                        comps_per_change.append(
                            comps / max(1, len(missing)))
                    prev = t
            rep.add(window=window, rule_bits=rule_bits,
                    common_nodes=sum(ratios) / len(ratios),
                    comparisons_per_changed_chunk=(
                        sum(comps_per_change) / len(comps_per_change)),
                    index_bytes_per_chunk=sum(sizes) / len(sizes),
                    mean_height=sum(heights) / len(heights))
    return rep


if __name__ == "__main__":
    run().print_csv()
