"""Static-analysis gate cost: what the `analyze` CI job pays per run.

The gate is on the critical path of every PR, so its runtime is a budget
we track like any other: per-analyzer wall time over the real source
trees (guarded-by lint, lock-order analyzer, wire-drift checker,
layer-import analyzer, err-contract analyzer, durability lint), with the
work each one did (files, fields, accesses, locks, edges, codec
round-trips, sizing identities, import edges, api boundaries, rename
sites) and — the invariant — zero violations.

Emits ``BENCH_analysis.json`` for CI diffing.
"""

from __future__ import annotations

import glob
import os

from repro.analysis import (durability, errcontract, guarded, layers,
                            lockorder, wiredrift)

from benchmarks.common import Report, Timer, write_json

REPS = 5
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
WIRE_DOC = os.path.join(ROOT, "docs", "WIRE_PROTOCOL.md")
ARCH_DOC = os.path.join(ROOT, "docs", "ARCHITECTURE.md")


def _scan_paths() -> list:
    out = []
    for sub in ("core", "delivery", "obs"):
        out.extend(sorted(glob.glob(
            os.path.join(ROOT, "src", "repro", sub, "*.py"))))
    return out


def _best(fn):
    best, result = None, None
    for _ in range(REPS):
        with Timer() as t:
            result = fn()
        best = t.s if best is None else min(best, t.s)
    return best * 1e3, result


def run() -> Report:
    rep = Report("analysis")
    paths = _scan_paths()

    ms, (g_findings, g_stats) = _best(lambda: guarded.check_files(paths))
    rep.add(analyzer="guarded_by", ms=ms, files=g_stats["files"],
            classes=g_stats["classes"],
            guarded_fields=g_stats["guarded_fields"],
            external_fields=g_stats["external_fields"],
            accesses_checked=g_stats["accesses_checked"],
            violations=len(g_findings))

    ms, lo = _best(lambda: lockorder.analyze_files(paths))
    rep.add(analyzer="lock_order", ms=ms, files=len(paths),
            classes=lo.stats["classes"],
            locks=len(lo.nodes), edges=len(lo.edges),
            violations=len(lo.findings))

    ms, (w_findings, w_stats) = _best(lambda: wiredrift.check_all(WIRE_DOC))
    rep.add(analyzer="wire_drift", ms=ms,
            doc_rows=w_stats["doc_rows"],
            enum_members=w_stats["enum_members"],
            round_trips=w_stats["round_trips"],
            sizing_checks=w_stats["sizing_checks"],
            violations=len(w_findings))

    ms, ly = _best(lambda: layers.analyze_paths(paths, doc=ARCH_DOC))
    rep.add(analyzer="layers", ms=ms, files=ly.stats["files"],
            modules=ly.stats["modules"], edges=ly.stats["edges"],
            lazy_edges=ly.stats["lazy_edges"],
            upward_edges=ly.stats["upward_edges"],
            exceptions=ly.stats["exceptions"],
            violations=len(ly.findings))

    ms, (e_findings, e_stats) = _best(
        lambda: errcontract.analyze_files(paths))
    rep.add(analyzer="err_contract", ms=ms, files=e_stats["files"],
            boundaries=e_stats["boundaries"],
            raise_sites=e_stats["raise_sites"],
            calls_resolved=e_stats["calls_resolved"],
            pragmas=e_stats["pragmas"],
            violations=len(e_findings))

    ms, (d_findings, d_stats) = _best(lambda: durability.check_files(paths))
    rep.add(analyzer="durability", ms=ms, files=d_stats["files"],
            replace_sites=d_stats["replace_sites"],
            commit_paths=d_stats["commit_paths"],
            journaled_paths=d_stats["journaled_paths"],
            pragmas=d_stats["pragmas"],
            violations=len(d_findings))

    write_json("BENCH_analysis.json", [rep])
    return rep
