"""Benchmark driver: one benchmark per paper table/figure + framework-native
workloads.  ``PYTHONPATH=src python -m benchmarks.run [names...]``"""

from __future__ import annotations

import sys
import time

from benchmarks import (bench_analysis, bench_build_time,
                        bench_cdmt_ablation, bench_cdmt_vs_merkle,
                        bench_checkpoint_delivery, bench_comparison_ratio,
                        bench_dedup_ratio, bench_delivery_scale,
                        bench_global_dedup, bench_kernels,
                        bench_push_incremental, bench_pushpull_io,
                        roofline)

ALL = {
    "fig6_dedup_ratio": bench_dedup_ratio.run,
    "fig7_global_dedup": bench_global_dedup.run,
    "fig8_cdmt_vs_merkle": bench_cdmt_vs_merkle.run,
    "fig9_comparison_ratio": bench_comparison_ratio.run,
    "fig10_build_time": bench_build_time.run,
    "table2_pushpull_io": bench_pushpull_io.run,
    "delivery_scale": bench_delivery_scale.run,
    "delivery_unified": bench_delivery_scale.run_unified,
    "delivery_socket": bench_delivery_scale.run_socket,
    "delivery_replicated": bench_delivery_scale.run_replicated,
    "delivery_bootstrap": bench_delivery_scale.run_bootstrap,
    "delivery_obs": bench_delivery_scale.run_obs,
    "delivery_async": bench_delivery_scale.run_async,
    "delivery_async_smoke": bench_delivery_scale.run_async_smoke,
    "cdmt_ablation": bench_cdmt_ablation.run,
    "checkpoint_delivery": bench_checkpoint_delivery.run,
    "push_incremental": bench_push_incremental.run,
    "kernels": bench_kernels.run,
    "roofline": roofline.run,
    "analysis": bench_analysis.run,
}


def main() -> None:
    names = sys.argv[1:] or list(ALL)
    t00 = time.time()
    for name in names:
        t0 = time.time()
        rep = ALL[name]()
        rep.print_csv()
        print(f"# {name} took {time.time() - t0:.1f}s\n")
    print(f"# total {time.time() - t00:.1f}s")


if __name__ == "__main__":
    main()
