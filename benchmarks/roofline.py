"""§Roofline aggregation: reads the dry-run JSON records and emits the
per-(arch × shape × mesh) three-term roofline table for EXPERIMENTS.md."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Report

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load(tag: str = "baseline"):
    recs = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        try:
            r = json.load(open(f))
        except (json.JSONDecodeError, OSError):   # mid-write / partial file
            continue
        if r.get("tag", "baseline") == tag:
            recs.append(r)
    return recs


def run(tag: str = "baseline") -> Report:
    rep = Report(f"roofline[{tag}]")
    for r in load(tag):
        if r["status"] != "ok":
            rep.add(arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                    status="FAIL", compute_ms=0, memory_ms=0, coll_ms=0,
                    dominant="-", hbm_gib=0, mfu_bound=0, useful_ratio=0)
            continue
        ro = r["roofline"]
        rep.add(arch=r["arch"], shape=r["shape"], mesh=r["mesh"], status="ok",
                compute_ms=ro["compute_s"] * 1e3,
                memory_ms=ro["memory_s"] * 1e3,
                coll_ms=ro["collective_s"] * 1e3,
                dominant=ro["dominant"],
                hbm_gib=r["memory"]["peak_bytes"] / 2**30,
                mfu_bound=ro["mfu_bound"],
                useful_ratio=ro["useful_flops_ratio"])
    return rep


if __name__ == "__main__":
    import sys
    run(sys.argv[1] if len(sys.argv) > 1 else "baseline").print_csv()
