"""Framework-native workload: CDMT-dedup checkpoint delivery.

Trains a reduced LM for a few steps, checkpointing every k steps through
the CDMT push path, then forks a fine-tune branch — measuring the wire
bytes the paper's technique saves on REAL training-state byte streams
(optimizer state + params), plus the elastic-join cost for a fresh host.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.checkpoint import CheckpointConfig, DedupCheckpointManager
from repro.core import cdc
from repro.core.registry import Registry
from repro.data import DataConfig
from repro.models.api import build_model
from repro.optim import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.train_step import TrainConfig

from benchmarks.common import Report

CDC_PARAMS = cdc.CDCParams(mask_bits=11, min_size=256, max_size=16384)


def run() -> Report:
    rep = Report("checkpoint_delivery")
    model = build_model("olmo-1b", reduced=True)
    data = DataConfig(vocab=model.cfg.vocab, seq_len=64, global_batch=4,
                      n_hosts=1, seed=0)
    reg = Registry()
    cfg = TrainerConfig(
        total_steps=20,
        ckpt=CheckpointConfig(lineage="main", n_groups=2, every_steps=4,
                              cdc_params=CDC_PARAMS),
        train=TrainConfig(n_micro=1, adamw=AdamWConfig(lr=1e-3),
                          warmup_steps=5, total_steps=20))
    tr = Trainer(model, data, cfg, registry=reg)
    tr.run()

    for info in tr.ckpt.history:
        rep.add(event=f"save@{info.step}", raw_mb=info.raw_bytes / 2**20,
                wire_mb=info.total_wire_bytes / 2**20,
                savings=info.savings_vs_raw)
    s = tr.ckpt.wire_summary()
    rep.add(event="_run_total", raw_mb=s["raw_bytes"] / 2**20,
            wire_mb=s["wire_bytes"] / 2**20, savings=s["savings"])

    # elastic join (fresh host) and warm-disk restart
    fork_cfg = CheckpointConfig(lineage="main", n_groups=2,
                                cdc_params=CDC_PARAMS)
    joiner = DedupCheckpointManager(reg, fork_cfg)
    joiner.manifests = dict(tr.ckpt.manifests)
    abstract = tr.init_or_restore()
    _, _, wire_first = joiner.restore(abstract)
    _, _, wire_again = joiner.restore(abstract)
    rep.add(event="elastic_join_first",
            raw_mb=sum(w.raw_bytes for w in wire_first) / 2**20,
            wire_mb=sum(w.total_wire_bytes for w in wire_first) / 2**20,
            savings=1 - sum(w.total_wire_bytes for w in wire_first)
            / max(1, sum(w.raw_bytes for w in wire_first)))
    rep.add(event="restart_warm_disk",
            raw_mb=sum(w.raw_bytes for w in wire_again) / 2**20,
            wire_mb=sum(w.total_wire_bytes for w in wire_again) / 2**20,
            savings=1 - sum(w.total_wire_bytes for w in wire_again)
            / max(1, sum(w.raw_bytes for w in wire_again)))

    # fine-tune fork: freeze everything but the head — the dominant
    # checkpoint-delivery case in a serving fleet (examples/serve_weights)

    state = jax.tree.map(np.asarray, tr.init_or_restore()._asdict())
    fork = DedupCheckpointManager(reg, CheckpointConfig(
        lineage="fork", n_groups=2, cdc_params=CDC_PARAMS))
    fork.save(state, step=0)
    state["params"]["lm_head"] = state["params"]["lm_head"] + 1e-3
    info = fork.save(state, step=1)
    rep.add(event="finetune_fork_head_only", raw_mb=info.raw_bytes / 2**20,
            wire_mb=info.total_wire_bytes / 2**20, savings=info.savings_vs_raw)

    # dense-update step save: flat vs byte-plane layout (honest: AdamW
    # perturbs nearly every float; byte-plane recovers only the stable
    # high-byte planes — single-digit % for f32 1e-3-relative updates)
    rng = np.random.default_rng(0)
    w1 = {"w": rng.standard_normal(2_000_00).astype(np.float32)}
    w2 = {"w": (w1["w"] * (1 + 1e-3 * rng.standard_normal(2_000_00))
                ).astype(np.float32)}
    for bp in (False, True):
        mgr2 = DedupCheckpointManager(Registry(), CheckpointConfig(
            lineage="bp", n_groups=1, byte_plane=bp, cdc_params=CDC_PARAMS))
        mgr2.save(w1, step=0)
        info = mgr2.save(w2, step=1)
        rep.add(event=f"dense_step_byte_plane={bp}",
                raw_mb=info.raw_bytes / 2**20,
                wire_mb=info.total_wire_bytes / 2**20,
                savings=info.savings_vs_raw)
    return rep


if __name__ == "__main__":
    run().print_csv()
