"""Fig. 8 — common-chunk DETECTION between consecutive image versions:
CDMT (Algorithm 2) vs plain Merkle tree comparison.

Three detectors over identical version pairs:
  cdmt              — Alg. 2 BFS: content-addressed nodes, prune-on-match;
  merkle_positional — the paper's Merkle semantics: authentication-path
                      (positional) comparison; a chunk shift misaligns all
                      positions right of the edit ⇒ detection collapses;
  merkle_id         — a *generous* Merkle baseline (node-id set
                      intersection) included for fairness.

Paper: CDMT detects far more common chunks; Merkle is low except for apps
whose churn rarely inserts/deletes bytes (no chunk shifts).
"""

from __future__ import annotations

from repro.core import cdc, hashing, merkle
from repro.core.cdmt import CDMT, CDMTParams, compare

from benchmarks.common import Report
from benchmarks.corpus import corpus

CDC_PARAMS = cdc.CDCParams(mask_bits=11, min_size=256, max_size=16384)
CDMT_PARAMS = CDMTParams(window=8, rule_bits=2)


def _leaf_fps(version) -> list:
    fps = []
    for layer in version.layers:
        fps.extend(hashing.chunk_fingerprint(c)
                   for c in cdc.chunk_bytes(layer, CDC_PARAMS))
    return fps


def run() -> Report:
    rep = Report("fig8_cdmt_vs_merkle_detection")
    agg = {"cdmt": [], "pos": [], "id": []}
    for app, versions in corpus().items():
        r_cdmt, r_pos, r_id = [], [], []
        prev = None
        for v in versions:
            fps = _leaf_fps(v)
            cur = (fps, CDMT.build(fps, CDMT_PARAMS),
                   merkle.MerkleTree.build(fps, k=4))
            if prev is not None:
                pf, pc, pm = prev
                fps_set = set(fps)
                truly_shared = len(set(pf) & fps_set) / max(1, len(fps_set))
                missing, _ = compare(pc, cur[1])
                det_cdmt = 1.0 - len(missing) / max(1, len(fps_set))
                shared_pos, _ = merkle.positional_compare(pm, cur[2])
                det_pos = len(shared_pos) / max(1, len(fps_set))
                shared_id, _ = merkle.compare_trees(pm, cur[2])
                det_id = len(shared_id) / max(1, len(fps_set))
                # normalize by what is actually shared (detection recall)
                if truly_shared > 0:
                    r_cdmt.append(det_cdmt / truly_shared)
                    r_pos.append(det_pos / truly_shared)
                    r_id.append(det_id / truly_shared)
            prev = cur
        mc = sum(r_cdmt) / len(r_cdmt)
        mp = sum(r_pos) / len(r_pos)
        mi = sum(r_id) / len(r_id)
        agg["cdmt"].append(mc); agg["pos"].append(mp); agg["id"].append(mi)
        rep.add(app=app, cdmt_detect=mc, merkle_positional=mp, merkle_id=mi)
    rep.add(app="_mean",
            cdmt_detect=sum(agg["cdmt"]) / len(agg["cdmt"]),
            merkle_positional=sum(agg["pos"]) / len(agg["pos"]),
            merkle_id=sum(agg["id"]) / len(agg["id"]))
    return rep


if __name__ == "__main__":
    run().print_csv()
