"""Fig. 6 — per-application dedup ratio (CDC block dedup) vs gzip.

Paper claims: compression tops out ≈3.5×; dedup reaches ≈8–20× for
high-version-similarity apps; dedup beats gzip for more than half the apps.
"""

from __future__ import annotations

import zlib

from repro.core import cdc
from repro.core.store import DedupStore

from benchmarks.common import Report
from benchmarks.corpus import corpus

CDC_PARAMS = cdc.CDCParams(mask_bits=11, min_size=256, max_size=16384)


def run() -> Report:
    rep = Report("fig6_dedup_vs_gzip")
    better = 0
    for app, versions in corpus().items():
        raw = 0
        gz = 0
        store = DedupStore(cdc_params=CDC_PARAMS)
        for v in versions:
            raw += v.size
            gz += sum(len(zlib.compress(l, 6)) for l in v.layers)
            for li, layer in enumerate(v.layers):
                store.ingest(f"{v.tag}/L{li}", layer)
        dedup_ratio = raw / store.chunks.stored_bytes()
        gzip_ratio = raw / gz
        better += dedup_ratio > gzip_ratio
        rep.add(app=app, raw_mb=raw / 2**20, dedup_ratio=dedup_ratio,
                gzip_ratio=gzip_ratio)
    rep.add(app="_summary", raw_mb=0.0,
            dedup_ratio=max(r["dedup_ratio"] for r in rep.rows),
            gzip_ratio=better / len(corpus()))  # fraction where dedup wins
    return rep


if __name__ == "__main__":
    run().print_csv()
