"""Delivery-stack scale benchmark: N concurrent clients upgrading through a
``RegistryServer``, registry-only vs swarm mode.

Each client is warm (provisioned with an older version of the app) and pulls
the latest version during a **rolling upgrade**: clients arrive in waves of
``n/4`` (concurrent within a wave, waves in order), the way fleets actually
roll.  In swarm mode every completed puller registers as a provider, so wave
1 drains the registry once and later waves fetch chunk payloads from peers —
the registry keeps serving only the KB-sized index/recipe (EdgePier's
offload).  Registry-only mode runs the identical schedule without peers.
Reported per (app × mode × N):

  * ``registry_egress_mb`` — actual serialized frame bytes leaving the
    registry (the number a capacity planner cares about);
  * ``naive_egress_mb``    — what N full-artifact transfers would cost;
  * ``cache_hit_rate``     — tiered-cache hits over the wave;
  * ``coalesced``          — chunk reads that piggy-backed on an identical
    in-flight read;
  * ``peer_offload``       — fraction of chunk bytes served by peers
    (swarm mode; 0 for registry-only);
  * ``wall_s``             — wave wall-clock.

Unified rows additionally report per-pull latency (``pull_p50_ms`` /
``pull_p99_ms``) read from the clients' ``client_pull_seconds`` histograms,
and ``run_obs`` measures the observability layer itself: the same socket
rollout with metrics + tracing enabled vs disabled (median-latency overhead
must stay small), plus a live ``Op.METRICS`` scrape sanity check.
``run_async`` scales the fleet to 1000 concurrent pullers against one
event-loop ``AsyncRegistryServer`` over shared multiplexed transports,
reporting exact per-pull p50/p99 and the server's (fixed) thread count.
The ``__main__`` entry also emits machine-readable ``BENCH_delivery.json``.

Run:  PYTHONPATH=src python -m benchmarks.bench_delivery_scale [scale]
      PYTHONPATH=src python -m benchmarks.run delivery_scale
"""

from __future__ import annotations

import statistics
import sys
import threading
import time
from typing import List, Optional

from repro.core import cdc
from repro.core.cdmt import CDMTParams
from repro.core.pushpull import Client
from repro.core.registry import Registry
from repro.delivery import (AsyncRegistryServer, DeltaSession, ImageClient,
                            JournalFollower, LocalTransport,
                            MuxSocketTransport, RegistryServer,
                            ReplicatedTransport, SocketRegistryServer,
                            SocketTransport, SwarmNode, SwarmTracker,
                            SwarmTransport, WireTransport, swarm_pull)
from repro.obs import (HistogramView, MetricsRegistry, Tracer,
                       parse_prometheus_text, to_prometheus_text)

from benchmarks.common import Report, Timer, write_json
from benchmarks.corpus import corpus

CDC_PARAMS = cdc.CDCParams(mask_bits=11, min_size=256, max_size=16384)
CDMT_PARAMS = CDMTParams(window=8, rule_bits=2)

APPS = ["node", "redis", "nginx"]       # small/medium apps: waves stay quick
N_CLIENTS = [2, 8, 16]


def _loaded_server(app: str, versions,
                   metrics: Optional[MetricsRegistry] = None
                   ) -> RegistryServer:
    reg = Registry(cdmt_params=CDMT_PARAMS, metrics=metrics)
    pub = Client(cdc_params=CDC_PARAMS, cdmt_params=CDMT_PARAMS)
    for v in versions:
        pub.commit(app, v.tag, v.tar())
        pub.push(reg, app, v.tag)
    return RegistryServer(reg)


def _hist_delta(before: Optional[HistogramView],
                after: Optional[HistogramView]) -> Optional[HistogramView]:
    """What ``after`` observed that ``before`` had not (bucket-wise)."""
    if after is None:
        return None
    if before is None:
        return after
    return HistogramView(after.edges,
                         [a - b for a, b in zip(after.counts, before.counts)],
                         after.sum - before.sum, after.count - before.count)


def _pull_latency(clients: List[ImageClient], base_snaps,
                  kind: str) -> Optional[HistogramView]:
    """Merged ``client_pull_seconds`` across all clients, provision pulls
    (observed before ``base_snaps`` were taken) subtracted out."""
    merged: Optional[HistogramView] = None
    for cl, base in zip(clients, base_snaps):
        delta = _hist_delta(
            base.histogram("client_pull_seconds", {"transport": kind}),
            cl.metrics.snapshot().histogram("client_pull_seconds",
                                            {"transport": kind}))
        if delta is None:
            continue
        merged = delta if merged is None else merged.merge(delta)
    return merged


def _rolling_waves(n: int, worker, wave_size: int = 0,
                   after_wave=None) -> float:
    """Run ``worker(i)`` for i in 0..n-1 as a rolling upgrade: waves of
    ``wave_size`` clients run concurrently (barrier-released), waves proceed
    in order.  Default wave size: n/4, ≥1.  ``after_wave(wave_index)`` runs
    between waves (fault injection: e.g. kill the primary registry)."""
    wave_size = wave_size or max(1, n // 4)
    errors: List[BaseException] = []

    def run(i, barrier):
        try:
            barrier.wait()
            worker(i)
        except BaseException as e:  # pragma: no cover
            errors.append(e)

    with Timer() as t:
        for wave, start in enumerate(range(0, n, wave_size)):
            members = range(start, min(start + wave_size, n))
            barrier = threading.Barrier(len(members))
            threads = [threading.Thread(target=run, args=(i, barrier))
                       for i in members]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            if errors:
                raise errors[0]
            if after_wave is not None:
                after_wave(wave)
    return t.s


def _registry_only(app: str, versions, n: int, warm_tag: str, new_tag: str):
    srv = _loaded_server(app, versions)
    sessions = []
    for _ in range(n):
        cl = Client(cdc_params=CDC_PARAMS, cdmt_params=CDMT_PARAMS)
        sess = DeltaSession(cl, srv, batch_chunks=64, pipeline_depth=4)
        sess.pull(app, warm_tag)              # provision (not measured)
        sessions.append(sess)
    base = srv.snapshot()
    base_cache = srv.cache.stats

    wall = _rolling_waves(n, lambda i: sessions[i].pull(app, new_tag))

    s = srv.snapshot()
    cache = srv.cache.stats
    hits = cache.hits - base_cache.hits
    misses = cache.misses - base_cache.misses
    return {
        "registry_egress_mb": (s.egress_bytes - base.egress_bytes) / 2**20,
        "cache_hit_rate": hits / (hits + misses) if hits + misses else 0.0,
        "coalesced": s.coalesced_reads - base.coalesced_reads,
        "peer_offload": 0.0,
        "wall_s": wall,
    }


def _swarm(app: str, versions, n: int, warm_tag: str, new_tag: str):
    srv = _loaded_server(app, versions)
    tracker = SwarmTracker()
    nodes = []
    for i in range(n):
        node = SwarmNode(f"n{i}", cdc_params=CDC_PARAMS,
                         cdmt_params=CDMT_PARAMS)
        swarm_pull(node, srv, tracker, app, warm_tag)   # provision + register
        nodes.append(node)
    base = srv.snapshot()
    base_cache = srv.cache.stats
    stats_out: List = [None] * n

    def worker(i):
        stats_out[i] = swarm_pull(nodes[i], srv, tracker, app, new_tag,
                                  max_peers=4)

    wall = _rolling_waves(n, worker)

    s = srv.snapshot()
    cache = srv.cache.stats
    hits = cache.hits - base_cache.hits
    misses = cache.misses - base_cache.misses
    peer_b = sum(st.peer_chunk_bytes for st in stats_out)
    reg_b = sum(st.registry_chunk_bytes for st in stats_out)
    return {
        "registry_egress_mb": (s.egress_bytes - base.egress_bytes) / 2**20,
        "cache_hit_rate": hits / (hits + misses) if hits + misses else 0.0,
        "coalesced": s.coalesced_reads - base.coalesced_reads,
        "peer_offload": peer_b / (peer_b + reg_b) if peer_b + reg_b else 0.0,
        "wall_s": wall,
    }


def _unified_clients(kind: str, srv: RegistryServer, n: int,
                     sock_srv=None):
    """N cold ImageClients over transport ``kind`` — the one code path the
    legacy modes above also route through (via their shims)."""
    tracker = SwarmTracker()
    clients = []
    for i in range(n):
        if kind == "local":
            transport = LocalTransport(srv.registry)
        elif kind == "wire":
            transport = WireTransport(srv)
        elif kind == "socket":
            transport = SocketTransport(sock_srv.address)
        else:
            node = SwarmNode(f"n{i}", cdc_params=CDC_PARAMS,
                             cdmt_params=CDMT_PARAMS)
            transport = SwarmTransport(node, tracker, srv)
            clients.append(ImageClient(
                transport, store=node.client.store,
                indexes=node.client.indexes,
                tag_trees=node.client.tag_trees,
                cdc_params=CDC_PARAMS, cdmt_params=CDMT_PARAMS))
            continue
        clients.append(ImageClient(transport, cdc_params=CDC_PARAMS,
                                   cdmt_params=CDMT_PARAMS))
    return clients


def _unified(app: str, versions, n: int, warm_tag: str, new_tag: str,
             kind: str):
    """Rolling upgrade driven purely through ``ImageClient`` + ``Transport``
    — identical Algorithm-2 logic on every backend, so rows are directly
    comparable across the in-process, framed, socket, and peer-first paths.
    For ``kind="socket"`` every client talks real TCP to one threaded
    acceptor, and ``registry_egress_mb`` is *socket* bytes (frames plus
    envelope overhead — the number that would actually leave a NIC)."""
    srv = _loaded_server(app, versions)
    sock_srv = SocketRegistryServer(srv) if kind == "socket" else None
    clients: List[ImageClient] = []
    try:
        clients = _unified_clients(kind, srv, n, sock_srv=sock_srv)
        for cl in clients:
            cl.pull(app, warm_tag)            # provision (not measured)
        base = srv.snapshot()
        base_sock = sock_srv.snapshot() if sock_srv else None
        base_cache = srv.cache.stats
        base_snaps = [cl.metrics.snapshot() for cl in clients]
        reports: List = [None] * n

        def worker(i):
            reports[i] = clients[i].pull(app, new_tag)

        wall = _rolling_waves(n, worker)

        s = srv.snapshot()
        cache = srv.cache.stats
        hits = cache.hits - base_cache.hits
        misses = cache.misses - base_cache.misses
        peer_b = sum(r.peer_chunk_bytes for r in reports)
        reg_b = sum(r.registry_chunk_bytes for r in reports)
        if kind == "local":                   # in-process: frontend untouched
            reg_egress = sum(r.total_wire_bytes for r in reports) / 2**20
        elif kind == "socket":                # bytes that crossed the socket
            reg_egress = (sock_srv.snapshot().egress_bytes
                          - base_sock.egress_bytes) / 2**20
        else:
            reg_egress = (s.egress_bytes - base.egress_bytes) / 2**20
        lat = _pull_latency(clients, base_snaps, kind)
        return {
            "registry_egress_mb": reg_egress,
            "cache_hit_rate": hits / (hits + misses) if hits + misses else 0.0,
            "coalesced": s.coalesced_reads - base.coalesced_reads,
            "peer_offload": (peer_b / (peer_b + reg_b)
                             if peer_b + reg_b else 0.0),
            "wall_s": wall,
            "pull_p50_ms": (lat.quantile(0.5) * 1e3) if lat else 0.0,
            "pull_p99_ms": (lat.quantile(0.99) * 1e3) if lat else 0.0,
        }
    finally:
        if sock_srv is not None:
            for cl in clients:
                cl.transport.close()
            sock_srv.stop()


def run(scale: float = 1.0) -> Report:
    rep = Report("delivery_scale")
    c = corpus(scale)
    for app in APPS:
        versions = c[app]
        warm_tag = versions[max(0, len(versions) - 4)].tag   # a few behind
        new_tag = versions[-1].tag
        naive_mb = versions[-1].size / 2**20
        for n in N_CLIENTS:
            for mode, fn in (("registry", _registry_only), ("swarm", _swarm)):
                row = fn(app, versions, n, warm_tag, new_tag)
                rep.add(app=app, mode=mode, n_clients=n,
                        naive_egress_mb=naive_mb * n, **row)
    return rep


def run_unified(scale: float = 1.0) -> Report:
    """The four transports benched through the single ``ImageClient`` code
    path, same rolling-upgrade schedule and metrics as ``delivery_scale``.
    The ``unified-socket`` rows are the paper's numbers measured the way
    Sec. VI means them: bytes that actually left a TCP socket."""
    rep = Report("delivery_unified")
    c = corpus(scale)
    for app in APPS:
        versions = c[app]
        warm_tag = versions[max(0, len(versions) - 4)].tag
        new_tag = versions[-1].tag
        naive_mb = versions[-1].size / 2**20
        for n in N_CLIENTS:
            for kind in ("local", "wire", "socket", "swarm"):
                row = _unified(app, versions, n, warm_tag, new_tag, kind)
                rep.add(app=app, mode=f"unified-{kind}", n_clients=n,
                        naive_egress_mb=naive_mb * n, **row)
    return rep


def _replicated(app: str, versions, n: int, warm_tag: str, new_tag: str,
                n_replicas: int = 3, kill_primary_after_wave: int = -1):
    """Rolling upgrade through a ``ReplicatedTransport`` over ``n_replicas``
    journal-shipped socket registries.  With ``kill_primary_after_wave >=
    0`` the primary's socket server is stopped after that wave — the
    remaining waves must promote a standby and complete with zero failed
    pulls."""
    srv = _loaded_server(app, versions)
    servers = [SocketRegistryServer(srv)]
    primary_wire = WireTransport(srv)
    for i in range(n_replicas - 1):
        sreg = Registry(cdmt_params=CDMT_PARAMS)
        # catch_up, not sync_once: the first standby's ack trims the
        # primary's log, so later standbys join via snapshot bootstrap
        JournalFollower(sreg, primary_wire, name=f"standby{i}").catch_up()
        servers.append(SocketRegistryServer(RegistryServer(sreg)))
    transports: List[SocketTransport] = []
    clients: List[ImageClient] = []
    try:
        for _ in range(n):
            ts = [SocketTransport(s.address) for s in servers]
            transports.extend(ts)
            clients.append(ImageClient(ReplicatedTransport(ts),
                                       cdc_params=CDC_PARAMS,
                                       cdmt_params=CDMT_PARAMS))
        for cl in clients:
            cl.pull(app, warm_tag)            # provision (not measured)
        base = [s.snapshot().egress_bytes for s in servers]
        reports: List = [None] * n
        failures: List = [None] * n

        def worker(i):
            # a failed pull is the metric under test in failover mode —
            # count it rather than crashing the whole wave
            try:
                reports[i] = clients[i].pull(app, new_tag)
            except Exception as e:            # noqa: BLE001 — recorded
                failures[i] = e

        def after_wave(w):
            if w == kill_primary_after_wave:
                servers[0].stop()             # primary dies mid-rollout

        wall = _rolling_waves(n, worker, after_wave=after_wave)

        egress = [s.snapshot().egress_bytes - b
                  for s, b in zip(servers, base)]
        return {
            "max_replica_egress_mb": max(egress) / 2**20,
            "total_egress_mb": sum(egress) / 2**20,
            "promotions": sum(cl.transport.promotions for cl in clients),
            "failed_pulls": sum(1 for e in failures if e is not None),
            "wall_s": wall,
        }
    finally:
        for t in transports:
            t.close()
        for s in servers:
            s.stop()


def run_replicated(scale: float = 1.0) -> Report:
    """Registry replication rows: the same rolling upgrade against one
    socket registry (``single-socket``: all egress leaves one NIC), against
    N=3 journal-shipped replicas (``replicated-3``: per-registry egress cut
    ~N× — the capacity-planning win), and against N=3 with the primary
    killed after the first wave (``replicated-3-failover``: standbys are
    promoted mid-rollout and ``failed_pulls`` stays 0 — the availability
    win)."""
    rep = Report("delivery_replicated")
    c = corpus(scale)
    app = "node"
    versions = c[app]
    warm_tag = versions[max(0, len(versions) - 4)].tag
    new_tag = versions[-1].tag
    naive_mb = versions[-1].size / 2**20
    n = 8
    single = _unified(app, versions, n, warm_tag, new_tag, "socket")
    rows = [("single-socket", {
        "max_replica_egress_mb": single["registry_egress_mb"],
        "total_egress_mb": single["registry_egress_mb"],
        "promotions": 0, "failed_pulls": 0, "wall_s": single["wall_s"],
    })]
    rows.append(("replicated-3",
                 _replicated(app, versions, n, warm_tag, new_tag)))
    rows.append(("replicated-3-failover",
                 _replicated(app, versions, n, warm_tag, new_tag,
                             kill_primary_after_wave=0)))
    for mode, row in rows:
        cut = (single["registry_egress_mb"] / row["max_replica_egress_mb"]
               if row["max_replica_egress_mb"] else 0.0)
        rep.add(app=app, mode=mode, n_clients=n,
                naive_egress_mb=naive_mb * n, egress_cut=cut, **row)
    return rep


def run_socket(scale: float = 1.0) -> Report:
    """Focused wire-vs-socket comparison (the CI smoke): one app, the same
    rolling upgrade over the in-process framed path and over real TCP —
    the delta between the two rows is pure envelope + kernel-socket cost."""
    rep = Report("delivery_socket")
    c = corpus(scale)
    app = "node"
    versions = c[app]
    warm_tag = versions[max(0, len(versions) - 4)].tag
    new_tag = versions[-1].tag
    naive_mb = versions[-1].size / 2**20
    for n in N_CLIENTS[:2]:
        for kind in ("wire", "socket"):
            row = _unified(app, versions, n, warm_tag, new_tag, kind)
            rep.add(app=app, mode=kind, n_clients=n,
                    naive_egress_mb=naive_mb * n, **row)
    return rep


def run_bootstrap(scale: float = 1.0) -> Report:
    """Cold-standby join (the bounded-log rows): a fresh standby joining
    via full history replay from offset 0, versus joining via snapshot
    bootstrap (``Op.SNAPSHOT_SHIP``) once the log has been trimmed.  The
    primary carries heavy metadata churn, so the record history is far
    larger than the collapsed state — the gap between the two ``records``
    columns (and the ``log_records`` column going to zero after every
    tracked replica acks) is what ``trim_replication`` plus snapshot
    bootstrap buy a long-lived primary."""
    rep = Report("delivery_bootstrap")
    c = corpus(scale)
    app = "node"
    versions = c[app]
    srv = _loaded_server(app, versions)
    reg = srv.registry
    # metadata churn: every version's manifest rewritten repeatedly — the
    # record history grows, the collapsed current state does not
    for round_ in range(20):
        for v in versions:
            reg.put_metadata(app, v.tag, b"manifest-%d" % round_)
    head = reg.replication.head()
    log_records_before = head - reg.replication.base
    ship_mb = sum(len(r) for r in reg.replication.dump()) / 2**20

    # (a) history replay from offset 0 — the only join path while the log
    # is untrimmed; its ack then trims the log (it is the only replica)
    replay_reg = Registry(cdmt_params=CDMT_PARAMS)
    replay_fol = JournalFollower(replay_reg, WireTransport(srv),
                                 name="replay")
    with Timer() as t_replay:
        replayed = replay_fol.sync_once()
    rep.add(app=app, mode="replay-join", records=replayed,
            shipped_mb=ship_mb, wall_s=t_replay.s,
            log_records=log_records_before)

    # (b) snapshot bootstrap — the log is now trimmed to the head, so a
    # fresh standby must join from the collapsed state snapshot
    assert reg.replication.base == head, "ack should have trimmed the log"
    boot_reg = Registry(cdmt_params=CDMT_PARAMS)
    boot_fol = JournalFollower(boot_reg, WireTransport(srv), name="boot")
    with Timer() as t_boot:
        adopted = boot_fol.catch_up()
    snap_mb = boot_reg.metrics.snapshot().value(
        "bootstrap_snapshot_bytes_total", {}) / 2**20
    rep.add(app=app, mode="snapshot-bootstrap", records=adopted,
            shipped_mb=snap_mb, wall_s=t_boot.s,
            log_records=head - reg.replication.base)
    return rep


def _quantile_ms(times: List[float], q: float) -> float:
    xs = sorted(times)
    return xs[min(len(xs) - 1, int(round(q * (len(xs) - 1))))] * 1e3


def _async_rollout(app: str, versions, n: int, new_tag: str,
                   wave_size: int, n_transports: int = 8):
    """``n`` cold pullers against one ``AsyncRegistryServer`` over
    ``n_transports`` **shared** ``MuxSocketTransport``s (so the whole fleet
    rides ≤ ``n_transports * 4`` sockets).  Each puller is an ephemeral
    ``ImageClient`` built inside its worker thread — live stores are
    bounded by the wave, not by ``n``, which is what lets the 1000-puller
    row fit in memory.  Per-pull wall-clock is timed directly (not via
    histograms) so tail quantiles are exact."""
    srv = _loaded_server(app, versions)
    asrv = AsyncRegistryServer(srv)
    transports = [MuxSocketTransport(asrv.address)
                  for _ in range(n_transports)]
    times: List[float] = [0.0] * n
    try:
        base = asrv.stats

        def worker(i):
            cl = ImageClient(transports[i % len(transports)],
                             cdc_params=CDC_PARAMS, cdmt_params=CDMT_PARAMS)
            t0 = time.perf_counter()
            cl.pull(app, new_tag)
            times[i] = time.perf_counter() - t0

        wall = _rolling_waves(n, worker, wave_size=min(wave_size, n))
        s = asrv.stats
        return {
            "registry_egress_mb": (s.egress_bytes - base.egress_bytes)
            / 2**20,
            "shed": s.sheds - base.sheds,
            "server_threads": asrv.thread_count,
            "wall_s": wall,
            "pull_p50_ms": _quantile_ms(times, 0.5),
            "pull_p99_ms": _quantile_ms(times, 0.99),
        }
    finally:
        for t in transports:
            t.close()
        asrv.stop()


def _run_async(ns: List[int], scale: float, wave_size: int = 10) -> Report:
    rep = Report("delivery_async")
    c = corpus(scale)
    app = "node"
    versions = c[app]
    new_tag = versions[-1].tag
    p50_at_lowest = 0.0
    for n in ns:
        row = _async_rollout(app, versions, n, new_tag, wave_size)
        if n == ns[0]:
            p50_at_lowest = row["pull_p50_ms"]
        rep.add(app=app, mode="async-mux", n_clients=n,
                wave_size=min(wave_size, n),
                p99_over_base_p50=(row["pull_p99_ms"] / p50_at_lowest
                                   if p50_at_lowest else 0.0),
                **row)
    return rep


def run_async(scale: float = 1.0) -> Report:
    """The async data plane at fleet scale: 10 / 100 / 1000 concurrent
    pullers against **one** ``AsyncRegistryServer`` whose thread count is
    ``O(cores)`` regardless of fleet size (``server_threads`` is in every
    row).  Pullers arrive in rolling waves of 10 — bounding *offered*
    concurrency the way real rollouts do (and the way the n=10 baseline
    row runs) is precisely why the tail stays flat while total clients
    grow 100×: every row offers the same instantaneous load, only the
    fleet size differs.  The acceptance bar for the event
    loop: ``pull_p99_ms`` at n=1000 stays under 2× the n=10 median
    (``p99_over_base_p50 < 2``), and ``shed`` stays 0 (admission control
    never fires at default limits)."""
    return _run_async([10, 100, 1000], scale)


def run_async_smoke(scale: float = 1.0) -> Report:
    """CI-sized ``run_async``: 10 / 50 pullers, same schedule and columns."""
    return _run_async([10, 50], scale)


def _obs_rollout(app: str, versions, n: int, warm_tag: str, new_tag: str,
                 enabled: bool):
    """N warm socket clients upgrading sequentially, observability on or
    off end to end (registry, server, cache, transport, client, tracer).
    Returns ``(per-pull wall times, on-mode extras)``."""
    srv = _loaded_server(app, versions,
                         metrics=MetricsRegistry(enabled=enabled))
    sock_srv = SocketRegistryServer(srv)
    tracer = Tracer(enabled=enabled, capacity=4 * n)
    transports: List[SocketTransport] = []
    clients: List[ImageClient] = []
    extras = {"scrape_families": 0, "scrape_entries": 0,
              "hist_pulls": 0, "spans_recorded": 0}
    try:
        for _ in range(n):
            t = SocketTransport(sock_srv.address,
                                metrics=MetricsRegistry(enabled=enabled))
            transports.append(t)
            clients.append(ImageClient(t, cdc_params=CDC_PARAMS,
                                       cdmt_params=CDMT_PARAMS,
                                       tracer=tracer))
        for cl in clients:
            cl.pull(app, warm_tag)            # provision (not measured)
        base_snaps = [cl.metrics.snapshot() for cl in clients]
        times = []
        for cl in clients:
            t0 = time.perf_counter()
            cl.pull(app, new_tag)
            times.append(time.perf_counter() - t0)
        if enabled:
            # the numbers must also be *reachable*: scrape the live server
            # over Op.METRICS, round-trip the Prometheus exposition, and
            # check the client histograms saw every measured pull
            scraped = transports[0].scrape_metrics()
            parsed = parse_prometheus_text(to_prometheus_text(scraped))
            lat = _pull_latency(clients, base_snaps, "socket")
            spans = tracer.take()
            extras = {
                "scrape_families": len(scraped.names()),
                "scrape_entries": len(parsed),
                "hist_pulls": lat.count if lat else 0,
                "spans_recorded": len(spans),
            }
        return times, extras
    finally:
        for t in transports:
            t.close()
        sock_srv.stop()


def run_obs(scale: float = 1.0) -> Report:
    """The observability layer measured on itself: the same warm socket
    upgrade with metrics + tracing fully enabled vs fully disabled.
    ``overhead_pct`` compares median per-pull wall-clock — the enabled row
    must stay within a few percent (the instruments are pre-bound children
    behind one lock; disabled paths are shared no-ops).  The enabled row
    also proves the scrape path: a live ``Op.METRICS`` snapshot whose
    Prometheus exposition parses, client histograms covering every measured
    pull, and one recorded span tree per pull."""
    rep = Report("delivery_obs")
    c = corpus(scale)
    app = "node"
    versions = c[app]
    warm_tag = versions[max(0, len(versions) - 4)].tag
    new_tag = versions[-1].tag
    n = 8
    rows = {}
    for mode, enabled in (("obs-off", False), ("obs-on", True)):
        times, extras = _obs_rollout(app, versions, n, warm_tag, new_tag,
                                     enabled)
        rows[mode] = {"times": times, "extras": extras}
    off_med = statistics.median(rows["obs-off"]["times"])
    for mode in ("obs-off", "obs-on"):
        times = rows[mode]["times"]
        med = statistics.median(times)
        rep.add(app=app, mode=mode, n_clients=n,
                pull_p50_ms=med * 1e3,
                pull_max_ms=max(times) * 1e3,
                overhead_pct=((med - off_med) / off_med * 100
                              if mode == "obs-on" and off_med else 0.0),
                **rows[mode]["extras"])
    return rep


if __name__ == "__main__":
    scale = float(sys.argv[1]) if len(sys.argv) > 1 else 1.0
    reports = [run(scale), run_unified(scale), run_socket(scale),
               run_replicated(scale), run_bootstrap(scale), run_obs(scale),
               run_async(scale)]
    for r in reports:
        r.print_csv()
    write_json("BENCH_delivery.json", reports)
    print("# wrote BENCH_delivery.json")
