"""Fig. 7 — GLOBAL dedup ratio vs gzip as the dataset grows (apps
aggregated one by one into a single client store).

Paper: global dedup ≈7.7 when gzip ≈2.5 at full corpus size.
"""

from __future__ import annotations

import zlib

from repro.core import cdc
from repro.core.store import DedupStore

from benchmarks.common import Report
from benchmarks.corpus import corpus

CDC_PARAMS = cdc.CDCParams(mask_bits=11, min_size=256, max_size=16384)


def run() -> Report:
    rep = Report("fig7_global_dedup_growth")
    store = DedupStore(cdc_params=CDC_PARAMS)
    raw = 0
    gz = 0
    for i, (app, versions) in enumerate(corpus().items()):
        for v in versions:
            raw += v.size
            gz += sum(len(zlib.compress(l, 6)) for l in v.layers)
            for li, layer in enumerate(v.layers):
                store.ingest(f"{app}/{v.tag}/L{li}", layer)
        rep.add(n_apps=i + 1, raw_mb=raw / 2**20,
                global_dedup_ratio=raw / store.chunks.stored_bytes(),
                global_gzip_ratio=raw / gz)
    return rep


if __name__ == "__main__":
    run().print_csv()
