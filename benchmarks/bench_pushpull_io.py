"""Table II + the ≥40% claim — network/disk I/O as a client pulls
successive versions of each application.

Three pull strategies over the same version chain:
  naive  — no index: every chunk of the new version moves;
  merkle — plain Merkle index: chunks under shifted internal nodes re-move;
  cdmt   — Algorithm 2: only truly-missing chunks move.

Paper: without the CDMT index, chunk traffic is >40% higher.
"""

from __future__ import annotations

from repro.core import cdc, hashing, merkle
from repro.core.cdmt import CDMT, CDMTParams
from repro.core.pushpull import Client
from repro.core.registry import Registry

from benchmarks.common import Report
from benchmarks.corpus import corpus

CDC_PARAMS = cdc.CDCParams(mask_bits=11, min_size=256, max_size=16384)
CDMT_PARAMS = CDMTParams(window=8, rule_bits=2)


def run() -> Report:
    rep = Report("table2_pull_io")
    tot_naive = tot_merkle = tot_cdmt = 0
    for app, versions in corpus().items():
        reg = Registry(cdmt_params=CDMT_PARAMS)
        pub = Client(cdc_params=CDC_PARAMS, cdmt_params=CDMT_PARAMS)
        for v in versions:
            pub.commit(app, v.tag, v.tar())
            pub.push(reg, app, v.tag)

        # client pulls v0 then upgrades through every version
        cl = Client(cdc_params=CDC_PARAMS, cdmt_params=CDMT_PARAMS)
        cl.pull(reg, app, versions[0].tag)
        naive = merkle_b = cdmt_b = 0
        raw = 0
        shared_frac = []
        prev_tree_m = None
        prev_fps = None
        for v in versions[1:]:
            recipe = reg.recipe_for(app, v.tag)
            # naive: full artifact
            naive += recipe.total_size
            # merkle: chunks not detected shared by positional
            # (authentication-path) comparison re-move — the paper's
            # chunk-shift penalty
            tree_m = merkle.MerkleTree.build(recipe.fps, k=4)
            if prev_tree_m is None:
                prev_tree_m = merkle.MerkleTree.build(
                    reg.recipe_for(app, versions[0].tag).fps, k=4)
            shared, _ = merkle.positional_compare(prev_tree_m, tree_m)
            merkle_b += sum(size for fp, size in zip(recipe.fps, recipe.sizes)
                            if fp not in shared)
            prev_tree_m = tree_m
            # cdmt: the real pull
            stats = cl.pull(reg, app, v.tag)
            cdmt_b += stats.chunk_bytes
            raw += recipe.total_size
            if prev_fps is not None:
                shared_frac.append(
                    len(set(prev_fps) & set(recipe.fps)) / len(set(recipe.fps)))
            prev_fps = recipe.fps
        dedup_ratio = (sum(shared_frac) / len(shared_frac)) if shared_frac else 0
        rep.add(app=app, dedup_ratio=dedup_ratio,
                pull_raw_mb=raw / 2**20, naive_mb=naive / 2**20,
                merkle_mb=merkle_b / 2**20, cdmt_mb=cdmt_b / 2**20,
                naive_over_cdmt=naive / cdmt_b if cdmt_b else float("inf"),
                merkle_over_cdmt=merkle_b / cdmt_b if cdmt_b else float("inf"))
        tot_naive += naive; tot_merkle += merkle_b; tot_cdmt += cdmt_b
    rep.add(app="_total", dedup_ratio=0.0, pull_raw_mb=0.0,
            naive_mb=tot_naive / 2**20, merkle_mb=tot_merkle / 2**20,
            cdmt_mb=tot_cdmt / 2**20,
            naive_over_cdmt=tot_naive / tot_cdmt,
            merkle_over_cdmt=tot_merkle / tot_cdmt)
    return rep


if __name__ == "__main__":
    run().print_csv()
