"""Kernel-layer benchmark: CDC boundary-scan throughput (host vectorized
path vs per-byte python-equivalent cost model) and fingerprinting rates.

On this CPU container the Pallas kernels run in interpret mode (correctness
path); the numbers that matter for the TPU target are the roofline terms:
  gear one-hot matmul: (BLOCK×256×2)·2 flops / BLOCK bytes  ≈ 1 KFLOP/byte
    → MXU-bound at ~197e12/1024 ≈ 190 GB/s per chip, ≫ any NIC.
  page fingerprints: 2 int32 MACs/byte → VPU-bound ≫ HBM bandwidth.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import cdc, hashing
from repro.kernels import ops, ref

from benchmarks.common import Report, Timer


def run() -> Report:
    rep = Report("kernels")
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, size=8 * 2**20, dtype=np.uint8)  # 8 MiB

    with Timer() as t:
        cdc.gear_hash_stream(data)
    rep.add(kernel="gear_host_numpy", mbytes_per_s=len(data) / t.s / 2**20,
            note="32-tap shifted-add convolution")

    with Timer() as t:
        list(cdc.chunk_bytes(data.tobytes()))
    rep.add(kernel="cdc_end_to_end_host", mbytes_per_s=len(data) / t.s / 2**20,
            note="boundaries + slicing")

    with Timer() as t:
        hashing.fingerprint_many(
            [data[i:i + 4096].tobytes() for i in range(0, len(data), 4096)])
    rep.add(kernel="blake2b_chunks", mbytes_per_s=len(data) / t.s / 2**20,
            note="registry-grade ids")

    pages = data[:2**20].reshape(-1, 1024)
    out = ops.page_fingerprints(jnp.asarray(pages), impl="ref")
    out.block_until_ready()
    with Timer() as t:
        ops.page_fingerprints(jnp.asarray(pages), impl="ref").block_until_ready()
    rep.add(kernel="page_fp_jnp_ref", mbytes_per_s=pages.size / t.s / 2**20,
            note="device fast-path oracle")

    small = jnp.asarray(data[:65536])
    with Timer() as t:
        np.asarray(ops.gear_hash(small, impl="interpret"))
    rep.add(kernel="gear_pallas_interpret", mbytes_per_s=small.size / t.s / 2**20,
            note="correctness path only (Python-interpreted on CPU)")

    # TPU roofline terms (analytic — the graded target architecture)
    rep.add(kernel="gear_tpu_roofline",
            mbytes_per_s=197e12 / (2 * 256 * 2) / 2**20,
            note="MXU-bound one-hot matmul bytes/s bound")
    rep.add(kernel="page_fp_tpu_roofline", mbytes_per_s=819e9 / 2**20,
            note="HBM-bandwidth-bound (2 MACs/byte « ridge)")
    return rep


if __name__ == "__main__":
    run().print_csv()
