"""Fig. 9 — comparison ratio vs dedup ratio.

comparison ratio = (Alg. 2 node comparisons) / (flat key-value lookups);
dedup ratio     = fraction of chunks shared between the two versions.
Paper: as versions grow more similar, comparisons needed decrease ~linearly
(authentication-path pruning pays off exactly when dedup is high).
"""

from __future__ import annotations

from repro.core import cdc, hashing
from repro.core.cdmt import CDMT, CDMTParams, compare

from benchmarks.common import Report
from benchmarks.corpus import corpus

CDC_PARAMS = cdc.CDCParams(mask_bits=11, min_size=256, max_size=16384)
CDMT_PARAMS = CDMTParams(window=8, rule_bits=2)


def _leaf_fps(version) -> list:
    fps = []
    for layer in version.layers:
        fps.extend(hashing.chunk_fingerprint(c)
                   for c in cdc.chunk_bytes(layer, CDC_PARAMS))
    return fps


def run() -> Report:
    rep = Report("fig9_comparison_vs_dedup")
    pts = []
    for app, versions in corpus().items():
        prev = None
        for v in versions:
            fps = _leaf_fps(v)
            if prev is not None:
                a = CDMT.build(prev, CDMT_PARAMS)
                b = CDMT.build(fps, CDMT_PARAMS)
                _, comps = compare(a, b)
                comp_ratio = comps / max(1, len(fps))
                shared = len(set(prev) & set(fps)) / max(1, len(set(fps)))
                pts.append((shared, comp_ratio, app))
            prev = fps
    # bucket by similarity for a readable table
    for lo in (0.0, 0.5, 0.8, 0.9, 0.95, 0.99):
        hi = {0.0: 0.5, 0.5: 0.8, 0.8: 0.9, 0.9: 0.95, 0.95: 0.99,
              0.99: 1.01}[lo]
        sel = [c for s, c, _ in pts if lo <= s < hi]
        if sel:
            rep.add(similarity_bucket=f"{lo:.2f}-{min(hi, 1.0):.2f}",
                    n=len(sel), mean_comparison_ratio=sum(sel) / len(sel))
    # correlation check: more similar ⇒ fewer comparisons
    import numpy as np
    s = np.array([p[0] for p in pts]); c = np.array([p[1] for p in pts])
    rep.add(similarity_bucket="_pearson_r", n=len(pts),
            mean_comparison_ratio=float(np.corrcoef(s, c)[0, 1]))
    return rep


if __name__ == "__main__":
    run().print_csv()
