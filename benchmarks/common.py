"""Shared benchmark helpers: row collection + CSV/JSON emission."""

from __future__ import annotations

import json
import time
from typing import Any, Dict, List


class Report:
    def __init__(self, name: str):
        self.name = name
        self.rows: List[Dict[str, Any]] = []

    def add(self, **kw) -> None:
        self.rows.append(kw)

    def print_csv(self) -> None:
        if not self.rows:
            print(f"# {self.name}: (no rows)")
            return
        keys = list(self.rows[0].keys())
        print(f"# --- {self.name} ---")
        print(",".join(keys))
        for r in self.rows:
            print(",".join(_fmt(r.get(k)) for k in keys))

    def to_json_obj(self) -> dict:
        return {"name": self.name, "rows": self.rows}


def write_json(path: str, reports: List["Report"]) -> None:
    """Machine-readable benchmark output (``BENCH_*.json``): one object per
    report, rows as plain dicts — what CI diffs and dashboards ingest."""
    with open(path, "w") as f:
        json.dump({"v": 1, "reports": [r.to_json_obj() for r in reports]},
                  f, indent=2, sort_keys=True)
        f.write("\n")


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
