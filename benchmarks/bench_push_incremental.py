"""Incremental registry push vs full index rebuild (paper Sec. V: "maintain
the CDMT index efficiently as new image versions are pushed").

For each image size n (leaves) we push a base version and then a chain of
versions each changing k leaves.  Two metrics show the incremental path is
O(changed subtrees), not O(n):

  * ``incr_hash_calls`` — blake2b calls per push (node ids + rolling-window
    boundary tests) on the registry's verified-params path, vs
    ``full_hash_calls`` for the throwaway full rebuild the registry used to
    do.  Flat in n ⇒ push cost is proportional to change size.
  * ``push_ms`` — wall time of ``receive_push`` (includes chunk hashing of
    the k new payloads and recipe coverage checks).

The acceptance bar (≥5× fewer hash calls at n≈10k, k≈10) is asserted by
``tests/test_incremental_cdmt.py``; this benchmark shows the scaling curve.
"""

from __future__ import annotations

import numpy as np

from repro.core import hashing
from repro.core.cdmt import BuildStats, CDMT, DEFAULT_PARAMS
from repro.core.registry import Registry
from repro.core.store import Recipe

from benchmarks.common import Report, Timer

CHUNK = 64          # tiny payloads: the cost under study is indexing
K_CHANGED = 10
N_VERSIONS = 8


def _payload(rng) -> bytes:
    return rng.bytes(CHUNK)


def run() -> Report:
    rep = Report("push_incremental")
    rng = np.random.default_rng(0)
    for n in (1_000, 3_000, 10_000, 30_000):
        reg = Registry()
        payloads = [_payload(rng) for _ in range(n)]
        fps = [hashing.chunk_fingerprint(p) for p in payloads]
        sizes = [len(p) for p in payloads]
        client = CDMT.build(fps, DEFAULT_PARAMS)
        reg.receive_push("img", "v0", Recipe("img:v0", list(fps), sizes),
                         dict(zip(fps, payloads)), claimed_root=client.root)

        cur = list(fps)
        incr_calls = []
        full_calls = []
        created = []
        push_ms = []
        for v in range(1, N_VERSIONS + 1):
            newchunks = {}
            for i in rng.choice(n, size=K_CHANGED, replace=False):
                p = _payload(rng)
                fp = hashing.chunk_fingerprint(p)
                cur[int(i)] = fp
                newchunks[fp] = p
            client = CDMT.build_incremental(client, cur)
            recipe = Recipe(f"img:v{v}", list(cur), sizes)
            with Timer() as t:
                receipt = reg.receive_push("img", f"v{v}", recipe, newchunks,
                                           claimed_root=client.root)
            push_ms.append(t.s * 1e3)
            incr_calls.append(receipt.hash_calls)
            created.append(receipt.nodes_created)
            st = BuildStats()
            CDMT.build(cur, DEFAULT_PARAMS, stats=st)   # the old full path
            full_calls.append(st.hash_calls)

        rep.add(n_leaves=n, k_changed=K_CHANGED, versions=N_VERSIONS,
                incr_hash_calls=float(np.mean(incr_calls)),
                full_hash_calls=float(np.mean(full_calls)),
                hash_ratio=float(np.mean(full_calls) / np.mean(incr_calls)),
                nodes_created=float(np.mean(created)),
                push_ms=float(np.mean(push_ms)))
    return rep


if __name__ == "__main__":
    run().print_csv()
