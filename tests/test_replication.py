"""Registry replication over journal shipping.

Three layers under test:

  * the **wire contract**: SHIP / RECORD / REPL_ACK codecs round-trip, and a
    torn (truncated or bit-flipped) shipped record fails its checksum
    *before* replay;
  * the **follower protocol**: a standby syncs a primary's full history,
    resumes incrementally from its own applied offset (which survives a
    standby restart — including one that tore the standby journal's tail),
    replays duplicate deliveries idempotently, and refuses epoch gaps;
  * the **replicated transport**: reads fan across replicas, a stale
    replica is detected by CDMT root mismatch and the pull completes
    byte-identically against the primary, and a primary death mid-pull
    promotes the freshest standby with zero failed pulls — the acceptance
    gate for the paper's registry being highly available, not just durable.
"""

import os

import pytest

from repro.core import cdc
from repro.core.cdmt import CDMTParams
from repro.core.errors import DeliveryError, JournalError
from repro.core.journal import ReplicationLog
from repro.core.registry import PushRejected, Registry, record_chunk_fps
from faultpoints import CRASH_POINTS, CrashPoint, crash_at
from repro.delivery import (ImageClient, JournalFollower, LocalTransport,
                            RegistryServer, ReplicatedTransport,
                            SocketRegistryServer, SocketTransport,
                            WireTransport, wire)

PARAMS = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)
P = CDMTParams(window=4, rule_bits=2)


def _rand(n, seed=0):
    import numpy as np
    return np.random.default_rng(seed).integers(
        0, 256, size=n, dtype=np.uint8).tobytes()


def _versions(n_versions=5, size=120_000, seed=0):
    import numpy as np
    rng = np.random.default_rng(seed)
    data = bytearray(_rand(size, seed))
    out = [bytes(data)]
    for _ in range(n_versions - 1):
        for _ in range(3):
            pos = rng.integers(0, len(data) - 100)
            data[pos:pos + 64] = rng.bytes(64)
        out.append(bytes(data))
    return out


def _seed_registry(versions, lineage="app", directory=None):
    reg = Registry(directory=directory, cdmt_params=P)
    pub = ImageClient(LocalTransport(reg), cdc_params=PARAMS, cdmt_params=P)
    for i, v in enumerate(versions):
        pub.commit(lineage, f"v{i}", v)
        pub.push(lineage, f"v{i}")
    return reg


def _assert_registries_equal(a: Registry, b: Registry, lineage="app"):
    assert a.tags(lineage) == b.tags(lineage)
    for tag in a.tags(lineage):
        assert a.index_for_tag(lineage, tag).root \
            == b.index_for_tag(lineage, tag).root
        assert a.recipe_for(lineage, tag).fps == b.recipe_for(lineage,
                                                             tag).fps
    # every referenced payload is servable from the standby
    for tag in a.tags(lineage):
        fps = a.recipe_for(lineage, tag).fps
        assert b.store.missing(fps) == []


# ------------------------------------------------------------- wire contract


class TestShipCodecs:
    def test_ship_roundtrip(self):
        frame = wire.encode_ship("standby-1", 3, 17, 256)
        assert wire.decode_ship(frame) == ("standby-1", 3, 17, 256)
        with pytest.raises(wire.WireError):
            wire.decode_ship(frame[:-1])
        with pytest.raises(wire.WireError):
            wire.decode_ship(frame + b"x")

    def test_repl_ack_roundtrip(self):
        frame = wire.encode_repl_ack("s0", 1, 42)
        assert wire.decode_repl_ack(frame) == ("s0", 1, 42)
        with pytest.raises(wire.WireError):
            wire.decode_repl_ack(wire.encode_ship("s0", 1, 42, 0))

    def test_record_frame_roundtrip_and_checksum(self):
        raw = wire.encode_record(7, b"some committed payload")
        frame = wire.encode_record_frame(raw)
        assert wire.decode_record_frame(frame) \
            == (7, b"some committed payload", raw)
        # torn in transit: truncated record fails before replay
        torn = wire.encode_record_frame(raw[:-3])
        with pytest.raises(wire.WireError):
            wire.decode_record_frame(torn)
        # bit-flipped in transit: checksum catches it
        flipped = bytearray(raw)
        flipped[len(raw) // 2] ^= 0xFF
        with pytest.raises(wire.WireError):
            wire.decode_record_frame(wire.encode_record_frame(bytes(flipped)))

    def test_replication_log_offsets(self):
        log = ReplicationLog()
        assert log.head() == 0 and log.epoch == 0
        log.append(1, b"a")
        log.append(1, b"b")
        log.append(2, b"c")
        assert log.head() == 3
        assert len(log.records_from(1)) == 2
        assert len(log.records_from(0, limit=2)) == 2
        assert log.records_from(3) == []           # caught-up follower
        with pytest.raises(JournalError):
            log.records_from(4)                    # diverged follower
        assert log.rollover() == 1
        assert log.head() == 0 and log.epoch == 1

    def test_record_chunk_fps(self):
        reg = _seed_registry(_versions(2, seed=10))
        raw = reg.replication.records_from(0, 1)[0]
        rtype, payload, _ = wire.decode_record(raw, 0)
        fps = record_chunk_fps(rtype, payload)
        assert fps == reg.recipe_for("app", "v0").fps
        reg.put_metadata("app", "v0", b"manifest")
        raw_meta = reg.replication.records_from(reg.replication.head() - 1,
                                                1)[0]
        rtype, payload, _ = wire.decode_record(raw_meta, 0)
        assert record_chunk_fps(rtype, payload) == []


# --------------------------------------------------------------- the tap


class TestReplicationTap:
    def test_commits_and_metadata_feed_the_log(self):
        versions = _versions(3, seed=11)
        reg = _seed_registry(versions)
        assert reg.replication.head() == 3
        reg.put_metadata("app", "v0", b"manifest")
        assert reg.replication.head() == 4

    def test_recovery_rebuilds_offsets(self, tmp_path):
        """A primary restart must not invalidate standby resume offsets."""
        versions = _versions(3, seed=12)
        reg = _seed_registry(versions, directory=str(tmp_path))
        head = reg.replication.head()
        records = reg.replication.records_from(0)
        reg.close()
        back = Registry(directory=str(tmp_path), cdmt_params=P)
        try:
            assert back.replication.head() == head
            assert back.replication.records_from(0) == records
        finally:
            back.close()

    def test_compact_preserves_offsets(self, tmp_path):
        versions = _versions(3, seed=13)
        reg = _seed_registry(versions, directory=str(tmp_path))
        head = reg.replication.head()
        reg.compact()
        assert reg.replication.head() == head      # journal truncation is
        reg.close()                                # local, offsets logical

    def test_offsets_survive_compact_restart_with_interleaved_records(
            self, tmp_path):
        """Regression: the snapshot must preserve the replication log's
        *live* record order (commits and metadata interleaved), not a
        re-derived grouping — otherwise a standby resuming its offset after
        a primary compact+restart receives the wrong records and silently
        loses versions."""
        reg = Registry(directory=str(tmp_path), cdmt_params=P)
        pub = ImageClient(LocalTransport(reg), cdc_params=PARAMS,
                          cdmt_params=P)
        versions = _versions(2, seed=15)
        pub.commit("app", "v0", versions[0])
        pub.push("app", "v0")
        reg.put_metadata("app", "v0", b"manifest-0")    # interleaved meta
        srv = RegistryServer(reg)
        sreg = Registry(cdmt_params=P)
        fol = JournalFollower(sreg, WireTransport(srv), name="s0")
        assert fol.sync_once() == 2                     # commit + meta
        pub.commit("app", "v1", versions[1])
        pub.push("app", "v1")                           # not yet shipped
        live_records = reg.replication.dump()
        reg.compact()
        reg.close()
        back = Registry(directory=str(tmp_path), cdmt_params=P)
        try:
            assert back.replication.dump() == live_records
            fol2 = JournalFollower(sreg, WireTransport(RegistryServer(back)),
                                   name="s0")
            assert fol2.sync_once() == 1                # exactly commit v1
            _assert_registries_equal(back, sreg)
            assert sreg.get_metadata("app", "v0") == b"manifest-0"
        finally:
            back.close()

    def test_compact_crash_window_does_not_shift_offsets(self, tmp_path):
        """Crash between snapshot rename and journal truncation: the stale
        journal is a byte-identical suffix of the snapshot — recovery must
        skip it, not double-feed the replication log."""
        versions = _versions(2, seed=16)
        reg = _seed_registry(versions, directory=str(tmp_path))
        head = reg.replication.head()
        records = reg.replication.dump()
        stale = open(os.path.join(str(tmp_path), "registry.journal"),
                     "rb").read()
        reg.compact()
        reg.close()
        with open(os.path.join(str(tmp_path), "registry.journal"),
                  "wb") as f:
            f.write(stale)                  # pretend the truncate never hit
        back = Registry(directory=str(tmp_path), cdmt_params=P)
        try:
            assert back.replication.head() == head
            assert back.replication.dump() == records
            assert back.tags("app") == ["v0", "v1"]
        finally:
            back.close()

    def test_post_compact_record_identical_to_tail_is_not_dropped(
            self, tmp_path):
        """Regression: a legitimate record written right after compact()
        that happens to be byte-identical to the snapshot's last record
        (idempotent metadata re-write) must survive a restart — the
        compaction boundary marker, not a byte heuristic, decides whether
        the journal continues the snapshot."""
        reg = Registry(directory=str(tmp_path), cdmt_params=P)
        reg.put_metadata("app", "v1", b"notes")
        reg.compact()
        reg.put_metadata("app", "v1", b"notes")     # identical bytes again
        head = reg.replication.head()
        assert head == 2
        reg.close()
        back = Registry(directory=str(tmp_path), cdmt_params=P)
        try:
            assert back.replication.head() == head  # nothing dropped
            assert back.get_metadata("app", "v1") == b"notes"
        finally:
            back.close()

    def test_gc_sweep_rolls_epoch_and_reseeds(self):
        versions = _versions(3, seed=14)
        reg = _seed_registry(versions)
        reg.sweep(retain_tags={"app": ["v2"]}, drop=True)
        assert reg.replication.epoch == 1
        # re-seeded: a *fresh* standby can still sync from offset 0
        sreg = Registry(cdmt_params=P)
        JournalFollower(sreg, WireTransport(RegistryServer(reg))).sync_once()
        assert sreg.tags("app") == ["v2"]
        _assert_registries_equal(reg, sreg)

    def test_sweep_crash_between_snapshot_and_truncate_recovers(
            self, tmp_path):
        """Regression: a sweep that dies after writing its (new-epoch)
        snapshot but before truncating the (old-epoch) journal must
        recover to the swept state — the prior-epoch journal is discarded,
        not fed (which would resurrect dropped versions) and not an
        unrecoverable JournalError."""
        versions = _versions(3, seed=17)
        reg = _seed_registry(versions, directory=str(tmp_path))
        reg.compact()                       # old-epoch marker in the journal
        pub = ImageClient(LocalTransport(reg), cdc_params=PARAMS,
                          cdmt_params=P)
        pub.pull("app", "v2")
        pub.commit("app", "v3", versions[2] + _rand(2_000, seed=18))
        pub.push("app", "v3")
        stale = open(os.path.join(str(tmp_path), "registry.journal"),
                     "rb").read()
        reg.sweep(retain_tags={"app": ["v2", "v3"]}, drop=True)
        reg.close()
        # pretend the sweep's journal truncation never hit the disk
        with open(os.path.join(str(tmp_path), "registry.journal"),
                  "wb") as f:
            f.write(stale)
        back = Registry(directory=str(tmp_path), cdmt_params=P)
        try:
            assert back.replication.epoch == 1
            assert back.tags("app") == ["v2", "v3"]    # swept state, no
            assert back.replication.head() == 2        # resurrected versions
        finally:
            back.close()


# ---------------------------------------------------------------- follower


class TestJournalFollower:
    def test_full_sync_then_incremental(self):
        versions = _versions(4, seed=20)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        sreg = Registry(cdmt_params=P)
        fol = JournalFollower(sreg, WireTransport(srv), name="s0")
        assert fol.sync_once() == 4
        _assert_registries_equal(reg, sreg)
        assert fol.sync_once() == 0                # caught up: no-op
        assert fol.lag() == 0
        # one more push ships only the delta
        pub = ImageClient(LocalTransport(reg), cdc_params=PARAMS,
                          cdmt_params=P)
        pub.pull("app", "v3")
        new = versions[3] + _rand(5_000, seed=21)
        pub.commit("app", "v4", new)
        pub.push("app", "v4")
        assert fol.lag() == 1
        before = fol.chunks_fetched
        assert fol.sync_once() == 1
        assert fol.chunks_fetched - before < len(
            reg.recipe_for("app", "v4").fps)       # only missing chunks moved
        _assert_registries_equal(reg, sreg)
        assert srv.replica_offsets["s0"] == reg.replication.head()

    def test_standby_serves_pulls_byte_identically(self):
        versions = _versions(3, seed=22)
        reg = _seed_registry(versions)
        sreg = Registry(cdmt_params=P)
        JournalFollower(sreg, WireTransport(RegistryServer(reg))).sync_once()
        a = ImageClient(LocalTransport(reg), cdc_params=PARAMS, cdmt_params=P)
        b = ImageClient(LocalTransport(sreg), cdc_params=PARAMS,
                        cdmt_params=P)
        ra = a.pull("app", "v2")
        rb = b.pull("app", "v2")
        assert a.materialize("app", "v2") == b.materialize("app", "v2") \
            == versions[2]
        assert ra.chunks_moved == rb.chunks_moved
        assert ra.chunk_bytes == rb.chunk_bytes

    def test_duplicate_delivery_is_idempotent(self):
        """A lost ack (or a crash between apply and ack) re-ships records
        the standby already applied — they must be skipped, not re-applied."""
        versions = _versions(3, seed=23)
        reg = _seed_registry(versions)
        sreg = Registry(cdmt_params=P)
        fol = JournalFollower(sreg, WireTransport(RegistryServer(reg)))
        # capture record 0 before the follower acks: once every tracked
        # replica has acked past it, the primary trims it away
        raw = reg.replication.records_from(0, 1)[0]
        fol.sync_once()
        n_versions = len(sreg.tags("app"))
        rtype, payload, _ = wire.decode_record(raw, 0)
        assert sreg.apply_replicated(rtype, payload, expected_seq=0) is False
        assert len(sreg.tags("app")) == n_versions
        assert sreg.replication.head() == reg.replication.head()

    def test_gap_is_refused(self):
        versions = _versions(2, seed=24)
        reg = _seed_registry(versions)
        sreg = Registry(cdmt_params=P)
        raw = reg.replication.records_from(1, 1)[0]
        rtype, payload, _ = wire.decode_record(raw, 0)
        with pytest.raises(JournalError):
            sreg.apply_replicated(rtype, payload, expected_seq=1)

    def test_torn_shipped_record_replays_idempotently(self, tmp_path):
        """The standby crashes mid-append while journaling a shipped record:
        on restart the torn tail is truncated, the resume offset falls back
        to the last complete record, and re-shipping applies the record
        exactly once — the standby ends bit-identical to the primary."""
        versions = _versions(4, seed=25)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        sdir = str(tmp_path / "standby")
        os.makedirs(sdir)
        sreg = Registry(directory=sdir, cdmt_params=P)
        fol = JournalFollower(sreg, WireTransport(srv), name="s0")
        # capture record 3 before the follower acks (the ack trims the log)
        raw = reg.replication.records_from(3, 1)[0]
        fol.sync_once()
        assert sreg.replication.head() == 4
        with open(os.path.join(sdir, "registry.journal"), "ab") as f:
            f.write(raw[:len(raw) // 2])
        sreg.close()
        back = Registry(directory=sdir, cdmt_params=P)
        try:
            assert back.replication.head() == 4    # torn tail discarded
            fol2 = JournalFollower(back, WireTransport(srv), name="s0")
            assert fol2.sync_once() == 0           # nothing new to apply
            _assert_registries_equal(reg, back)
            assert back.tags("app") == [f"v{i}" for i in range(4)]
        finally:
            back.close()

    def test_standby_restart_resumes_from_journal(self, tmp_path):
        versions = _versions(3, seed=26)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        sdir = str(tmp_path / "standby")
        os.makedirs(sdir)
        sreg = Registry(directory=sdir, cdmt_params=P)
        JournalFollower(sreg, WireTransport(srv)).sync_once()
        sreg.close()
        # primary advances while the standby is down
        pub = ImageClient(LocalTransport(reg), cdc_params=PARAMS,
                          cdmt_params=P)
        pub.pull("app", "v2")
        pub.commit("app", "v3", versions[2] + _rand(4_000, seed=27))
        pub.push("app", "v3")
        back = Registry(directory=sdir, cdmt_params=P)
        try:
            fol = JournalFollower(back, WireTransport(srv))
            assert fol.sync_once() == 1            # only the new record
            _assert_registries_equal(reg, back)
        finally:
            back.close()

    def test_restarted_follower_refused_after_primary_sweep(self):
        """Regression: a follower constructed *fresh* over an already-synced
        standby must resume with the standby's persisted epoch, not a
        freshly probed one — otherwise a primary GC sweep between follower
        restarts lets old-epoch offsets replay against the new-epoch log
        and the standby silently diverges."""
        versions = _versions(3, seed=56)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        sreg = Registry(cdmt_params=P)
        JournalFollower(sreg, WireTransport(srv), name="s0").sync_once()
        reg.sweep(retain_tags={"app": ["v2"]}, drop=True)   # epoch 0 -> 1
        pub = ImageClient(LocalTransport(reg), cdc_params=PARAMS,
                          cdmt_params=P)
        pub.pull("app", "v2")
        pub.commit("app", "v3", versions[2] + _rand(3_000, seed=57))
        pub.push("app", "v3")
        fresh_follower = JournalFollower(sreg, WireTransport(srv), name="s0")
        with pytest.raises(DeliveryError):
            fresh_follower.sync_once()
        assert "v3" not in sreg.tags("app")    # nothing cross-epoch applied

    def test_fresh_standby_adopts_primary_epoch_durably(self, tmp_path):
        versions = _versions(2, seed=58)
        reg = _seed_registry(versions)
        reg.sweep(retain_tags={"app": ["v1"]}, drop=True)   # primary epoch 1
        srv = RegistryServer(reg)
        sdir = str(tmp_path / "standby")
        os.makedirs(sdir)
        sreg = Registry(directory=sdir, cdmt_params=P)
        JournalFollower(sreg, WireTransport(srv)).sync_once()
        assert sreg.replication.epoch == 1
        sreg.close()
        back = Registry(directory=sdir, cdmt_params=P)
        try:
            assert back.replication.epoch == 1      # epoch survives restart
            fol = JournalFollower(back, WireTransport(srv))
            assert fol.sync_once() == 0
            _assert_registries_equal(reg, back)
        finally:
            back.close()

    def test_follow_thread_survives_divergence(self):
        """Regression: a diverged standby (ahead of the primary's log)
        raises JournalError — the follow() daemon must record it in
        last_error and keep retrying, never die silently."""
        import time
        donor = _seed_registry(_versions(2, seed=59))
        sreg = Registry(cdmt_params=P)
        JournalFollower(sreg, WireTransport(RegistryServer(donor))
                        ).sync_once()
        empty_primary = Registry(cdmt_params=P)     # head 0: standby is ahead
        fol = JournalFollower(sreg, WireTransport(
            RegistryServer(empty_primary)), poll_interval=0.01)
        fol.follow()
        try:
            deadline = 100
            while fol.last_error is None and deadline:
                time.sleep(0.02)
                deadline -= 1
            assert fol.last_error is not None
            assert fol._thread.is_alive()           # still retrying
        finally:
            fol.stop()

    def test_epoch_mismatch_requires_full_resync(self):
        versions = _versions(3, seed=28)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        sreg = Registry(cdmt_params=P)
        fol = JournalFollower(sreg, WireTransport(srv))
        fol.sync_once()
        reg.sweep(retain_tags={"app": ["v2"]}, drop=True)   # epoch rollover
        with pytest.raises(DeliveryError):
            fol.sync_once()
        # a fresh standby at the new epoch syncs fine
        fresh = Registry(cdmt_params=P)
        JournalFollower(fresh, WireTransport(srv)).sync_once()
        assert fresh.tags("app") == ["v2"]

    def test_follow_thread_keeps_up(self):
        versions = _versions(2, seed=29)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        sreg = Registry(cdmt_params=P)
        fol = JournalFollower(sreg, WireTransport(srv), poll_interval=0.01)
        fol.follow()
        try:
            pub = ImageClient(LocalTransport(reg), cdc_params=PARAMS,
                              cdmt_params=P)
            pub.pull("app", "v1")
            pub.commit("app", "v2", versions[1] + _rand(3_000, seed=30))
            pub.push("app", "v2")
            deadline = 100
            while fol.lag() and deadline:
                import time
                time.sleep(0.02)
                deadline -= 1
            assert fol.lag() == 0
            _assert_registries_equal(reg, sreg)
        finally:
            fol.stop()


# ------------------------------------------------------------- socket ship


class TestSocketShip:
    def test_ship_over_real_tcp(self):
        versions = _versions(3, seed=31)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        with SocketRegistryServer(srv) as door:
            with SocketTransport(door.address) as t:
                epoch, head = t.replication_status()
                assert (epoch, head) == (0, 3)
                sreg = Registry(cdmt_params=P)
                fol = JournalFollower(sreg, t, name="tcp-standby")
                assert fol.sync_once() == 3
                _assert_registries_equal(reg, sreg)
                s = srv.snapshot()
                assert s.ship_requests >= 2        # probe + ship
                assert s.records_shipped == 3
                assert s.repl_acks >= 1
                assert srv.replica_offsets["tcp-standby"] == 3

    def test_stale_epoch_ack_is_dropped(self):
        """Regression: a late REPL_ACK from an old-epoch standby must not
        overwrite the lag table with an offset that is meaningless against
        the new epoch's head."""
        versions = _versions(3, seed=60)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        t = WireTransport(srv)
        t.ack_journal("s0", 0, 3)
        assert srv.replica_offsets["s0"] == 3
        reg.sweep(retain_tags={"app": ["v2"]}, drop=True)   # epoch 0 -> 1
        epoch, head = t.ack_journal("s0", 0, 3)             # late old ack
        assert epoch == 1
        assert "s0" not in srv.replica_offsets              # forgotten
        t.ack_journal("s0", 1, 1)
        assert srv.replica_offsets["s0"] == 1

    def test_ship_is_metered(self):
        versions = _versions(2, seed=32)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        s0 = srv.snapshot()
        t = WireTransport(srv)
        t.ship_journal("s0", 0, 0, 512)
        s1 = srv.snapshot()
        assert s1.ingress_bytes > s0.ingress_bytes
        assert s1.egress_bytes > s0.egress_bytes


# ----------------------------------------------------- replicated transport


def _replicated_stack(versions, n_standbys=2, batch_chunks=16):
    """Primary + synced standbys behind sockets, a ReplicatedTransport
    client, and the underlying servers for egress inspection."""
    reg = _seed_registry(versions)
    servers = [SocketRegistryServer(RegistryServer(reg))]
    primary_wire = WireTransport(servers[0].server)
    standby_regs = []
    for i in range(n_standbys):
        sreg = Registry(cdmt_params=P)
        # the first standby's ack trims the log, so later standbys join
        # via snapshot bootstrap — catch_up picks the right path
        JournalFollower(sreg, primary_wire, name=f"s{i}").catch_up()
        standby_regs.append(sreg)
        servers.append(SocketRegistryServer(RegistryServer(sreg)))
    transports = [SocketTransport(s.address) for s in servers]
    rt = ReplicatedTransport(transports)
    cl = ImageClient(rt, cdc_params=PARAMS, cdmt_params=P,
                     batch_chunks=batch_chunks)
    return reg, standby_regs, servers, transports, rt, cl


def _teardown(servers, transports):
    for t in transports:
        t.close()
    for s in servers:
        s.stop()


class TestReplicatedTransport:
    def test_plan_quote_exact_envelope_included(self):
        versions = _versions(3, seed=33)
        _, _, servers, transports, rt, cl = _replicated_stack(versions)
        try:
            plan = cl.plan_pull("app", "v2")
            assert plan.transport == "replicated"
            report = cl.execute(plan)
            assert (report.index_bytes + report.recipe_bytes
                    + report.chunk_bytes) == plan.expected_wire_bytes
            assert cl.materialize("app", "v2") == versions[2]
        finally:
            _teardown(servers, transports)

    def test_reads_fan_across_replicas(self):
        versions = _versions(3, seed=34)
        _, _, servers, transports, rt, cl = _replicated_stack(versions,
                                                              batch_chunks=8)
        try:
            base = [s.snapshot().egress_bytes for s in servers]
            cl.pull("app", "v0")
            egress = [s.snapshot().egress_bytes - b
                      for s, b in zip(servers, base)]
            # every replica carried chunk traffic (many batches, 3 replicas)
            assert all(e > 0 for e in egress), egress
        finally:
            _teardown(servers, transports)

    def test_stale_root_detected_pull_byte_identical_vs_primary(self):
        """A standby serving a *stale root* for the tag is detected by CDMT
        root mismatch and excluded; the pull completes byte-identically
        against the primary (same chunk set as the single-registry pull)."""
        versions = _versions(3, seed=35)
        reg, standby_regs, servers, transports, rt, cl = \
            _replicated_stack(versions)
        try:
            # baseline: what a single-registry pull of v2 moves
            baseline = ImageClient(LocalTransport(_seed_registry(versions)),
                                   cdc_params=PARAMS, cdmt_params=P)
            bplan = baseline.plan_pull("app", "v2")
            # corrupt both standbys: bind the tag to an older version's root
            for sreg in standby_regs:
                sreg.lineages["app"]._by_tag["v2"] = 0
                assert sreg.index_for_tag("app", "v2").root \
                    != reg.index_for_tag("app", "v2").root
            plan = cl.plan_pull("app", "v2")
            assert set(plan.missing) == set(bplan.missing)
            report = cl.execute(plan)
            assert rt.stale_detected >= 1
            assert report.chunks_moved == len(bplan.missing)
            # every chunk byte came from the primary, none from stale standbys
            assert report.sources["registry"].chunks == report.chunks_moved
            assert cl.materialize("app", "v2") == versions[2]
            baseline.execute(bplan)
            assert baseline.materialize("app", "v2") == versions[2]
        finally:
            _teardown(servers, transports)

    def test_lagging_standby_falls_through_to_primary(self):
        """A standby that never synced the tag is stale (probe fails) — the
        pull still completes, entirely from sources that hold the data."""
        versions = _versions(3, seed=36)
        reg = _seed_registry(versions)
        servers = [SocketRegistryServer(RegistryServer(reg))]
        empty = Registry(cdmt_params=P)              # never synced
        servers.append(SocketRegistryServer(RegistryServer(empty)))
        transports = [SocketTransport(s.address) for s in servers]
        rt = ReplicatedTransport(transports)
        cl = ImageClient(rt, cdc_params=PARAMS, cdmt_params=P,
                         batch_chunks=16)
        try:
            rep = cl.pull("app", "v2")
            assert cl.materialize("app", "v2") == versions[2]
            assert rep.chunks_moved == rep.chunks_total
            assert rt.stale_detected >= 1
        finally:
            _teardown(servers, transports)

    def test_promoted_standby_after_primary_death_mid_pull(self):
        """The acceptance gate: plan while the primary lives, kill it, and
        the executing pull promotes the freshest standby and moves the
        byte-identical chunk set a single healthy registry would have."""
        versions = _versions(4, seed=37)
        reg, standby_regs, servers, transports, rt, cl = \
            _replicated_stack(versions)
        try:
            baseline = ImageClient(LocalTransport(_seed_registry(versions)),
                                   cdc_params=PARAMS, cdmt_params=P)
            bplan = baseline.plan_pull("app", "v3")
            brep = baseline.execute(bplan)
            plan = cl.plan_pull("app", "v3")
            assert set(plan.missing) == set(bplan.missing)
            servers[0].stop()                        # primary dies mid-pull
            report = cl.execute(plan)
            assert rt.primary_index != 0             # a standby took over
            assert rt.promotions >= 1
            assert report.chunks_moved == brep.chunks_moved
            assert cl.materialize("app", "v3") == versions[3] \
                == baseline.materialize("app", "v3")
            # and the promoted standby now answers the control plane too
            assert cl.transport.tags("app") == [f"v{i}" for i in range(4)]
        finally:
            _teardown(servers[1:], transports)

    def test_pushes_route_to_primary_then_replicate(self):
        versions = _versions(2, seed=38)
        reg, standby_regs, servers, transports, rt, cl = \
            _replicated_stack(versions)
        try:
            cl.pull("app", "v1")
            cl.commit("app", "v2", versions[1] + _rand(4_000, seed=39))
            cl.push("app", "v2")
            assert reg.tags("app") == ["v0", "v1", "v2"]
            assert standby_regs[0].tags("app") == ["v0", "v1"]  # not yet
            fol = JournalFollower(standby_regs[0],
                                  WireTransport(servers[0].server), name="s0")
            fol.sync_once()
            _assert_registries_equal(reg, standby_regs[0])
        finally:
            _teardown(servers, transports)


# ---------------------------------------------- snapshot bootstrap and trim


class TestSnapshotBootstrap:
    """The bounded log: acks trim the replication log below the lowest
    tracked replica offset; fresh standbys join from the collapsed state
    snapshot (``Op.SNAPSHOT_SHIP``) instead of replaying offset 0; an
    epoch roll wipe-and-resyncs automatically instead of stalling."""

    def test_acks_trim_log_to_lowest_replica_offset(self):
        versions = _versions(3, seed=70)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        t = WireTransport(srv)
        head = reg.replication.head()
        t.ack_journal("slow", 0, 1)
        assert reg.replication.base == 1            # min over {slow: 1}
        t.ack_journal("fast", 0, head)
        assert reg.replication.base == 1            # slow pins the log
        assert srv.replica_offsets == {"slow": 1, "fast": head}
        assert reg.replication.base == min(srv.replica_offsets.values())
        t.ack_journal("slow", 0, head)
        assert reg.replication.base == head         # everyone acked: empty
        assert reg.replication.dump() == []
        assert reg.replication.head() == head       # offsets never reissued
        snap = reg.metrics.snapshot()
        assert snap.value("replication_log_trimmed_total", {}) == head
        assert snap.value("replication_log_base", {}) == head
        assert snap.value("replication_log_records", {}) == 0

    def test_fresh_standby_joins_via_snapshot_not_history(self):
        versions = _versions(4, seed=71)
        reg = _seed_registry(versions)
        for i in range(10):                    # metadata churn: 10 records
            reg.put_metadata("app", "v0", b"m%d" % i)
        srv = RegistryServer(reg)
        t = WireTransport(srv)
        head = reg.replication.head()
        t.ack_journal("s0", 0, head)           # every record acked: trimmed
        assert reg.replication.base == head
        sreg = Registry(cdmt_params=P)
        fol = JournalFollower(sreg, t, name="s1")
        applied = fol.catch_up()
        # collapsed state: one commit per version plus the *current*
        # metadata value — not the 14-record history
        assert applied == len(versions) + 1 < head
        assert srv.snapshot().snapshot_requests == 1
        assert fol.records_applied == applied
        _assert_registries_equal(reg, sreg)
        assert sreg.metadata[("app", "v0")] == b"m9"
        assert sreg.replication.head() == head  # resumes from the offset
        assert srv.replica_offsets["s1"] == head
        # later pushes ship incrementally — no second bootstrap
        pub = ImageClient(LocalTransport(reg), cdc_params=PARAMS,
                          cdmt_params=P)
        pub.pull("app", "v3")
        pub.commit("app", "v4", versions[3] + _rand(2_000, seed=72))
        pub.push("app", "v4")
        assert fol.catch_up() == 1
        assert fol._m_bootstraps.value() == 1
        _assert_registries_equal(reg, sreg)

    def test_standby_read_only_until_promoted(self):
        versions = _versions(2, seed=73)
        reg = _seed_registry(versions)
        sreg = Registry(cdmt_params=P)
        fol = JournalFollower(sreg, WireTransport(RegistryServer(reg)),
                              name="s0")
        fol.catch_up()
        pub = ImageClient(LocalTransport(sreg), cdc_params=PARAMS,
                          cdmt_params=P)
        pub.commit("app", "v2", _rand(40_000, seed=74))
        with pytest.raises(PushRejected):
            pub.push("app", "v2")
        with pytest.raises(PushRejected):
            sreg.put_metadata("app", "v1", b"m")
        assert sreg.tags("app") == ["v0", "v1"]    # nothing landed
        fol.promote()
        pub.push("app", "v2")                  # accepted after promotion
        assert sreg.tags("app") == ["v0", "v1", "v2"]

    def test_epoch_roll_triggers_automatic_wipe_and_resync(self):
        versions = _versions(3, seed=75)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        sreg = Registry(cdmt_params=P)
        fol = JournalFollower(sreg, WireTransport(srv), name="s0")
        fol.sync_once()
        reg.sweep(retain_tags={"app": ["v2"]}, drop=True)   # epoch 0 -> 1
        applied = fol.catch_up()               # no operator intervention
        assert applied >= 1
        assert fol._m_bootstraps.value() == 1
        assert fol._m_epoch_mismatch.value() == 1
        assert sreg.replication.epoch == 1
        assert sreg.tags("app") == ["v2"]
        _assert_registries_equal(reg, sreg)

    def test_auto_resync_off_stalls_visibly(self):
        """Regression pin for the historical behavior: with
        ``auto_resync=False`` an epoch roll leaves the follower stalled —
        a typed ``DeliveryError`` in ``last_error``, nothing wiped, the
        mismatch counter visible on a scrape — until an operator flips
        resync back on."""
        import time
        versions = _versions(3, seed=76)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        sreg = Registry(cdmt_params=P)
        fol = JournalFollower(sreg, WireTransport(srv), name="s0",
                              poll_interval=0.01, auto_resync=False)
        fol.sync_once()
        head_before = sreg.replication.head()
        reg.sweep(retain_tags={"app": ["v2"]}, drop=True)
        fol.follow()
        try:
            deadline = 250
            while fol.last_error is None and deadline:
                time.sleep(0.02)
                deadline -= 1
            assert isinstance(fol.last_error, DeliveryError)
            assert "epoch mismatch" in str(fol.last_error)
            assert sreg.replication.head() == head_before  # nothing wiped
            assert sreg.tags("app") == ["v0", "v1", "v2"]
            snap = sreg.metrics.snapshot()
            assert snap.value("replication_epoch_mismatch_total", {}) >= 1
            assert snap.value("replication_bootstraps_total", {}) == 0
        finally:
            fol.stop()
        # the operator's lever: re-enable resync and converge
        fol.auto_resync = True
        assert fol.catch_up() >= 1
        _assert_registries_equal(reg, sreg)


# ------------------------------------------------------------- crash matrix


class TestCrashMatrix:
    """Kill the 'process' at every planted fault point, reopen from the
    directory, and assert byte-identical recovery (primary) or an
    idempotent bootstrap restart (standby).  ``CRASH_POINTS`` is the full
    catalog — the coverage test fails if a new ``faults.fire`` site lands
    without a matrix entry."""

    PRIMARY_POINTS = [p for p in CRASH_POINTS
                      if p.startswith(("trim.", "compact."))]
    STANDBY_POINTS = [p for p in CRASH_POINTS
                      if p.startswith(("bootstrap.", "follower."))]

    def test_catalog_covers_every_planted_point(self):
        import pathlib
        import re
        src = pathlib.Path(__file__).resolve().parents[1] / "src"
        planted = set()
        for p in src.rglob("*.py"):
            if p.name == "faults.py":      # its docstring shows the idiom
                continue
            planted |= set(re.findall(r'faults\.fire\("([^"]+)"\)',
                                      p.read_text()))
        assert planted == set(CRASH_POINTS)
        assert set(self.PRIMARY_POINTS) | set(self.STANDBY_POINTS) \
            == set(CRASH_POINTS)

    @pytest.mark.parametrize("point", PRIMARY_POINTS)
    def test_primary_dies_mid_trim_recovers_byte_identical(self, tmp_path,
                                                           point):
        versions = _versions(3, seed=77)
        pdir = str(tmp_path / "primary")
        os.makedirs(pdir)
        reg = _seed_registry(versions, directory=pdir)
        epoch, head = reg.replication.epoch, reg.replication.head()
        records = reg.replication.dump()
        with crash_at(point), pytest.raises(CrashPoint):
            reg.trim_replication(head)     # every replica acked everything
        reg.close()                        # the "process" died here
        back = Registry(directory=pdir, cdmt_params=P)
        try:
            # state: byte-identical to an untouched seed
            _assert_registries_equal(_seed_registry(versions), back)
            # log: same position; base either untrimmed (crash before any
            # durable step) or fully trimmed — never torn — and every
            # surviving record is byte-identical to the original
            assert back.replication.epoch == epoch
            assert back.replication.head() == head
            assert back.replication.base in (0, head)
            assert back.replication.dump() == records[back.replication.base:]
            # a fresh standby joins the recovered primary either way
            sreg = Registry(cdmt_params=P)
            JournalFollower(sreg, WireTransport(RegistryServer(back)),
                            name="s0").catch_up()
            _assert_registries_equal(back, sreg)
        finally:
            back.close()

    @pytest.mark.parametrize("point", STANDBY_POINTS)
    def test_standby_dies_mid_bootstrap_restarts_idempotently(
            self, tmp_path, point):
        versions = _versions(3, seed=78)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        t = WireTransport(srv)
        head = reg.replication.head()
        t.ack_journal("acked", 0, head)    # trimmed: joining = bootstrap
        sdir = str(tmp_path / "standby")
        os.makedirs(sdir)
        sreg = Registry(directory=sdir, cdmt_params=P)
        fol = JournalFollower(sreg, t, name="s0")
        with crash_at(point), pytest.raises(CrashPoint):
            fol.catch_up()
        sreg.close()
        back = Registry(directory=sdir, cdmt_params=P)
        try:
            # recovery is all-or-nothing: either the pre-bootstrap empty
            # state or the complete snapshot — never a torn mixture
            assert back.replication.head() in (0, head)
            if back.replication.head() == head:
                _assert_registries_equal(reg, back)
            else:
                assert back.tags("app") == []
            # the restarted follower completes the join either way
            fol2 = JournalFollower(back, t, name="s0")
            fol2.catch_up()
            _assert_registries_equal(reg, back)
            assert back.replication.head() == head
            assert srv.replica_offsets["s0"] == head
        finally:
            back.close()

    @pytest.mark.parametrize("point", STANDBY_POINTS)
    def test_synced_standby_dies_mid_resync_after_epoch_roll(
            self, tmp_path, point):
        """The hardest window: a standby with a durable old-epoch journal
        crashes mid wipe-and-resync.  Recovery must never mix epochs —
        the reopened standby is wholly pre-resync (old epoch) or wholly
        post-resync (new epoch) — and the restarted follower converges."""
        versions = _versions(3, seed=79)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        t = WireTransport(srv)
        sdir = str(tmp_path / "standby")
        os.makedirs(sdir)
        sreg = Registry(directory=sdir, cdmt_params=P)
        fol = JournalFollower(sreg, t, name="s0")
        fol.sync_once()                    # durable old-epoch history
        reg.sweep(retain_tags={"app": ["v2"]}, drop=True)   # epoch 0 -> 1
        with crash_at(point), pytest.raises(CrashPoint):
            fol.catch_up()
        sreg.close()
        back = Registry(directory=sdir, cdmt_params=P)
        try:
            assert back.replication.epoch in (0, 1)   # never torn
            if back.replication.epoch == 1:
                _assert_registries_equal(reg, back)
            else:
                assert back.tags("app") == ["v0", "v1", "v2"]
            fol2 = JournalFollower(back, t, name="s0")
            fol2.catch_up()
            _assert_registries_equal(reg, back)
            assert back.replication.epoch == reg.replication.epoch
        finally:
            back.close()
