"""CDMT (Alg. 1 build, Alg. 2 compare) and the chunk-shift contrast vs
plain Merkle trees — the paper's core claims as tests."""

import numpy as np
import pytest

from repro.core import cdc, hashing, merkle
from repro.core.cdmt import (CDMT, CDMTParams, common_node_ratio, compare,
                             comparison_ratio, diff_chunks)

P = CDMTParams(window=4, rule_bits=2)


def _fps(n, seed=0):
    rng = np.random.default_rng(seed)
    return [hashing.chunk_fingerprint(rng.bytes(32)) for _ in range(n)]


class TestBuild:
    def test_empty(self):
        t = CDMT.build([], P)
        assert t.root is None and t.n_nodes() == 0

    def test_single_leaf(self):
        fps = _fps(1)
        t = CDMT.build(fps, P)
        assert t.root == fps[0]

    def test_all_leaves_present(self):
        fps = _fps(200)
        t = CDMT.build(fps, P)
        assert t.leaf_fps() == fps
        assert all(fp in t.nodes for fp in fps)

    def test_root_depends_on_content(self):
        a = CDMT.build(_fps(50, seed=1), P)
        b = CDMT.build(_fps(50, seed=2), P)
        assert a.root != b.root

    def test_deterministic(self):
        fps = _fps(100, seed=3)
        assert CDMT.build(fps, P).root == CDMT.build(fps, P).root

    def test_expected_fanout(self):
        """rule_bits=2 ⇒ ~1 parent per 4 children ⇒ total nodes ≤ (4/3)N + h
        (the paper's O(N) complexity argument)."""
        fps = _fps(3000, seed=4)
        t = CDMT.build(fps, P)
        assert t.n_nodes() < 1.6 * len(fps)

    def test_low_height(self):
        fps = _fps(4096, seed=5)
        t = CDMT.build(fps, P)
        assert t.height() <= 16


class TestCompare:
    def test_identical_trees_one_comparison(self):
        fps = _fps(128)
        a, b = CDMT.build(fps, P), CDMT.build(fps, P)
        missing, comps = compare(a, b)
        assert missing == set() and comps == 1    # root matches, prune all

    def test_fresh_pull(self):
        fps = _fps(64)
        t = CDMT.build(fps, P)
        missing, comps = compare(None, t)
        assert missing == set(fps) and comps == 0

    def test_detects_exactly_the_new_leaves(self):
        fps = _fps(256, seed=6)
        new = _fps(3, seed=7)
        edited = fps[:100] + new + fps[100:]
        a = CDMT.build(fps, P)
        b = CDMT.build(edited, P)
        missing = diff_chunks(a, b)
        assert set(new) <= missing
        # locality: only the edit path may be extra
        assert len(missing) <= len(new) + 4 * P.window

    def test_comparisons_sublinear_for_similar_trees(self):
        fps = _fps(2048, seed=8)
        edited = list(fps)
        edited[1024] = hashing.chunk_fingerprint(b"edit")
        a, b = CDMT.build(fps, P), CDMT.build(edited, P)
        assert comparison_ratio(a, b) < 0.2       # Fig. 9 regime


class TestChunkShiftResistance:
    """Fig. 8: an insertion that changes the chunk COUNT renames nearly every
    internal node of a plain Merkle tree, but leaves most CDMT nodes intact."""

    def _trees(self, n=512, insert_at=200, seed=9):
        fps = _fps(n, seed=seed)
        shifted = fps[:insert_at] + _fps(1, seed=99) + fps[insert_at:]
        return fps, shifted

    def test_cdmt_resists_chunk_shift(self):
        fps, shifted = self._trees()
        a, b = CDMT.build(fps, P), CDMT.build(shifted, P)
        assert common_node_ratio(a, b) > 0.9

    def test_merkle_suffers_chunk_shift(self):
        fps, shifted = self._trees()
        ma, mb = merkle.MerkleTree.build(fps, k=4), merkle.MerkleTree.build(shifted, k=4)
        merkle_ratio = merkle.common_node_ratio(ma, mb)
        a, b = CDMT.build(fps, P), CDMT.build(shifted, P)
        cdmt_ratio = common_node_ratio(a, b)
        # leaves are shared either way (diluting the ratio); internal nodes
        # diverge only in Merkle — the internal-only contrast is below
        assert cdmt_ratio > merkle_ratio + 0.1

    def test_merkle_internal_nodes_nearly_all_change(self):
        # insert near the FRONT: the paper (Sec. III-C) — every internal node
        # to the right of the shift changes, so almost nothing survives
        fps, shifted = self._trees(insert_at=40)
        ma = merkle.MerkleTree.build(fps, k=4)
        mb = merkle.MerkleTree.build(shifted, k=4)
        internal_a = ma.node_set() - set(fps)
        internal_b = mb.node_set() - set(shifted)
        shared = internal_a & internal_b
        assert len(shared) / len(internal_b) < 0.2
        # CDMT on the same shift keeps most internal nodes
        a, b = CDMT.build(fps, P), CDMT.build(shifted, P)
        int_a = a.node_set() - set(fps)
        int_b = b.node_set() - set(shifted)
        assert len(int_a & int_b) / len(int_b) > 0.8


class TestAuthenticationPath:
    def test_path_verifies_leaf(self):
        fps = _fps(300, seed=10)
        t = CDMT.build(fps, P)
        path = t.authentication_path(fps[17])
        assert all(p in t.nodes for p in path)
        assert len(path) < len(fps)


# Hypothesis property tests live in tests/test_properties.py (optional dep).
