"""Per-architecture smoke tests (reduced configs, CPU) + serve consistency.

Each assigned arch: one train step forward (finite loss, shapes), prefill →
decode consistency against teacher forcing.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_archs
from repro.models import lm
from repro.models.api import Model, build_model

ARCHS = list_archs()


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(name):
        if name not in cache:
            m = build_model(name, reduced=True)
            cache[name] = (m, m.init_params(jax.random.PRNGKey(0)))
        return cache[name]
    return get


@pytest.mark.parametrize("name", ARCHS)
def test_all_archs_registered_with_exact_dims(name):
    cfg = get_config(name)
    expected = {
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }[name]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected


@pytest.mark.parametrize("name", ARCHS)
def test_train_forward(name, models):
    m, params = models(name)
    batch = m.make_batch("train", 2, 64)
    loss = m.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    assert 2.0 < float(loss) < 12.0          # ~ln(vocab) at init


@pytest.mark.parametrize("name", ARCHS)
def test_grads_finite(name, models):
    m, params = models(name)
    batch = m.make_batch("train", 2, 64)
    grads = jax.grad(lambda p: m.loss(p, batch))(params)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in leaves)


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_consistent_with_teacher_forcing(name, models):
    """logits(prefill(x)) == logits(forward(x))[-1] and one decode step
    matches the teacher-forced next-position logits."""
    m, params = models(name)
    cfg = m.cfg
    b, s = 2, 32
    batch = m.make_batch("prefill", b, s)
    cache, logits_pf = m.prefill(params, batch)

    # teacher-forced forward over the same prompt
    fbatch = dict(batch)
    hidden = lm.family_hidden(params, fbatch, cfg, remat=False)
    logits_tf = lm.logits_last(params, hidden, cfg)
    if cfg.family == "encdec":
        # encdec prefill runs a BOS decode step, not directly comparable
        assert bool(jnp.all(jnp.isfinite(logits_pf)))
        return
    np.testing.assert_allclose(np.asarray(logits_pf, np.float32),
                               np.asarray(logits_tf, np.float32),
                               atol=2e-2, rtol=2e-2)

    # decode 1 token and compare with teacher forcing on prompt+token
    tok = jnp.argmax(logits_pf[:, -1:], axis=-1).astype(jnp.int32)
    logits_dec, _ = m.decode_step(params, cache, tok)
    batch2 = {**batch, "tokens": jnp.concatenate([batch["tokens"], tok], 1)}
    hidden2 = lm.family_hidden(params, batch2, cfg, remat=False)
    logits_tf2 = lm.logits_last(params, hidden2, cfg)
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(logits_tf2, np.float32),
                               atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("name", ARCHS)
def test_multi_step_decode_no_nan(name, models):
    m, params = models(name)
    batch = m.make_batch("prefill", 2, 32)
    cache, logits = m.prefill(params, batch)
    for _ in range(4):
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        logits, cache = m.decode_step(params, cache, tok)
        assert bool(jnp.all(jnp.isfinite(logits)))


def test_vocab_padding():
    cfg = get_config("internvl2-2b")
    assert cfg.vocab == 92553
    assert cfg.vocab_padded % 128 == 0 and cfg.vocab_padded >= cfg.vocab


def test_active_params_moe_less_than_total():
    m = build_model("deepseek-v2-236b")
    assert m.active_param_count() < 0.25 * m.param_count()


def test_full_param_counts_sane():
    """Full configs should be within 25% of the published sizes."""
    expect = {"olmo-1b": 1.2e9, "qwen2-72b": 72e9, "deepseek-v2-236b": 236e9,
              "granite-20b": 20e9, "internlm2-20b": 20e9, "olmoe-1b-7b": 7e9,
              "rwkv6-3b": 3e9, "zamba2-1.2b": 1.2e9}
    for name, n in expect.items():
        got = build_model(name).param_count()
        assert 0.7 * n < got < 1.35 * n, (name, got, n)


class TestChunkedWKV:
    """The §Perf chunked WKV reformulation must match the serial recurrence
    exactly (it is algebra, not approximation)."""

    def test_hidden_states_match_serial(self):
        from repro.configs.base import get_config
        from repro.models.api import Model
        cfg_s = get_config("rwkv6-3b", reduced=True).replace(wkv_impl="serial")
        cfg_c = cfg_s.replace(wkv_impl="chunked", wkv_chunk=8)
        m_s, m_c = Model(cfg_s), Model(cfg_c)
        params = m_s.init_params(jax.random.PRNGKey(0))
        batch = m_s.make_batch("train", 2, 64)
        h_s = lm.family_hidden(params, batch, cfg_s, remat=False)
        h_c = lm.family_hidden(params, batch, cfg_c, remat=False)
        np.testing.assert_allclose(np.asarray(h_s, np.float32),
                                   np.asarray(h_c, np.float32),
                                   atol=1e-4, rtol=1e-4)

    def test_grads_match_serial(self):
        from repro.configs.base import get_config
        from repro.models.api import Model
        cfg_s = get_config("rwkv6-3b", reduced=True).replace(wkv_impl="serial")
        cfg_c = cfg_s.replace(wkv_impl="chunked", wkv_chunk=8)
        m_s, m_c = Model(cfg_s), Model(cfg_c)
        params = m_s.init_params(jax.random.PRNGKey(0))
        batch = m_s.make_batch("train", 2, 64)
        g_s = jax.grad(lambda p: m_s.loss(p, batch))(params)
        g_c = jax.grad(lambda p: m_c.loss(p, batch))(params)
        for a, b in zip(jax.tree.leaves(g_s), jax.tree.leaves(g_c)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-4, rtol=1e-2)

    def test_odd_lengths(self):
        from repro.configs.base import get_config
        from repro.models.api import Model
        cfg_c = get_config("rwkv6-3b", reduced=True).replace(
            wkv_impl="chunked", wkv_chunk=8)
        m = Model(cfg_c)
        params = m.init_params(jax.random.PRNGKey(0))
        batch = m.make_batch("train", 2, 33)     # prime-ish length
        assert bool(jnp.isfinite(m.loss(params, batch)))


class TestChunkedSSD:
    """Chunked SSD (Mamba2 block decomposition) == serial recurrence."""

    def test_hidden_and_grads_match_serial(self):
        cfg_s = get_config("zamba2-1.2b", reduced=True).replace(
            ssm_impl="serial")
        cfg_c = cfg_s.replace(ssm_impl="chunked", ssd_chunk=8)
        m_s, m_c = Model(cfg_s), Model(cfg_c)
        params = m_s.init_params(jax.random.PRNGKey(0))
        batch = m_s.make_batch("train", 2, 64)
        h_s = lm.family_hidden(params, batch, cfg_s, remat=False)
        h_c = lm.family_hidden(params, batch, cfg_c, remat=False)
        np.testing.assert_allclose(np.asarray(h_s, np.float32),
                                   np.asarray(h_c, np.float32),
                                   atol=1e-4, rtol=1e-4)
        g_s = jax.grad(lambda p: m_s.loss(p, batch))(params)
        g_c = jax.grad(lambda p: m_c.loss(p, batch))(params)
        for a, b in zip(jax.tree.leaves(g_s), jax.tree.leaves(g_c)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4, rtol=1e-2)

    def test_decode_consistency_preserved(self):
        cfg_c = get_config("zamba2-1.2b", reduced=True).replace(
            ssm_impl="chunked", ssd_chunk=8)
        m = Model(cfg_c)
        params = m.init_params(jax.random.PRNGKey(0))
        batch = m.make_batch("prefill", 2, 32)
        cache, logits = m.prefill(params, batch)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        logits2, _ = m.decode_step(params, cache, tok)
        assert bool(jnp.all(jnp.isfinite(logits2)))
