"""Async data plane: mux envelope codecs, the event-loop server's
concurrency behavior (fairness, backpressure, admission control, idle
reaping), and the satellite pool-hygiene fixes on the threaded transport.

Byte-exactness and transport conformance for the mux transport live in
``tests/test_transport.py`` (the matrix runs every transport through the
same scenario); this file covers what is *new* with the event loop.
"""

import socket as socket_mod
import threading
import time

import pytest

from repro.core import cdc
from repro.core.cdmt import CDMTParams
from repro.core.errors import DeliveryError
from repro.core.registry import Registry
from repro.delivery import (AsyncRegistryServer, ImageClient, LocalTransport,
                            MuxSocketTransport, RegistryServer,
                            SocketRegistryServer, SocketTransport,
                            serve_registry_async, wire)

PARAMS = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)
P = CDMTParams(window=4, rule_bits=2)


def _rand(n, seed=0):
    import numpy as np
    return np.random.default_rng(seed).integers(
        0, 256, size=n, dtype=np.uint8).tobytes()


def _seeded_server(n_versions=3, seed=70, **server_kw):
    import numpy as np
    rng = np.random.default_rng(seed)
    data = bytearray(_rand(120_000, seed))
    reg = Registry(cdmt_params=P)
    pub = ImageClient(LocalTransport(reg), cdc_params=PARAMS, cdmt_params=P)
    versions = []
    for i in range(n_versions):
        versions.append(bytes(data))
        pub.commit("app", f"v{i}", bytes(data))
        pub.push("app", f"v{i}")
        pos = int(rng.integers(0, len(data) - 200))
        data[pos:pos + 128] = rng.bytes(128)
        ins = int(rng.integers(0, len(data)))
        data[ins:ins] = rng.bytes(64)
    return RegistryServer(reg, **server_kw), versions


# ----------------------------------------------------------------- codecs


class TestMuxCodecs:
    def test_request_roundtrip(self):
        frames = [b"alpha", b"", b"x" * 300]
        buf = wire.encode_mux_request(wire.Op.WANT, 7, "lin", "tag", frames)
        assert wire.decode_mux_request(buf) == (
            wire.Op.WANT, 7, "lin", "tag", frames)

    def test_request_stream_id_is_fixed_width(self):
        """Envelope size must not depend on the stream id value — that is
        what keeps plan quotes exact without knowing future ids."""
        a = wire.encode_mux_request(wire.Op.INDEX, 0, "l", "t")
        b = wire.encode_mux_request(wire.Op.INDEX, wire.MAX_STREAM_ID,
                                    "l", "t")
        assert len(a) == len(b)

    def test_request_stream_id_out_of_range(self):
        with pytest.raises(wire.WireError):
            wire.encode_mux_request(wire.Op.INDEX, wire.MAX_STREAM_ID + 1,
                                    "l", "t")

    def test_response_header_and_frame_roundtrip(self):
        hdr = wire.encode_mux_response_header(9, wire.STATUS_OK, 3)
        sid, status, n, off = wire.decode_mux_response_header(hdr)
        assert (sid, status, n, off) == (9, wire.STATUS_OK, 3, len(hdr))
        msg = wire.encode_mux_response_frame(9, b"payload")
        sid, frame, off = wire.decode_mux_response_frame(msg)
        assert (sid, frame, off) == (9, b"payload", len(msg))

    def test_header_frame_confusion_rejected(self):
        hdr = wire.encode_mux_response_header(1, wire.STATUS_OK, 0)
        with pytest.raises(wire.WireError):
            wire.decode_mux_response_frame(hdr)
        msg = wire.encode_mux_response_frame(1, b"x")
        with pytest.raises(wire.WireError):
            wire.decode_mux_response_header(msg)

    def test_bad_magic_and_version_rejected(self):
        with pytest.raises(wire.WireError):
            wire.check_mux_request_header(b"XX\x01\x01\x00\x00\x00\x01")
        with pytest.raises(wire.WireError):
            wire.check_mux_response_header(b"CS\x63\x00\x00\x00\x00\x01")

    def test_sizing_identities_match_encoders(self):
        frames = [b"a" * 5, b"b" * 1000]
        req = wire.encode_mux_request(wire.Op.PUSH, 3, "lin", "t2", frames)
        assert len(req) == wire.mux_request_envelope_bytes(
            "lin", "t2", [len(f) for f in frames])
        lens = [17, 0, 4096]
        measured = len(wire.encode_mux_response_header(5, wire.STATUS_OK,
                                                       len(lens)))
        for n in lens:
            measured += len(wire.encode_mux_response_frame(5, b"z" * n))
        assert measured == wire.mux_response_envelope_bytes(lens)

    def test_busy_error_code_roundtrip(self):
        frame = wire.encode_error(wire.ErrorCode.BUSY, "overloaded")
        assert wire.decode_error(frame) == (wire.ErrorCode.BUSY,
                                            "overloaded")


# ----------------------------------------------------------------- server


@pytest.fixture()
def aio_env():
    srv, versions = _seeded_server()
    asrv = AsyncRegistryServer(srv)
    transports = []

    def connect(**kw):
        t = MuxSocketTransport(asrv.address, **kw)
        transports.append(t)
        return t

    yield srv, asrv, versions, connect
    for t in transports:
        t.close()
    asrv.stop()


class TestAsyncServer:
    def test_pull_and_materialize(self, aio_env):
        srv, asrv, versions, connect = aio_env
        cl = ImageClient(connect(), cdc_params=PARAMS, cdmt_params=P)
        rep = cl.pull("app", "v2")
        assert cl.materialize("app", "v2") == versions[2]
        assert rep.transport == "mux"
        assert rep.chunks_moved == rep.chunks_total

    def test_o_cores_threads_regardless_of_clients(self, aio_env):
        """The scale claim: thread count is fixed at construction — loop +
        worker pool — and does not grow with connections."""
        srv, asrv, versions, connect = aio_env
        assert asrv.thread_count == 1 + asrv.workers
        before = threading.active_count()
        clients = [ImageClient(connect(), cdc_params=PARAMS, cdmt_params=P)
                   for _ in range(8)]
        for cl in clients:
            cl.pull("app", "v1")
        # each transport adds its own reader threads and the server's lazy
        # worker pool fills up to its fixed cap — nothing grows per client
        grown = threading.active_count() - before
        assert grown <= asrv.workers + sum(
            len(cl.transport._conns) for cl in clients)

    def test_many_concurrent_pullers_one_connection_each(self, aio_env):
        srv, asrv, versions, connect = aio_env
        errors = []

        def puller(i):
            try:
                cl = ImageClient(connect(connections=1),
                                 cdc_params=PARAMS, cdmt_params=P)
                cl.pull("app", f"v{i % 3}")
                assert cl.materialize("app", f"v{i % 3}") == versions[i % 3]
            except Exception as e:          # noqa: BLE001 — collected
                errors.append(e)

        threads = [threading.Thread(target=puller, args=(i,))
                   for i in range(16)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errors

    def test_concurrent_streams_share_one_transport(self, aio_env):
        """Many threads multiplex over one shared transport's few
        connections — the per-stream demux must never cross wires."""
        srv, asrv, versions, connect = aio_env
        transport = connect(connections=2)
        errors = []

        def worker(i):
            try:
                idx, _ = transport.get_index("app", f"v{i % 3}")
                recipe, _ = transport.get_recipe("app", f"v{i % 3}")
                assert len(idx.leaf_fps()) == len(recipe.fps)
            except Exception as e:          # noqa: BLE001 — collected
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(24)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errors
        assert len(transport._conns) <= 2

    def test_admission_control_sheds_with_busy(self):
        """Past ``max_inflight`` the server answers BUSY instead of
        queueing — typed, immediate, and counted."""
        srv, _versions = _seeded_server()
        # workers=1 + a stalled handler ⇒ the next requests stay in flight
        asrv = AsyncRegistryServer(srv, workers=1, max_inflight=1)
        gate = threading.Event()
        real = srv.get_index

        def slow_get_index(lineage, tag):
            gate.wait(timeout=30)
            return real(lineage, tag)

        srv.get_index = slow_get_index
        t = MuxSocketTransport(asrv.address)
        try:
            blocker = threading.Thread(
                target=lambda: t.get_index("app", "v0"), daemon=True)
            blocker.start()
            deadline = time.monotonic() + 10
            while (srv.metrics.snapshot().value(
                    "async_inflight_requests", {}) < 1
                    and time.monotonic() < deadline):
                time.sleep(0.01)             # wait for admission
            with pytest.raises(DeliveryError, match="busy"):
                t.get_index("app", "v1")     # overlaps the blocker → BUSY
            gate.set()
            blocker.join(timeout=30)
            assert asrv.stats.sheds >= 1
            snap = srv.metrics.snapshot()
            assert snap.value("async_shed_total", {}) >= 1
        finally:
            gate.set()
            t.close()
            asrv.stop()

    def test_idle_reap_and_transparent_redial(self):
        """The server reaps a connection idle between requests; the shared
        mux connection redials on next use instead of failing the call."""
        srv, _versions = _seeded_server()
        asrv = AsyncRegistryServer(srv, idle_timeout=0.2)
        t = MuxSocketTransport(asrv.address, connections=1)
        try:
            t.get_index("app", "v0")
            deadline = time.monotonic() + 10
            while (srv.metrics.snapshot().value(
                    "async_idle_reaped_total", {}) < 1
                    and time.monotonic() < deadline):
                time.sleep(0.05)
            assert srv.metrics.snapshot().value(
                "async_idle_reaped_total", {}) >= 1
            # the reaped socket is still in the transport; next call must
            # succeed anyway (stale-stream retry on a fresh connection)
            idx, _ = t.get_index("app", "v1")
            assert len(idx.leaf_fps()) > 0
        finally:
            t.close()
            asrv.stop()

    def test_mux_error_maps_to_typed_exception(self, aio_env):
        srv, asrv, versions, connect = aio_env
        t = connect()
        with pytest.raises(DeliveryError):
            t.get_index("app", "no-such-tag")
        # the connection survives a typed error (no close, no redial)
        idx, _ = t.get_index("app", "v0")
        assert len(idx.leaf_fps()) > 0
        assert asrv.stats.errors >= 1

    def test_plain_envelope_client_is_rejected(self, aio_env):
        """The async server speaks only the mux protocol; a plain-envelope
        ("CQ") client must be dropped, not answered garbage."""
        srv, asrv, versions, connect = aio_env
        s = socket_mod.create_connection(asrv.address)
        try:
            s.sendall(wire.encode_request(wire.Op.INDEX, "app", "v0"))
            s.settimeout(10)
            assert s.recv(100) == b""        # server closed on bad magic
        finally:
            s.close()

    def test_stop_is_idempotent_and_releases_port(self):
        srv, _versions = _seeded_server()
        asrv = AsyncRegistryServer(srv)
        addr = asrv.address
        asrv.stop()
        asrv.stop()                          # second stop is a no-op
        with pytest.raises(DeliveryError):
            MuxSocketTransport(addr, timeout=0.5)

    def test_scrape_metrics_over_mux(self, aio_env):
        srv, asrv, versions, connect = aio_env
        t = connect()
        cl = ImageClient(t, cdc_params=PARAMS, cdmt_params=P)
        cl.pull("app", "v2")
        scraped = t.scrape_metrics()
        local = srv.metrics.snapshot()
        assert scraped.value("registry_requests_total", {"op": "want"}) \
            == local.value("registry_requests_total", {"op": "want"})
        assert scraped.value("async_requests_total", {}) >= 1
        assert scraped.value("async_open_connections", {}) >= 1


class TestFairness:
    def test_small_pulls_not_starved_by_large_pull(self):
        """One huge WANT stream must not starve many small pulls: handler
        work is scheduled per CHUNK_BATCH, so small streams interleave.
        Scaled-down fairness gate: every small pull (a few chunks) must
        finish while the large stream (hundreds of chunks, small server
        split ⇒ hundreds of scheduling points) is still running, and their
        p99 stays bounded."""
        import numpy as np
        rng = np.random.default_rng(73)
        reg = Registry(cdmt_params=P)
        pub = ImageClient(LocalTransport(reg), cdc_params=PARAMS,
                          cdmt_params=P)
        pub.commit("big", "v0", _rand(600_000, seed=74))
        pub.push("big", "v0")
        pub.commit("small", "v0", _rand(4_000, seed=75))
        pub.push("small", "v0")
        srv = RegistryServer(reg, max_batch_chunks=4)
        # pace the big stream like a store with per-batch latency, so the
        # interleaving window is real on localhost (~190 batches ⇒ ≥ 1s)
        real_want_plan = srv.want_plan

        def paced_want_plan(want_frame):
            n, frames = real_want_plan(want_frame)

            def paced():
                for f in frames:
                    time.sleep(0.005)
                    yield f

            return n, paced()

        srv.want_plan = paced_want_plan
        asrv = AsyncRegistryServer(srv, workers=2)
        transport = MuxSocketTransport(asrv.address, connections=2)
        try:
            big_done = threading.Event()
            lat = []
            errors = []

            def big_pull():
                cl = ImageClient(MuxSocketTransport(asrv.address),
                                 cdc_params=PARAMS, cdmt_params=P)
                try:
                    cl.pull("big", "v0")
                except Exception as e:      # noqa: BLE001 — collected
                    errors.append(e)
                finally:
                    big_done.set()
                    cl.transport.close()

            def small_pull():
                try:
                    t0 = time.perf_counter()
                    idx, _ = transport.get_index("small", "v0")
                    recipe, _ = transport.get_recipe("small", "v0")
                    res = transport.fetch_chunks("small", "v0", recipe.fps)
                    lat.append(time.perf_counter() - t0)
                    assert len(res.chunks) == len(set(recipe.fps))
                    assert not big_done.is_set(), \
                        "small pull outlived the large pull"
                except Exception as e:      # noqa: BLE001 — collected
                    errors.append(e)

            big = threading.Thread(target=big_pull)
            big.start()
            time.sleep(0.05)                 # let the big stream get going
            smalls = [threading.Thread(target=small_pull)
                      for _ in range(12)]
            for t in smalls:
                t.start()
            for t in smalls:
                t.join(timeout=60)
            big.join(timeout=60)
            assert not errors
            assert len(lat) == 12
            # generous absolute bound: each small pull is 3 tiny
            # exchanges; starvation behind a ~200-batch stream would blow
            # straight past this
            assert sorted(lat)[-1] < 5.0
        finally:
            transport.close()
            asrv.stop()


# ------------------------------------------------- threaded-server satellites


class TestThreadedIdleReap:
    def test_server_reaps_idle_connection(self):
        srv, _versions = _seeded_server()
        sock_srv = SocketRegistryServer(srv, idle_timeout=0.2)
        try:
            t = SocketTransport(sock_srv.address)
            t.get_index("app", "v0")
            deadline = time.monotonic() + 10
            while (srv.metrics.snapshot().value(
                    "socket_idle_reaped_total", {}) < 1
                    and time.monotonic() < deadline):
                time.sleep(0.05)
            assert srv.metrics.snapshot().value(
                "socket_idle_reaped_total", {}) >= 1
            # graceful eviction: the pooled socket was reaped server-side,
            # yet the next exchange succeeds via the stale-conn retry
            idx, _ = t.get_index("app", "v1")
            assert len(idx.leaf_fps()) > 0
            t.close()
        finally:
            sock_srv.stop()

    def test_no_reaping_by_default(self):
        """``idle_timeout=None`` preserves the historical contract: a
        pooled connection may idle past any io_timeout and still serve."""
        srv, _versions = _seeded_server()
        sock_srv = SocketRegistryServer(srv, io_timeout=0.3)
        try:
            t = SocketTransport(sock_srv.address)
            t.get_index("app", "v0")
            time.sleep(0.6)                  # > io_timeout, idle is exempt
            idx, _ = t.get_index("app", "v1")
            assert len(idx.leaf_fps()) > 0
            assert srv.metrics.snapshot().value(
                "socket_idle_reaped_total", {}) == 0
            t.close()
        finally:
            sock_srv.stop()


class TestPoolHygiene:
    def test_pool_bounded_and_gauged(self):
        srv, _versions = _seeded_server()
        sock_srv = SocketRegistryServer(srv)
        try:
            t = SocketTransport(sock_srv.address, pool_size=2)
            conns = [t._checkout() for _ in range(5)]
            for c in conns:
                t._checkin(c)
            assert len(t._pool) == 2         # excess checkins closed
            assert t.metrics.snapshot().value(
                "transport_pool_connections",
                {"transport": "socket"}) == 2
            t.close()
            assert t.metrics.snapshot().value(
                "transport_pool_connections",
                {"transport": "socket"}) == 0
        finally:
            sock_srv.stop()

    def test_ttl_expired_connection_not_reused(self):
        srv, _versions = _seeded_server()
        sock_srv = SocketRegistryServer(srv)
        try:
            t = SocketTransport(sock_srv.address, pool_ttl=0.05)
            t.get_index("app", "v0")
            assert len(t._pool) == 1
            expired = t._pool[0]
            time.sleep(0.1)
            idx, _ = t.get_index("app", "v1")   # dials fresh, works
            assert len(idx.leaf_fps()) > 0
            assert expired.sock.fileno() == -1  # TTL victim was closed
            t.close()
        finally:
            sock_srv.stop()

    def test_restarted_server_does_not_fail_pooled_client(self):
        """Server restart while a client connection sits in the pool: the
        first reuse must redial, not surface DeliveryError."""
        srv, _versions = _seeded_server()
        sock_srv = SocketRegistryServer(srv)
        t = SocketTransport(sock_srv.address)
        try:
            t.get_index("app", "v0")
            assert len(t._pool) == 1
            host, port = sock_srv.address
            sock_srv.stop()                  # pooled conn is now dead
            sock_srv = SocketRegistryServer(srv, host=host, port=port)
            idx, _ = t.get_index("app", "v1")
            assert len(idx.leaf_fps()) > 0
        finally:
            t.close()
            sock_srv.stop()

    def test_fresh_connection_failure_still_raises(self):
        """The retry is for *reused* connections only — a first-dial
        failure surfaces immediately."""
        srv, _versions = _seeded_server()
        sock_srv = SocketRegistryServer(srv)
        t = SocketTransport(sock_srv.address)
        addr = sock_srv.address
        sock_srv.stop()
        with pytest.raises(DeliveryError):
            t.get_index("app", "v0")
        t.close()

    def test_serve_registry_async_convenience(self):
        reg = Registry(cdmt_params=P)
        pub = ImageClient(LocalTransport(reg), cdc_params=PARAMS,
                          cdmt_params=P)
        pub.commit("app", "v0", _rand(50_000, seed=80))
        pub.push("app", "v0")
        asrv = serve_registry_async(reg)
        try:
            t = MuxSocketTransport(asrv.address)
            cl = ImageClient(t, cdc_params=PARAMS, cdmt_params=P)
            cl.pull("app", "v0")
            assert cl.materialize("app", "v0") is not None
            t.close()
        finally:
            asrv.stop()
