"""Chunk GC: ``Registry.sweep`` mark-and-sweep over recipes with pinned-tag
retention, and the crash-safe ``ChunkStore.compact`` log compaction under it.
"""

import os

import numpy as np
import pytest

from repro.core import cdc, hashing
from repro.core.cdmt import CDMTParams
from repro.core.errors import DeliveryError
from repro.core.pushpull import Client
from repro.core.registry import Registry
from repro.core.store import ChunkStore

PARAMS = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)
P = CDMTParams(window=4, rule_bits=2)


def _rand(n, seed=0):
    return np.random.default_rng(seed).integers(0, 256, size=n,
                                                dtype=np.uint8).tobytes()


def _versions(n_versions=4, size=120_000, seed=0):
    rng = np.random.default_rng(seed)
    data = bytearray(_rand(size, seed))
    out = [bytes(data)]
    for _ in range(n_versions - 1):
        for _ in range(3):
            pos = rng.integers(0, len(data) - 100)
            data[pos:pos + 64] = rng.bytes(64)
        ins = rng.integers(0, len(data))
        data[ins:ins] = rng.bytes(rng.integers(1, 256))
        out.append(bytes(data))
    return out


def _loaded_registry(directory=None, n_versions=4, seed=60, lineage="app"):
    reg = Registry(directory=directory, cdmt_params=P)
    cl = Client(cdc_params=PARAMS, cdmt_params=P)
    versions = _versions(n_versions, seed=seed)
    for i, v in enumerate(versions):
        cl.commit(lineage, f"v{i}", v)
        cl.push(reg, lineage, f"v{i}")
    return reg, versions


class TestSweepReportOnly:
    def test_orphan_chunks_are_flagged_not_dropped(self):
        reg, _ = _loaded_registry()
        junk = _rand(5_000, seed=61)
        reg.store.chunks.put(hashing.chunk_fingerprint(junk), junk)
        rep = reg.sweep()                       # retain everything
        assert rep.unreferenced_chunks == 1
        assert rep.unreferenced_bytes == 5_000
        assert rep.dropped_chunks == 0          # report-only
        assert reg.store.chunks.has(hashing.chunk_fingerprint(junk))
        assert rep.live_chunks == reg.store.chunks.n_chunks() - 1

    def test_clean_registry_has_no_garbage(self):
        reg, _ = _loaded_registry()
        rep = reg.sweep()
        assert rep.unreferenced_chunks == 0
        assert rep.live_bytes == reg.store.chunks.stored_bytes()

    def test_narrowed_retention_is_reported_before_drop(self):
        reg, _ = _loaded_registry()
        rep = reg.sweep(retain_tags={"app": ["v3"]})
        assert rep.dropped_versions == 3
        assert rep.unreferenced_chunks > 0      # v0–v2-only chunks
        assert reg.tags("app") == ["v0", "v1", "v2", "v3"]  # untouched

    def test_one_shot_iterator_pins_are_honored(self):
        """A generator as a retain_tags value must pin exactly like a list —
        validation must not consume it and leave the sweep reading an empty
        set (which would drop the pinned versions themselves)."""
        reg, versions = _loaded_registry()
        rep = reg.sweep(retain_tags={"app": iter(["v2", "v3"])}, drop=True)
        assert rep.dropped_versions == 2
        assert reg.tags("app") == ["v2", "v3"]
        fresh = Client(cdc_params=PARAMS, cdmt_params=P)
        fresh.pull(reg, "app", "v3")
        assert fresh.materialize("app", "v3") == versions[3]

    def test_unknown_pins_rejected(self):
        reg, _ = _loaded_registry()
        with pytest.raises(ValueError):
            reg.sweep(retain_tags={"ghost": ["v0"]})
        with pytest.raises(ValueError):
            reg.sweep(retain_tags={"app": ["v99"]})


class TestSweepDrop:
    def test_pinned_tags_survive_dropped_tags_vanish(self):
        reg, versions = _loaded_registry()
        before = reg.store.chunks.stored_bytes()
        rep = reg.sweep(retain_tags={"app": ["v2", "v3"]}, drop=True)
        assert rep.dropped_versions == 2
        assert rep.dropped_chunks > 0
        assert rep.reclaimed_bytes > 0
        assert reg.store.chunks.stored_bytes() == before - rep.reclaimed_bytes
        assert reg.tags("app") == ["v2", "v3"]
        for i in (2, 3):
            fresh = Client(cdc_params=PARAMS, cdmt_params=P)
            fresh.pull(reg, "app", f"v{i}")
            assert fresh.materialize("app", f"v{i}") == versions[i]
        with pytest.raises(DeliveryError):
            reg.index_for_tag("app", "v0")
        with pytest.raises(DeliveryError):
            reg.recipe_for("app", "v0")

    def test_other_lineages_retain_everything(self):
        reg, versions_a = _loaded_registry(lineage="a", seed=62)
        cl = Client(cdc_params=PARAMS, cdmt_params=P)
        data_b = _rand(80_000, seed=63)
        cl.commit("b", "v0", data_b)
        cl.push(reg, "b", "v0")
        reg.sweep(retain_tags={"a": ["v3"]}, drop=True)
        assert reg.tags("a") == ["v3"]
        assert reg.tags("b") == ["v0"]          # absent from mapping: kept
        fresh = Client(cdc_params=PARAMS, cdmt_params=P)
        fresh.pull(reg, "b", "v0")
        assert fresh.materialize("b", "v0") == data_b

    def test_retaining_no_tags_removes_lineage(self):
        reg, _ = _loaded_registry()
        reg.sweep(retain_tags={"app": []}, drop=True)
        assert reg.tags("app") == []
        assert "app" not in reg.lineages
        assert reg.store.chunks.n_chunks() == 0

    def test_push_after_sweep_works(self):
        reg, versions = _loaded_registry()
        reg.sweep(retain_tags={"app": ["v3"]}, drop=True)
        cl = Client(cdc_params=PARAMS, cdmt_params=P)
        cl.pull(reg, "app", "v3")
        new = versions[3] + _rand(3_000, seed=64)
        cl.commit("app", "v4", new)
        cl.push(reg, "app", "v4")
        assert reg.tags("app") == ["v3", "v4"]
        fresh = Client(cdc_params=PARAMS, cdmt_params=P)
        fresh.pull(reg, "app", "v4")
        assert fresh.materialize("app", "v4") == new


class TestSweepDurable:
    def test_sweep_survives_restart(self, tmp_path):
        d = str(tmp_path)
        reg, versions = _loaded_registry(directory=d)
        rep = reg.sweep(retain_tags={"app": ["v3"]}, drop=True)
        assert rep.reclaimed_bytes > 0
        reg.close()
        reg2 = Registry(directory=d, cdmt_params=P)
        try:
            assert reg2.tags("app") == ["v3"]
            fresh = Client(cdc_params=PARAMS, cdmt_params=P)
            fresh.pull(reg2, "app", "v3")
            assert fresh.materialize("app", "v3") == versions[3]
            # replayed state references no dropped chunk
            assert reg2.sweep().unreferenced_chunks == 0
        finally:
            reg2.close()

    def test_journal_compacted_before_chunks_drop(self, tmp_path):
        """Journal-safety ordering: after a drop-sweep, the on-disk journal
        must not reference the dropped versions at all (a crash right after
        the sweep must not resurrect them on replay)."""
        d = str(tmp_path)
        reg, _ = _loaded_registry(directory=d)
        journal_before = reg.journal_size_bytes()
        reg.sweep(retain_tags={"app": ["v3"]}, drop=True)
        assert reg.journal_size_bytes() < journal_before  # reset to snapshot
        reg.close()
        reg2 = Registry(directory=d, cdmt_params=P)
        try:
            assert set(reg2.recipes) == {("app", "v3")}
        finally:
            reg2.close()


class TestChunkStoreCompact:
    def _filled(self, directory, n=6, size=10_000):
        store = ChunkStore(directory)
        fps = []
        for i in range(n):
            data = _rand(size, seed=100 + i)
            fp = hashing.chunk_fingerprint(data)
            store.put(fp, data)
            fps.append(fp)
        return store, fps

    def test_memory_compact(self):
        store, fps = self._filled(None)
        dropped, reclaimed = store.compact(set(fps[:2]))
        assert (dropped, reclaimed) == (4, 40_000)
        assert store.n_chunks() == 2
        assert store.get(fps[0]) is not None

    def test_directory_compact_and_reopen(self, tmp_path):
        d = str(tmp_path)
        store, fps = self._filled(d)
        keep = set(fps[::2])
        dropped, reclaimed = store.compact(keep)
        assert dropped == 3 and reclaimed == 30_000
        for fp in keep:
            assert hashing.chunk_fingerprint(store.get(fp)) == fp
        store.close()
        assert os.path.getsize(os.path.join(d, "chunks.log")) == 30_000
        re = ChunkStore(d)
        assert set(re.fingerprints()) == keep
        for fp in keep:
            assert hashing.chunk_fingerprint(re.get(fp)) == fp
        re.close()

    def test_compact_noop_when_all_live(self, tmp_path):
        store, fps = self._filled(str(tmp_path))
        assert store.compact(set(fps)) == (0, 0)
        store.close()

    def test_uncommitted_compaction_discarded(self, tmp_path):
        """``.new`` files with no intent flag = crash before commit: the old
        generation stays authoritative."""
        d = str(tmp_path)
        store, fps = self._filled(d)
        store.close()
        with open(os.path.join(d, "chunks.log.new"), "wb") as f:
            f.write(b"half-written garbage")
        re = ChunkStore(d)
        assert set(re.fingerprints()) == set(fps)
        assert not os.path.exists(os.path.join(d, "chunks.log.new"))
        re.close()

    def test_committed_compaction_completed_on_reopen(self, tmp_path):
        """Intent flag present = crash after commit: recovery must finish
        the swap, even when only one of the two files was renamed."""
        d = str(tmp_path)
        store, fps = self._filled(d)
        keep = set(fps[:3])
        # build the compacted generation by hand (what compact() writes)
        import struct
        from repro.core.hashing import DIGEST_SIZE
        off = 0
        with open(os.path.join(d, "chunks.log.new"), "wb") as lf, \
                open(os.path.join(d, "chunks.idx.new"), "wb") as xf:
            for fp in fps[:3]:
                data = store.get(fp)
                lf.write(data)
                xf.write(fp + struct.pack("<QQ", off, len(data)))
                off += len(data)
        store.close()
        # simulate: log already swapped, idx not yet, flag durable
        os.replace(os.path.join(d, "chunks.log.new"),
                   os.path.join(d, "chunks.log"))
        with open(os.path.join(d, "chunks.compacting"), "wb") as f:
            f.write(b"compact")
        re = ChunkStore(d)
        assert set(re.fingerprints()) == keep
        for fp in keep:
            assert hashing.chunk_fingerprint(re.get(fp)) == fp
        assert not os.path.exists(os.path.join(d, "chunks.compacting"))
        re.close()

    def test_put_get_after_compact(self, tmp_path):
        store, fps = self._filled(str(tmp_path))
        store.compact(set(fps[:1]))
        data = _rand(4_000, seed=200)
        fp = hashing.chunk_fingerprint(data)
        assert store.put(fp, data)
        assert store.get(fp) == data
        store.sync()
        store.close()
        re = ChunkStore(str(tmp_path))
        assert re.get(fp) == data
        assert re.get(fps[0]) is not None
        re.close()
