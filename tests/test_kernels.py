"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cdc
from repro.kernels import ops, ref
from repro.kernels.gear_cdc import BLOCK


def _bytes(n, seed=0):
    return np.random.default_rng(seed).integers(0, 256, size=n, dtype=np.uint8)


class TestGearCDC:
    @pytest.mark.parametrize("n", [1, 100, BLOCK - 1, BLOCK, BLOCK + 1,
                                   2 * BLOCK + 777, 3 * BLOCK])
    def test_matches_ref(self, n):
        data = jnp.asarray(_bytes(n, seed=n))
        out_ref = np.asarray(ref.gear_hash_ref(data))
        out_pl = np.asarray(ops.gear_hash(data, impl="interpret"))
        np.testing.assert_array_equal(out_pl, out_ref)

    def test_matches_host_numpy(self):
        raw = _bytes(50_000, seed=1)
        h_np = cdc.gear_hash_stream(raw)
        h_ref = np.asarray(ref.gear_hash_ref(jnp.asarray(raw)))
        np.testing.assert_array_equal(h_np, h_ref)

    def test_boundary_mask_roundtrip(self):
        """Device boundary scan + host min/max pass == pure host CDC."""
        params = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)
        raw = _bytes(80_000, seed=2).tobytes()
        assert ops.chunk_boundaries_accelerated(raw, params, impl="interpret") \
            == cdc.chunk_boundaries(raw, params)

    def test_blockwise_halo_correct(self):
        """Hashes at block boundaries depend on the previous block's tail —
        the halo operand must carry it."""
        data = jnp.asarray(_bytes(2 * BLOCK, seed=3))
        full = np.asarray(ops.gear_hash(data, impl="interpret"))
        reference = np.asarray(ref.gear_hash_ref(data))
        np.testing.assert_array_equal(full[BLOCK - 2: BLOCK + 2],
                                      reference[BLOCK - 2: BLOCK + 2])


class TestChunkFingerprint:
    @pytest.mark.parametrize("n_pages,page", [(1, 256), (7, 512), (256, 256),
                                              (300, 1024), (513, 128)])
    def test_matches_ref(self, n_pages, page):
        pages = jnp.asarray(_bytes(n_pages * page, seed=n_pages).reshape(n_pages, page))
        np.testing.assert_array_equal(
            np.asarray(ops.page_fingerprints(pages, impl="interpret")),
            np.asarray(ref.page_fingerprint_ref(pages)))

    def test_distinct_pages_distinct_fps(self):
        pages = jnp.asarray(_bytes(64 * 256, seed=5).reshape(64, 256))
        fps = np.asarray(ops.page_fingerprints(pages, impl="ref"))
        assert len({tuple(r) for r in fps}) == 64


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,kvh,s,d,dtype", [
        (1, 4, 4, 128, 64, jnp.float32),
        (2, 4, 2, 256, 64, jnp.float32),      # GQA
        (2, 8, 1, 256, 128, jnp.float32),     # MQA
        (1, 4, 4, 384, 64, jnp.bfloat16),     # non-tile-multiple S
        (1, 2, 2, 512, 32, jnp.float32),
    ])
    def test_matches_ref(self, b, h, kvh, s, d, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, h, s, d), dtype)
        k = jax.random.normal(ks[1], (b, kvh, s, d), dtype)
        v = jax.random.normal(ks[2], (b, kvh, s, d), dtype)
        o_ref = ops.flash_attention(q, k, v, impl="ref")
        o_pl = ops.flash_attention(q, k, v, impl="interpret")
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(o_pl, np.float32),
                                   np.asarray(o_ref, np.float32),
                                   atol=tol, rtol=tol)

    def test_non_causal(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 2, 256, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(ops.flash_attention(q, k, v, causal=False, impl="interpret")),
            np.asarray(ops.flash_attention(q, k, v, causal=False, impl="ref")),
            atol=2e-5, rtol=2e-5)


class TestBlockwiseJnpAttention:
    """The scan-based in-model attention must agree with naive attention."""

    @pytest.mark.parametrize("s,bq,bkv", [(256, 64, 64), (512, 128, 256)])
    def test_matches_naive(self, s, bq, bkv):
        from repro.models import layers as L
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (2, s, 4, 32), jnp.float32)
        k = jax.random.normal(ks[1], (2, s, 4, 32), jnp.float32)
        v = jax.random.normal(ks[2], (2, s, 4, 32), jnp.float32)
        out_b = L.blockwise_attention(q, k, v, causal=True, block_q=bq,
                                      block_kv=bkv)
        out_n = L.naive_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_n),
                                   atol=2e-5, rtol=2e-5)

    def test_mla_shaped_dv_neq_dq(self):
        from repro.models import layers as L
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (1, 256, 4, 48), jnp.float32)
        k = jax.random.normal(ks[1], (1, 256, 4, 48), jnp.float32)
        v = jax.random.normal(ks[2], (1, 256, 4, 32), jnp.float32)   # dv≠dq
        out_b = L.blockwise_attention(q, k, v, causal=True, block_q=64,
                                      block_kv=64)
        out_n = L.naive_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_n),
                                   atol=2e-5, rtol=2e-5)
