"""Incremental CDMT maintenance: build_incremental must be bit-identical to
a full Algorithm-1 build while hashing only O(changed subtrees), the
verified push path must reuse it end-to-end, and tag bindings must be
immutable (same-root re-push idempotent, different-root rejected)."""

import random

import pytest

from repro.core import hashing
from repro.core.cdmt import (BuildStats, CDMT, CDMTParams, OverlayNodeStore)
from repro.core.registry import PushRejected, Registry
from repro.core.store import Recipe
from repro.core.versioning import VersionedCDMT

P = CDMTParams(window=4, rule_bits=2, max_fanout=16)


def _fps(rng, n):
    return [hashing.chunk_fingerprint(str(rng.random()).encode())
            for _ in range(n)]


def _assert_identical(a: CDMT, b: CDMT):
    assert a.root == b.root
    assert a.levels == b.levels
    assert set(a.nodes) == set(b.nodes)


class TestEquivalence:
    """build_incremental(parent, leaves) == build(leaves), always."""

    def _edit(self, rng, base, op):
        edited = list(base)
        if op == "replace":
            for _ in range(rng.randint(1, 5)):
                edited[rng.randrange(len(edited))] = _fps(rng, 1)[0]
        elif op == "insert":
            i = rng.randint(0, len(edited))
            edited[i:i] = _fps(rng, rng.randint(1, 8))
        elif op == "delete" and len(edited) > 1:
            i = rng.randrange(len(edited))
            del edited[i:i + rng.randint(1, min(8, len(edited) - i))]
        elif op == "prepend":
            edited = _fps(rng, rng.randint(1, 8)) + edited
        elif op == "append":
            edited = edited + _fps(rng, rng.randint(1, 8))
        elif op == "truncate":
            edited = edited[:rng.randint(1, len(edited))]
        elif op == "scatter":
            for i in rng.sample(range(len(edited)), min(10, len(edited))):
                edited[i] = _fps(rng, 1)[0]
        elif op == "swap-all":
            edited = _fps(rng, len(edited))
        elif op == "dup":
            edited = edited + edited[:rng.randint(1, len(edited))]
        return edited

    @pytest.mark.parametrize("op", ["replace", "insert", "delete", "prepend",
                                    "append", "truncate", "scatter",
                                    "swap-all", "dup", "same"])
    def test_randomized_edits_match_full_build(self, op):
        rng = random.Random(hash(op) & 0xFFFF)
        for trial in range(25):
            n = rng.randint(1, 300)
            base = _fps(rng, n)
            parent = CDMT.build(base, params=P)
            edited = base if op == "same" else self._edit(rng, base, op)
            full = CDMT.build(edited, params=P)
            incr = CDMT.build_incremental(parent, edited)
            _assert_identical(incr, full)

    def test_default_params_and_shared_store_chain(self):
        """20 chained versions through one node store (the lineage pattern)."""
        rng = random.Random(7)
        store = {}
        cur = _fps(rng, 2000)
        prev = CDMT.build(cur, params=P, node_store=store)
        for _ in range(20):
            for i in rng.sample(range(len(cur)), 5):
                cur[i] = _fps(rng, 1)[0]
            overlay = OverlayNodeStore(store)
            tree = CDMT.build_incremental(prev, cur, node_store=overlay)
            _assert_identical(tree, CDMT.build(cur, params=P))
            store.update(overlay.overlay)
            prev = tree

    def test_fallbacks(self):
        rng = random.Random(3)
        base = _fps(rng, 50)
        parent = CDMT.build(base, params=P)
        # empty new leaves -> empty tree
        assert CDMT.build_incremental(parent, []).root is None
        # empty parent -> full build
        t = CDMT.build_incremental(CDMT(params=P), base, params=P)
        _assert_identical(t, parent)
        # differing params -> full build under the requested params
        q = CDMTParams(window=8, rule_bits=1)
        t = CDMT.build_incremental(parent, base, params=q)
        _assert_identical(t, CDMT.build(base, params=q))


class TestIncrementalCost:
    def test_hash_calls_scale_with_change_not_size(self):
        """Acceptance: k=10 of n=10k leaves -> ≥5× fewer blake2b calls than
        a full rebuild, and O(k · depth · fanout) nodes created."""
        rng = random.Random(0)
        store = {}
        base = _fps(rng, 10_000)
        parent = CDMT.build(base, params=CDMTParams(), node_store=store)
        edited = list(base)
        for i in rng.sample(range(len(base)), 10):
            edited[i] = _fps(rng, 1)[0]
        st_full, st_incr = BuildStats(), BuildStats()
        full = CDMT.build(edited, params=CDMTParams(), stats=st_full)
        overlay = OverlayNodeStore(store)
        incr = CDMT.build_incremental(parent, edited, node_store=overlay,
                                      stats=st_incr)
        _assert_identical(incr, full)
        assert st_incr.hash_calls * 5 <= st_full.hash_calls, (
            st_incr.hash_calls, st_full.hash_calls)
        # 10 changed leaves + their ancestor spans: far fewer than n
        assert st_incr.nodes_created <= 10 * incr.height() * 64
        assert st_incr.nodes_created < 0.05 * len(store)

    def test_overlay_leaves_base_untouched(self):
        rng = random.Random(1)
        store = {}
        base = _fps(rng, 1000)
        parent = CDMT.build(base, params=P, node_store=store)
        before = len(store)
        edited = list(base)
        edited[500] = _fps(rng, 1)[0]
        overlay = OverlayNodeStore(store)
        CDMT.build_incremental(parent, edited, node_store=overlay)
        assert len(store) == before
        assert 0 < len(overlay.overlay) < 50


class TestVersionedCommit:
    def test_commit_uses_incremental_build(self):
        rng = random.Random(2)
        v = VersionedCDMT(P)
        fps = _fps(rng, 5000)
        v.commit(fps, tag="v0")
        edited = list(fps)
        edited[2500] = _fps(rng, 1)[0]
        tree, overlay, stats = v.build_next(edited)
        assert tree.root == CDMT.build(edited, params=P).root
        assert stats.hash_calls < 0.2 * len(fps)     # no full-tree re-hash
        rec = v.commit(edited, tag="v1", tree=tree, new_nodes=overlay)
        assert rec.root == tree.root
        assert rec.new_nodes == len(overlay)
        assert v.get_version(rec.version).leaf_fps() == edited

    def test_build_next_does_not_mutate(self):
        rng = random.Random(4)
        v = VersionedCDMT(P)
        v.commit(_fps(rng, 500), tag="v0")
        n_before = v.total_nodes()
        v.build_next(_fps(rng, 500))
        assert v.total_nodes() == n_before
        assert len(v.version_records()) == 1

    def test_tag_repush_idempotent_and_rejected(self):
        rng = random.Random(5)
        v = VersionedCDMT(P)
        fps = _fps(rng, 200)
        rec = v.commit(fps, tag="v0")
        again = v.commit(fps, tag="v0")          # same root: idempotent
        assert again is rec
        assert len(v.version_records()) == 1
        assert v.tags() == ["v0"]                # no duplicate tags
        with pytest.raises(ValueError):          # different root: rejected
            v.commit(_fps(rng, 200), tag="v0")
        assert len(v.version_records()) == 1


class TestRegistryIncrementalPush:
    def _payloads(self, rng, n):
        chunks = {}
        fps = []
        for _ in range(n):
            data = str(rng.random()).encode() * 3
            fp = hashing.chunk_fingerprint(data)
            chunks[fp] = data
            fps.append(fp)
        return fps, chunks

    def test_verified_push_is_incremental_no_full_rebuild(self):
        """receive_push of a k-leaf change verifies the claimed root via the
        incremental path: O(k·depth) nodes created, hash calls ≪ n."""
        rng = random.Random(6)
        reg = Registry()
        n, k = 10_000, 10
        fps, chunks = self._payloads(rng, n)
        sizes = [len(chunks[fp]) for fp in fps]
        client = CDMT.build(fps)                 # client-side index
        r0 = reg.receive_push("img", "v0", Recipe("img:v0", list(fps), sizes),
                              chunks, claimed_root=client.root)
        assert r0.version == 0
        cur = list(fps)
        idxs = rng.sample(range(n), k)
        newchunks = {}
        for i in idxs:
            data = str(rng.random()).encode() * 3
            fp = hashing.chunk_fingerprint(data)
            cur[i] = fp
            newchunks[fp] = data
        new_sizes = [len(chunks.get(fp) or newchunks[fp]) for fp in cur]
        client = CDMT.build_incremental(client, cur)
        r1 = reg.receive_push("img", "v1", Recipe("img:v1", cur, new_sizes),
                              newchunks, claimed_root=client.root)
        assert r1.root == client.root
        assert r1.hash_calls * 5 <= r0.hash_calls      # flat in n, not O(n)
        assert r1.nodes_created <= k * 8 * 64          # O(k · depth · fanout)

    def test_tag_repush_semantics_at_registry(self):
        rng = random.Random(8)
        reg = Registry()
        fps, chunks = self._payloads(rng, 100)
        sizes = [len(chunks[fp]) for fp in fps]
        recipe = Recipe("a:v0", list(fps), sizes)
        r0 = reg.receive_push("a", "v0", recipe, chunks)
        # same tag, same content: idempotent dedup, no new version
        r1 = reg.receive_push("a", "v0", recipe, chunks)
        assert r1.deduplicated and r1.version == r0.version
        assert r1.chunks_received == 0
        assert reg.tags("a") == ["v0"]
        # same tag, different content: rejected, state unchanged
        fps2, chunks2 = self._payloads(rng, 100)
        with pytest.raises(PushRejected):
            reg.receive_push("a", "v0",
                             Recipe("a:v0", fps2,
                                    [len(chunks2[f]) for f in fps2]), chunks2)
        assert reg.tags("a") == ["v0"]
        assert len(reg.lineage("a").version_records()) == 1

    def test_unknown_parent_version_rejected(self):
        rng = random.Random(9)
        reg = Registry()
        fps, chunks = self._payloads(rng, 10)
        recipe = Recipe("a:v0", fps, [len(chunks[f]) for f in fps])
        with pytest.raises(PushRejected):
            reg.receive_push("a", "v0", recipe, chunks, parent_version=3)
        assert reg.tags("a") == []
