"""Observability layer: metric math, snapshot algebra, exposition formats,
tracing semantics, and the cost contract of the disabled paths.

The delivery-path integration (metric byte totals == TransferReport totals,
live ``Op.METRICS`` scrape) is asserted in ``tests/test_transport.py``; this
file tests the ``repro.obs`` package itself.
"""

import json
import threading
import time

import pytest

from repro.obs import (LATENCY_BUCKETS, NULL_REGISTRY, NULL_TRACER,
                       MetricsRegistry, MetricsSnapshot, Span, Tracer,
                       check_monotonic, parse_prometheus_text,
                       to_prometheus_text)


# ------------------------------------------------------------------ counters

class TestCounters:
    def test_inc_and_value(self):
        m = MetricsRegistry()
        c = m.counter("reqs_total", "requests").labels()
        c.inc()
        c.inc(4)
        assert c.value() == 5

    def test_labels_are_independent_children(self):
        m = MetricsRegistry()
        fam = m.counter("bytes_total", "bytes", ("direction",))
        fam.labels("in").inc(10)
        fam.labels("out").inc(3)
        assert fam.labels("in").value() == 10
        assert fam.labels("out").value() == 3

    def test_negative_increment_rejected(self):
        m = MetricsRegistry()
        with pytest.raises(ValueError):
            m.counter("c_total", "c").labels().inc(-1)

    def test_reregistration_returns_same_family(self):
        m = MetricsRegistry()
        a = m.counter("c_total", "c")
        b = m.counter("c_total", "c")
        a.labels().inc()
        assert b.labels().value() == 1

    def test_kind_mismatch_rejected(self):
        m = MetricsRegistry()
        m.counter("x_total", "x")
        with pytest.raises(ValueError):
            m.gauge("x_total", "x")

    def test_labelnames_mismatch_rejected(self):
        m = MetricsRegistry()
        m.counter("y_total", "y", ("a",))
        with pytest.raises(ValueError):
            m.counter("y_total", "y", ("a", "b"))

    def test_concurrent_increments_lose_nothing(self):
        """The whole point of re-basing ServerStats on the registry: many
        threads hammering one counter must not lose increments the way the
        old unsynchronized ``+=`` could."""
        m = MetricsRegistry()
        c = m.counter("n_total", "n").labels()
        n_threads, per_thread = 8, 2000

        def work():
            for _ in range(per_thread):
                c.inc()

        threads = [threading.Thread(target=work) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.value() == n_threads * per_thread


# ------------------------------------------------------------------- gauges

class TestGauges:
    def test_set_inc_dec(self):
        m = MetricsRegistry()
        g = m.gauge("inflight", "in flight").labels()
        g.set(5)
        g.inc(2)
        g.dec()
        assert g.value() == 6


# --------------------------------------------------------------- histograms

class TestHistograms:
    def test_bucket_edges_are_le_semantics(self):
        m = MetricsRegistry()
        h = m.histogram("lat", "latency", buckets=(1.0, 2.0, 4.0)).labels()
        for v in (0.5, 1.0, 1.5, 4.0, 99.0):
            h.observe(v)
        view = m.snapshot().histogram("lat", {})
        # counts per bucket: le=1.0 gets {0.5, 1.0}, le=2.0 gets {1.5},
        # le=4.0 gets {4.0}, overflow gets {99.0}
        assert list(view.counts) == [2, 1, 1, 1]
        assert view.count == 5
        assert view.sum == pytest.approx(106.0)

    def test_quantiles_interpolate(self):
        m = MetricsRegistry()
        h = m.histogram("lat", "latency",
                        buckets=(0.1, 0.2, 0.4, 0.8)).labels()
        for _ in range(100):
            h.observe(0.15)                # all in the (0.1, 0.2] bucket
        view = m.snapshot().histogram("lat", {})
        q50 = view.quantile(0.5)
        assert 0.1 <= q50 <= 0.2
        assert view.quantile(0.0) <= view.quantile(0.99)

    def test_overflow_quantile_clamps_to_last_edge(self):
        m = MetricsRegistry()
        h = m.histogram("lat", "latency", buckets=(1.0, 2.0)).labels()
        h.observe(100.0)
        assert m.snapshot().histogram("lat", {}).quantile(0.99) == 2.0

    def test_empty_quantile_is_zero(self):
        m = MetricsRegistry()
        m.histogram("lat", "latency", buckets=(1.0,)).labels()
        assert m.snapshot().histogram("lat", {}).quantile(0.5) == 0.0

    def test_default_latency_buckets_span_sub_ms_to_10s(self):
        assert LATENCY_BUCKETS[0] <= 0.0005
        assert LATENCY_BUCKETS[-1] >= 10.0


# ---------------------------------------------------------------- snapshots

class TestSnapshots:
    def _sample(self):
        m = MetricsRegistry()
        m.counter("c_total", "c", ("k",)).labels("a").inc(3)
        m.gauge("g", "g").labels().set(7)
        h = m.histogram("h", "h", buckets=(1.0, 2.0)).labels()
        h.observe(0.5)
        h.observe(1.5)
        return m.snapshot()

    def test_value_lookup(self):
        snap = self._sample()
        assert snap.value("c_total", {"k": "a"}) == 3
        assert snap.value("g", {}) == 7
        assert snap.value("c_total", {"k": "zzz"}) == 0
        assert snap.value("nope", {}, default=None) is None

    def test_json_round_trip(self):
        snap = self._sample()
        again = MetricsSnapshot.from_json(snap.to_json())
        assert again.to_json_obj() == snap.to_json_obj()
        # and the payload is plain JSON (the Op.METRICS wire body)
        obj = json.loads(snap.to_json())
        assert obj["v"] == 1

    def test_merge_sums_counters_and_histograms(self):
        a, b = self._sample(), self._sample()
        merged = a.merge(b)
        assert merged.value("c_total", {"k": "a"}) == 6
        h = merged.histogram("h", {})
        assert h.count == 4
        assert h.sum == pytest.approx(4.0)

    def test_sum_values_across_label_sets(self):
        m = MetricsRegistry()
        fam = m.counter("c_total", "c", ("k",))
        fam.labels("a").inc(1)
        fam.labels("b").inc(2)
        snap = m.snapshot()
        assert snap.sum_values("c_total") == 3
        assert snap.sum_values("c_total", k="a") == 1


# --------------------------------------------------------------- exposition

class TestPrometheusText:
    def test_round_trip_parses(self):
        m = MetricsRegistry()
        m.counter("c_total", "help text", ("k",)).labels("v").inc(2)
        m.histogram("h_seconds", "hist", buckets=(0.5, 1.0)).labels()\
            .observe(0.7)
        text = to_prometheus_text(m.snapshot())
        parsed = parse_prometheus_text(text)
        assert parsed[("c_total", (("k", "v"),))] == 2
        # histogram exposition: cumulative buckets, +Inf, _sum, _count
        assert parsed[("h_seconds_bucket", (("le", "0.5"),))] == 0
        assert parsed[("h_seconds_bucket", (("le", "1"),))] == 1
        assert parsed[("h_seconds_bucket", (("le", "+Inf"),))] == 1
        assert parsed[("h_seconds_count", ())] == 1

    def test_parse_rejects_garbage(self):
        with pytest.raises(ValueError):
            parse_prometheus_text("this is not prometheus\n")

    def test_check_monotonic(self):
        m = MetricsRegistry()
        c = m.counter("c_total", "c").labels()
        c.inc(2)
        before = m.snapshot()
        c.inc()
        after = m.snapshot()
        assert check_monotonic(before, after) == []
        assert check_monotonic(after, before)  # regression detected


# ------------------------------------------------------------------ tracing

class TestTracing:
    def test_nesting_and_attrs(self):
        tr = Tracer(enabled=True)
        with tr.span("pull", tag="v1") as sp:
            with tr.span("plan"):
                pass
            sp.annotate(chunks=3)
        roots = tr.take()
        assert len(roots) == 1
        root = roots[0]
        assert root.name == "pull"
        assert root.attrs == {"tag": "v1", "chunks": 3}
        assert [c.name for c in root.children] == ["plan"]
        assert root.duration >= root.children[0].duration

    def test_explicit_parent_crosses_threads(self):
        tr = Tracer(enabled=True)
        with tr.span("execute") as sp:
            parent = tr.current()
            assert parent is sp

            def work():
                with tr.span("fetch_batch", parent=parent):
                    pass

            t = threading.Thread(target=work)
            t.start()
            t.join()
        [root] = tr.take()
        assert [c.name for c in root.children] == ["fetch_batch"]

    def test_error_annotated(self):
        tr = Tracer(enabled=True)
        with pytest.raises(RuntimeError):
            with tr.span("boom"):
                raise RuntimeError("x")
        [root] = tr.take()
        assert root.attrs["error"] == "RuntimeError"

    def test_ring_buffer_bounds_memory(self):
        tr = Tracer(enabled=True, capacity=4)
        for i in range(10):
            with tr.span(f"s{i}"):
                pass
        roots = tr.take()
        assert [r.name for r in roots] == ["s6", "s7", "s8", "s9"]
        assert tr.take() == []             # drained

    def test_dict_round_trip(self):
        tr = Tracer(enabled=True)
        with tr.span("a", k=1):
            with tr.span("b"):
                pass
        [root] = tr.take()
        again = Span.from_dict(root.to_dict())
        assert again.name == "a"
        assert again.attrs == {"k": 1}
        assert [c.name for c in again.children] == ["b"]
        walked = [(d, s.name) for d, s in again.walk()]
        assert walked == [(0, "a"), (1, "b")]


# ------------------------------------------------------------ disabled cost

class TestDisabledCost:
    def test_null_registry_vends_noops(self):
        c = NULL_REGISTRY.counter("c_total", "c").labels()
        c.inc(5)
        assert c.value() == 0
        assert NULL_REGISTRY.snapshot().names() == []

    def test_disabled_tracer_shares_one_null_span(self):
        a = NULL_TRACER.span("x")
        b = NULL_TRACER.span("y", parent=None, attr=1)
        assert a is b                      # no allocation per span
        with a as sp:
            sp.annotate(ignored=True)
        assert NULL_TRACER.take() == []

    def test_disabled_tracing_is_cheap(self):
        """Disabled span entry must cost roughly a no-op method call — the
        budget here (2µs/span) is ~100x the observed cost, tight enough to
        catch an accidental allocation-per-span or clock read."""
        tr = Tracer(enabled=False)
        n = 20_000
        t0 = time.perf_counter()
        for _ in range(n):
            with tr.span("hot", a=1):
                pass
        per_span = (time.perf_counter() - t0) / n
        assert per_span < 2e-6


# ----------------------------------------------------- registry wiring smoke

class TestRegistryWiring:
    def test_core_registry_owns_metrics(self):
        from repro.core.registry import Registry
        reg = Registry()
        assert isinstance(reg.metrics, MetricsRegistry)

    def test_server_adopts_registry_metrics(self):
        from repro.core.registry import Registry
        from repro.delivery import RegistryServer
        reg = Registry()
        srv = RegistryServer(reg)
        assert srv.metrics is reg.metrics
        assert srv.cache.metrics is reg.metrics
