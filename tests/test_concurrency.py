"""DebugLock-instrumented stress tier + regression tests for the races the
guarded-by lint found.

The stress test builds the full stack — primary registry behind a real TCP
`SocketRegistryServer`, a standby applying the journal live through a
`JournalFollower`, N puller threads and a metrics-scrape thread — with
every lock swapped for a ranked `DebugLock` (`repro.analysis.runtime`),
then asserts two things the static analyzer cannot: no thread ever
acquired locks against the documented hierarchy (`docs/CONCURRENCY.md`),
and the post-run state is consistent (byte-identical pulls, zero errors,
follower fully caught up).

The regression tests pin the concrete defects fixed in this change:

  * `SocketTransport.close()` set `_closed` outside `_pool_lock`, so a
    concurrent `_checkin` could repool a connection after close drained
    the pool — leaking a live socket;
  * two concurrent `JournalFollower.follow()` calls could both observe no
    live thread and both start appliers, violating the standby's
    single-writer contract;
  * `ReplicationLog.epoch` was a bare attribute written without the log's
    lock (now a locked property + `set_epoch`).
"""

import threading

import pytest

from repro.analysis import runtime
from repro.core import cdc
from repro.core.cdmt import CDMTParams
from repro.core.journal import ReplicationLog
from repro.core.registry import Registry
from repro.delivery import (ImageClient, JournalFollower, LocalTransport,
                            RegistryServer, SocketRegistryServer,
                            SocketTransport, WireTransport)

PARAMS = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)
P = CDMTParams(window=4, rule_bits=2)


def _rand(n, seed=0):
    import numpy as np
    return np.random.default_rng(seed).integers(
        0, 256, size=n, dtype=np.uint8).tobytes()


def _versions(n_versions=3, size=60_000, seed=0):
    import numpy as np
    rng = np.random.default_rng(seed)
    data = bytearray(_rand(size, seed))
    out = [bytes(data)]
    for _ in range(n_versions - 1):
        pos = rng.integers(0, len(data) - 200)
        data[pos:pos + 64] = rng.bytes(64)
        out.append(bytes(data))
    return out


def _seed_registry(versions, lineage="app"):
    reg = Registry(cdmt_params=P)
    pub = ImageClient(LocalTransport(reg), cdc_params=PARAMS, cdmt_params=P)
    for i, v in enumerate(versions):
        pub.commit(lineage, f"v{i}", v)
        pub.push(lineage, f"v{i}")
    return reg, pub


# ------------------------------------------------------------- stress tier


class TestInstrumentedStress:
    N_PULLERS = 4
    ROUNDS = 3

    def test_full_stack_hammer_respects_the_lock_hierarchy(self):
        versions = _versions(3, seed=41)
        reg, pub = _seed_registry(versions)
        srv = RegistryServer(reg)

        log = runtime.ViolationLog()
        # Instrument BEFORE any traffic (and before the socket door opens
        # accepts): swapping a lock another thread holds would split it.
        wrapped = runtime.instrument(srv, log=log)
        assert wrapped >= 4      # registry/stats/inflight/metrics at least

        sock_srv = SocketRegistryServer(srv)
        runtime.instrument(sock_srv, log=log)

        sreg = Registry(cdmt_params=P)
        fol_t = SocketTransport(sock_srv.address)
        fol = JournalFollower(sreg, fol_t, name="stress-standby",
                              poll_interval=0.005)
        runtime.instrument(fol, sreg, log=log)
        fol.follow()

        stop = threading.Event()
        errors = []
        pulled = []

        def puller(seed):
            try:
                with SocketTransport(sock_srv.address) as t:
                    cl = ImageClient(t, cdc_params=PARAMS, cdmt_params=P)
                    for r in range(self.ROUNDS):
                        tag = f"v{(seed + r) % len(versions)}"
                        cl.pull("app", tag)
                        pulled.append(
                            (tag, cl.materialize("app", tag)))
            except Exception as e:   # pragma: no cover - diagnostic
                errors.append(e)

        def scraper():
            try:
                with SocketTransport(sock_srv.address) as t:
                    while not stop.is_set():
                        snap = t.scrape_metrics()
                        assert snap.families is not None
            except Exception as e:   # pragma: no cover - diagnostic
                errors.append(e)

        threads = [threading.Thread(target=puller, args=(i,))
                   for i in range(self.N_PULLERS)]
        threads.append(threading.Thread(target=scraper))
        for t in threads:
            t.start()
        try:
            # concurrent publishes drive the live follower while pulls run
            pub.commit("app", "v3", versions[-1] + _rand(5_000, seed=42))
            pub.push("app", "v3")
            for t in threads[:-1]:
                t.join(timeout=60)
        finally:
            stop.set()
            threads[-1].join(timeout=10)

        try:
            assert errors == []
            # 1. the documented hierarchy held under real contention
            assert log.violations == []
            # 2. every pull was byte-identical to what was pushed
            assert len(pulled) == self.N_PULLERS * self.ROUNDS
            for tag, data in pulled:
                assert data == versions[int(tag[1:])]
            # 3. the follower caught up with zero violations of its own
            deadline = 200
            while fol.lag() and deadline:
                stop.wait(0.02)
                deadline -= 1
            assert fol.lag() == 0
            assert fol.last_error is None
            assert sreg.tags("app") == reg.tags("app")
            for tag in reg.tags("app"):
                assert (sreg.index_for_tag("app", tag).root
                        == reg.index_for_tag("app", tag).root)
            # 4. server-side counters are consistent after the dust settles
            s = sock_srv.snapshot()
            assert s.errors == 0
            assert s.requests >= self.N_PULLERS * self.ROUNDS
        finally:
            fol.stop()
            fol_t.close()
            sock_srv.stop()


# ------------------------------------------ regressions found by the lint


class TestSocketTransportCloseRace:
    """`close()` must flip `_closed` and drain the pool in ONE critical
    section: a checkin that raced the old unlocked flag write could repool
    a live connection after close() had already drained, leaking a socket
    to the OS until process exit."""

    def test_checkin_after_close_does_not_repool(self):
        versions = _versions(2, seed=43)
        reg, _ = _seed_registry(versions)
        srv = RegistryServer(reg)
        with SocketRegistryServer(srv) as door:
            t = SocketTransport(door.address)
            conn = t._checkout()          # a live connection in flight
            t.close()
            t._checkin(conn)              # the racing return
            assert t._pool == []          # must NOT be repooled
            with pytest.raises(Exception):
                t.get_index("app", "v0")  # closed transport stays closed

    def test_concurrent_close_and_traffic_never_leaves_pooled_conns(self):
        versions = _versions(2, seed=44)
        reg, _ = _seed_registry(versions)
        srv = RegistryServer(reg)
        with SocketRegistryServer(srv) as door:
            for trial in range(8):
                t = SocketTransport(door.address)
                barrier = threading.Barrier(3)

                def traffic():
                    barrier.wait()
                    try:
                        t.get_index("app", "v1")
                    except Exception:
                        pass              # losing the race to close is fine

                def closer():
                    barrier.wait()
                    t.close()

                ths = [threading.Thread(target=traffic),
                       threading.Thread(target=traffic),
                       threading.Thread(target=closer)]
                for th in ths:
                    th.start()
                for th in ths:
                    th.join()
                with t._pool_lock:
                    assert t._pool == [] and t._closed


class TestFollowerSingleWriter:
    """Concurrent `follow()` calls must yield exactly ONE applier thread —
    standby registries are single-writer; two concurrent appliers corrupt
    the standby journal."""

    def test_concurrent_follow_starts_exactly_one_applier(self):
        versions = _versions(2, seed=45)
        reg, _ = _seed_registry(versions)
        srv = RegistryServer(reg)
        sreg = Registry(cdmt_params=P)
        fol = JournalFollower(sreg, WireTransport(srv),
                              poll_interval=0.01)
        barrier = threading.Barrier(8)

        def start():
            barrier.wait()
            fol.follow()

        ths = [threading.Thread(target=start) for _ in range(8)]
        for th in ths:
            th.start()
        for th in ths:
            th.join()
        try:
            appliers = [th for th in threading.enumerate()
                        if th.name == "journal-follower"]
            assert len(appliers) == 1
            deadline = 200
            while fol.lag() and deadline:
                threading.Event().wait(0.02)
                deadline -= 1
            assert fol.lag() == 0
        finally:
            fol.stop()
        # exactly one applier ran: no record was double-applied
        assert fol.records_applied == len(versions)
        assert fol.duplicates_skipped == 0

    def test_follow_after_stop_restarts_cleanly(self):
        versions = _versions(2, seed=46)
        reg, pub = _seed_registry(versions)
        srv = RegistryServer(reg)
        sreg = Registry(cdmt_params=P)
        fol = JournalFollower(sreg, WireTransport(srv), poll_interval=0.01)
        fol.follow()
        fol.stop()
        fol.follow()                      # new generation, new stop event
        try:
            pub.commit("app", "v2", versions[1] + _rand(2_000, seed=47))
            pub.push("app", "v2")
            deadline = 200
            while fol.lag() and deadline:
                threading.Event().wait(0.02)
                deadline -= 1
            assert fol.lag() == 0
        finally:
            fol.stop()


class TestReplicationLogEpochLocking:
    """`epoch` is now a locked property: writes go through `set_epoch` (or
    `rollover`), bare attribute assignment is rejected, and concurrent
    rollovers never lose an increment."""

    def test_epoch_attribute_cannot_be_assigned(self):
        log = ReplicationLog()
        with pytest.raises(AttributeError):
            log.epoch = 7

    def test_set_epoch_and_property_round_trip(self):
        log = ReplicationLog()
        assert log.epoch == 0
        log.set_epoch(5)
        assert log.epoch == 5

    def test_concurrent_rollovers_are_all_counted(self):
        log = ReplicationLog()
        per_thread, n_threads = 25, 4
        barrier = threading.Barrier(n_threads)

        def spin():
            barrier.wait()
            for _ in range(per_thread):
                log.rollover()

        ths = [threading.Thread(target=spin) for _ in range(n_threads)]
        for th in ths:
            th.start()
        for th in ths:
            th.join()
        assert log.epoch == per_thread * n_threads
