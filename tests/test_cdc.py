"""CDC invariants: reconstruction, determinism, byte-shift locality."""

import numpy as np
import pytest

from repro.core import cdc


def _rand(n, seed=0):
    return np.random.default_rng(seed).integers(0, 256, size=n,
                                                dtype=np.uint8).tobytes()


PARAMS = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)


class TestReconstruction:
    def test_concat_reproduces_data(self):
        data = _rand(100_000)
        chunks = list(cdc.chunk_bytes(data, PARAMS))
        assert b"".join(chunks) == data

    def test_empty(self):
        assert list(cdc.chunk_bytes(b"", PARAMS)) == []

    def test_tiny(self):
        data = b"x"
        assert b"".join(cdc.chunk_bytes(data, PARAMS)) == data

    def test_bounds_respected(self):
        data = _rand(200_000)
        sizes = [len(c) for c in cdc.chunk_bytes(data, PARAMS)]
        assert all(s <= PARAMS.max_size for s in sizes)
        assert all(s >= PARAMS.min_size for s in sizes[:-1])  # last may be short

    def test_deterministic(self):
        data = _rand(50_000, seed=3)
        a = cdc.chunk_boundaries(data, PARAMS)
        b = cdc.chunk_boundaries(data, PARAMS)
        assert a == b

    def test_rabin_reference_agrees_on_reconstruction(self):
        data = _rand(60_000, seed=4)
        p = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192,
                          algorithm="rabin")
        chunks = list(cdc.chunk_bytes(data, p))
        assert b"".join(chunks) == data


class TestByteShiftResistance:
    """The paper's core CDC claim (Sec. III-A): an edit only perturbs
    chunks local to the edit."""

    def test_insert_preserves_most_chunks(self):
        data = _rand(300_000, seed=1)
        fps_a = set(cdc.chunk_boundaries(data, PARAMS))
        chunks_a = {bytes(c) for c in cdc.chunk_bytes(data, PARAMS)}
        edited = data[:150_000] + b"INSERTED" + data[150_000:]
        chunks_b = list(cdc.chunk_bytes(edited, PARAMS))
        shared = sum(1 for c in chunks_b if bytes(c) in chunks_a)
        assert shared / len(chunks_b) > 0.9, "edit must stay local"

    def test_prefix_insert_shifts_nothing_after_sync(self):
        data = _rand(200_000, seed=2)
        chunks_a = {bytes(c) for c in cdc.chunk_bytes(data, PARAMS)}
        edited = b"PREFIX" + data
        chunks_b = list(cdc.chunk_bytes(edited, PARAMS))
        shared = sum(1 for c in chunks_b if bytes(c) in chunks_a)
        # fixed-width chunking would share ~0 here (the byte-shift problem)
        assert shared / len(chunks_b) > 0.9


# Hypothesis property tests live in tests/test_properties.py (optional dep).


def test_mask_to_boundaries_matches_direct():
    data = np.frombuffer(_rand(50_000, seed=7), dtype=np.uint8)
    h = cdc.gear_hash_stream(data)
    mask = (h & np.uint32(PARAMS.mask)) == 0
    assert cdc.boundaries_from_mask(mask, PARAMS) == \
        cdc.chunk_boundaries(data, PARAMS)
