"""Launcher CLIs as subprocess integration tests (the public entrypoints)."""

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_cli(args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-m"] + args,
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_train_cli_runs_and_checkpoints(tmp_path):
    out = run_cli(["repro.launch.train", "--arch", "olmo-1b", "--reduced",
                   "--steps", "20", "--batch", "4", "--seq", "64",
                   "--ckpt-every", "10", "--log-every", "5"])
    assert "done: 20 steps" in out
    assert "checkpoints: 2" in out
    # loss decreased from ~ln(512)=6.2
    lines = [l for l in out.splitlines() if l.startswith("step")]
    first = float(lines[0].split()[3])
    last = float(lines[-1].split()[3])
    assert last < first


def test_serve_cli_runs(tmp_path):
    out = run_cli(["repro.launch.serve", "--arch", "olmo-1b", "--reduced",
                   "--requests", "4", "--batch", "2", "--prompt-len", "16",
                   "--new-tokens", "4"])
    assert "served 4 requests" in out
    assert "tokens/s" in out


def test_dryrun_cli_single_cell(tmp_path):
    out = run_cli(["repro.launch.dryrun", "--arch", "olmo-1b",
                   "--shape", "decode_32k", "--mesh", "single",
                   "--out-dir", str(tmp_path)], timeout=900)
    assert "OK" in out
    import json, glob
    recs = glob.glob(str(tmp_path / "*.json"))
    assert len(recs) == 1
    r = json.load(open(recs[0]))
    assert r["status"] == "ok"
    assert r["roofline"]["memory_s"] > 0
