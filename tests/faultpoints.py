"""Crash-point fault-injection harness for durability tests.

``repro.core.faults`` plants named fire points inside the registry's
crash-ordering windows (snapshot rename vs journal reset, trim vs
compact, bootstrap persist vs install).  This harness arms a point with a
hook that raises :class:`CrashPoint` — simulating a process death at
exactly that boundary — and guarantees disarm on exit, so one test's
crash never leaks into the next.

Usage::

    with crash_at("compact.after_snapshot"):
        with pytest.raises(CrashPoint):
            reg.compact()
    # the "process" died between the snapshot rename and the journal
    # reset; reopen the directory and assert recovery

``CRASH_POINTS`` is the catalog of every planted point, so a kill-matrix
test can parametrize over all of them and fail loudly if a new point is
planted without coverage (see ``test_replication.py::TestCrashMatrix``).
"""

from __future__ import annotations

from contextlib import contextmanager

from repro.core import faults

__all__ = ["CRASH_POINTS", "CrashPoint", "crash_at", "crash_after"]

# every faults.fire() site in the tree, in execution order per path
CRASH_POINTS = (
    # primary: trim -> compact
    "trim.before_compact",          # trimmed in memory, nothing durable yet
    # primary/standby: compact()
    "compact.after_snapshot",       # snapshot renamed, journal not reset
    "compact.before_marker",        # journal reset, no _J_COMPACT marker yet
    # standby: bootstrap_from_snapshot()
    "bootstrap.before_snapshot",    # verified in scratch, nothing persisted
    "bootstrap.after_snapshot",     # snapshot renamed, journal not reset
    "bootstrap.before_marker",      # journal reset, no marker yet
    "bootstrap.after_persist",      # durable, in-memory state not installed
    # follower: bootstrap_from_primary()
    "follower.before_bootstrap",    # resync decided, snapshot not fetched
    "follower.before_ack",          # bootstrap installed, head not acked
)


class CrashPoint(Exception):
    """Raised by an armed fault hook — the simulated process death."""

    def __init__(self, point: str):
        super().__init__(f"injected crash at fault point {point!r}")
        self.point = point


@contextmanager
def crash_at(point: str):
    """Arm ``point`` to raise :class:`CrashPoint` the first time it fires;
    disarmed on exit no matter how the body ends."""
    def die():
        raise CrashPoint(point)
    faults.arm(point, die)
    try:
        yield
    finally:
        faults.disarm(point)


@contextmanager
def crash_after(point: str, n: int):
    """Arm ``point`` to raise on its ``n``-th firing (0-based) — for
    points that fire once per call on a path crossed repeatedly."""
    seen = {"count": 0}

    def maybe_die():
        hit = seen["count"]
        seen["count"] += 1
        if hit == n:
            raise CrashPoint(point)
    faults.arm(point, maybe_die)
    try:
        yield
    finally:
        faults.disarm(point)
