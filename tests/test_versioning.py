"""Versioned CDMT maintenance (paper Sec. V-A): node-copying, array of
roots, branching, layering history."""

import numpy as np

from repro.core import hashing
from repro.core.cdmt import CDMTParams
from repro.core.versioning import VersionedCDMT

P = CDMTParams(window=4, rule_bits=2)


def _fps(n, seed=0):
    rng = np.random.default_rng(seed)
    return [hashing.chunk_fingerprint(rng.bytes(32)) for _ in range(n)]


def test_commit_and_get_version_roundtrip():
    v = VersionedCDMT(P)
    fps = _fps(100)
    rec = v.commit(fps, tag="v1")
    t = v.get_version(rec.version)
    assert t.leaf_fps() == fps
    assert t.root == rec.root


def test_node_copying_shares_unchanged_subtrees():
    """The paper's write-optimization: a new version materializes only the
    changed root-to-leaf paths."""
    v = VersionedCDMT(P)
    fps = _fps(1000, seed=1)
    v.commit(fps, tag="v1")
    nodes_after_v1 = v.total_nodes()
    edited = list(fps)
    edited[500] = hashing.chunk_fingerprint(b"new chunk")
    rec2 = v.commit(edited, tag="v2")
    created = v.total_nodes() - nodes_after_v1
    # one leaf + its ancestor path (≪ full tree rebuild)
    assert created < 0.05 * nodes_after_v1
    assert rec2.new_nodes == created


def test_array_of_roots_all_versions_reconstructible():
    v = VersionedCDMT(P)
    base = _fps(200, seed=2)
    tags = []
    cur = list(base)
    for i in range(10):
        cur = cur[:i * 10] + _fps(1, seed=100 + i) + cur[i * 10:]
        tags.append(f"v{i}")
        v.commit(cur, tag=f"v{i}")
    assert len(v.version_records()) == 10
    # every historical version still reconstructs exactly
    t0 = v.get_tag("v0")
    assert len(t0.leaf_fps()) == 201
    t9 = v.get_tag("v9")
    assert len(t9.leaf_fps()) == 210


def test_branching():
    """Two branches from a common parent share the node store (Fig. 5)."""
    v = VersionedCDMT(P)
    base = _fps(300, seed=3)
    rec0 = v.commit(base, tag="main@v1")
    # branch A and branch B both fork from v1 with disjoint edits
    edit_a = list(base)
    edit_a[10] = hashing.chunk_fingerprint(b"branch-a")
    rec_a = v.commit(edit_a, tag="a@v1", parent=rec0.version)
    edit_b = list(base)
    edit_b[250] = hashing.chunk_fingerprint(b"branch-b")
    rec_b = v.commit(edit_b, tag="b@v1", parent=rec0.version)
    assert rec_a.parent == rec0.version and rec_b.parent == rec0.version
    # diff between the branches is just the two edits' paths
    d = v.diff(rec_a.version, rec_b.version)
    assert hashing.chunk_fingerprint(b"branch-b") in d
    assert len(d) <= 6


def test_diff_incremental():
    v = VersionedCDMT(P)
    fps = _fps(400, seed=4)
    v.commit(fps, tag="v1")
    edited = fps + _fps(5, seed=5)
    v.commit(edited, tag="v2")
    missing = v.diff(0, 1)
    assert set(_fps(5, seed=5)) <= missing
    assert len(missing) <= 5 + 4 * P.window


def test_layering_history_resolves_by_version():
    v = VersionedCDMT(P)
    roots = []
    for i in range(5):
        rec = v.commit(_fps(50, seed=10 + i), tag=f"r@v{i}")
        roots.append(rec.root)
    for i in range(5):
        assert v.resolve_at(b"root:r", i) == roots[i]


def test_branch_root_at_is_resolve_at_on_the_root_slot():
    v = VersionedCDMT(P)
    roots = []
    for i in range(4):
        rec = v.commit(_fps(40, seed=20 + i), tag=f"main@{i}")
        roots.append(rec.root)
    for i in range(4):
        assert v.branch_root_at("main", i) == roots[i]
        assert v.branch_root_at("main", i) == v.resolve_at(b"root:main", i)
    assert v.branch_root_at("other", 3) is None


def test_branch_history_is_a_safe_copy_in_version_order():
    v = VersionedCDMT(P)
    v.commit(_fps(30, seed=30), tag="main@0")
    v.commit(_fps(30, seed=31), tag="dev@0")
    v.commit(_fps(30, seed=32), tag="main@1")
    hist = v.branch_history("main")
    assert [ver for ver, _ in hist] == [0, 2]
    hist.append((99, b"x" * 16))            # mutating the copy…
    assert len(v.branch_history("main")) == 2   # …never leaks back
    assert v.branch_history("dev") == [(1, v.roots[1].root)]
    assert v.branch_history("ghost") == []
