"""Socket layer: envelope codecs, the TCP registry server, and the socket
transport's error/streaming/pooling behavior.

Transport *conformance* (socket moves the same chunks as local/wire, byte
relations, plan quoting) lives in ``tests/test_transport.py``; this file
covers the protocol pieces themselves.
"""

import threading

import pytest

from repro.core import cdc, hashing
from repro.core.cdmt import CDMTParams
from repro.core.errors import DeliveryError
from repro.core.registry import PushRejected, Registry
from repro.delivery import (ImageClient, LocalTransport, RegistryServer,
                            SocketRegistryServer, SocketTransport, wire)

PARAMS = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)
P = CDMTParams(window=4, rule_bits=2)


def _rand(n, seed=0):
    import numpy as np
    return np.random.default_rng(seed).integers(
        0, 256, size=n, dtype=np.uint8).tobytes()


def _seeded_server(n_versions=3, seed=70, **server_kw):
    import numpy as np
    rng = np.random.default_rng(seed)
    data = bytearray(_rand(120_000, seed))
    reg = Registry(cdmt_params=P)
    pub = ImageClient(LocalTransport(reg), cdc_params=PARAMS, cdmt_params=P)
    versions = []
    for i in range(n_versions):
        versions.append(bytes(data))
        pub.commit("app", f"v{i}", bytes(data))
        pub.push("app", f"v{i}")
        pos = int(rng.integers(0, len(data) - 200))
        data[pos:pos + 128] = rng.bytes(128)
        ins = int(rng.integers(0, len(data)))
        data[ins:ins] = rng.bytes(64)
    return RegistryServer(reg, **server_kw), versions


# ---------------------------------------------------------------- codecs


class TestEnvelopeCodecs:
    def test_request_roundtrip(self):
        frames = [wire.encode_want([hashing.chunk_fingerprint(b"x")]),
                  b"\x00" * 17]
        buf = wire.encode_request(wire.Op.WANT, "app", "v3", frames)
        op, lineage, tag, out = wire.decode_request(buf)
        assert (op, lineage, tag, out) == (wire.Op.WANT, "app", "v3", frames)

    def test_request_no_frames_and_unicode_routing(self):
        buf = wire.encode_request(wire.Op.INDEX, "appé", "v∞")
        op, lineage, tag, out = wire.decode_request(buf)
        assert (op, lineage, tag, out) == (wire.Op.INDEX, "appé", "v∞", [])

    def test_request_bad_magic_version_op_truncation(self):
        buf = wire.encode_request(wire.Op.HAS, "a", "b", [b"xy"])
        with pytest.raises(wire.WireError):
            wire.decode_request(b"XX" + buf[2:])
        with pytest.raises(wire.WireError):
            wire.decode_request(buf[:2] + b"\x99" + buf[3:])
        with pytest.raises(wire.WireError):
            wire.decode_request(buf[:3] + b"\xfe" + buf[4:])   # unknown op
        with pytest.raises(wire.WireError):
            wire.decode_request(buf[:-1])                      # truncated
        with pytest.raises(wire.WireError):
            wire.decode_request(buf + b"!")                    # trailing

    def test_response_roundtrip_and_error_status(self):
        frames = [b"alpha", b"", b"gamma"]
        status, out = wire.decode_response(
            wire.encode_response(wire.STATUS_OK, frames))
        assert (status, out) == (wire.STATUS_OK, frames)
        err = wire.encode_error(wire.ErrorCode.DELIVERY, "nope")
        status, out = wire.decode_response(
            wire.encode_response(wire.STATUS_ERROR, [err]))
        assert status == wire.STATUS_ERROR
        assert wire.decode_error(out[0]) == (wire.ErrorCode.DELIVERY, "nope")

    def test_envelope_sizing_is_exact(self):
        frames = [b"x" * n for n in (0, 1, 127, 128, 300)]
        lens = [len(f) for f in frames]
        assert wire.request_envelope_bytes("lineage", "tag", lens) \
            == len(wire.encode_request(wire.Op.PUSH, "lineage", "tag",
                                       frames))
        assert wire.response_envelope_bytes(lens) \
            == len(wire.encode_response(wire.STATUS_OK, frames))

    def test_chunk_batch_frame_lens_match_sum(self):
        sizes = [100, 2000, 1, 0, 550, 129]
        lens = wire.chunk_batch_frame_lens(sizes, 2)
        assert len(lens) == 3
        assert sum(lens) == wire.chunk_batches_wire_bytes(sizes, 2)


class TestControlFrames:
    def test_tags_roundtrip(self):
        assert wire.decode_tags_request(wire.encode_tags_request("app")) \
            == "app"
        tags = ["v0", "release-1.2", "head"]
        assert wire.decode_tag_list(wire.encode_tag_list(tags)) == tags
        assert wire.decode_tag_list(wire.encode_tag_list([])) == []
        with pytest.raises(wire.WireError):
            wire.decode_tag_list(wire.encode_tags_request("app"))

    def test_error_roundtrip_and_unknown_code_degrades(self):
        for code in wire.ErrorCode:
            assert wire.decode_error(wire.encode_error(code, "m")) \
                == (code, "m")
        # a future error code decodes as INTERNAL instead of raising
        raw = wire.encode_frame(
            wire.FrameType.ERROR,
            wire.encode_uvarint(250) + wire.encode_uvarint(2) + b"hi")
        assert wire.decode_error(raw) == (wire.ErrorCode.INTERNAL, "hi")

    def test_receipt_roundtrip(self):
        from repro.core.registry import PushReceipt
        r = PushReceipt(lineage="app", tag="v3", version=3,
                        chunks_received=17, bytes_received=54321,
                        index_bytes=900, root=hashing.chunk_fingerprint(b"r"),
                        nodes_created=5, nodes_hashed=9, hash_calls=40,
                        deduplicated=True)
        assert wire.decode_receipt(wire.encode_receipt(r)) == r
        with pytest.raises(wire.WireError):
            wire.decode_receipt(wire.encode_receipt(r)[:-1])

    def test_receipt_roundtrip_empty_artifact(self):
        """An empty artifact's receipt carries root=None (its CDMT has no
        root) — the frame must encode the absence, not crash."""
        from repro.core.registry import PushReceipt
        r = PushReceipt(lineage="app", tag="v0", version=0,
                        chunks_received=0, bytes_received=0,
                        index_bytes=0, root=None)
        assert wire.decode_receipt(wire.encode_receipt(r)) == r

    def test_info_roundtrip(self):
        assert wire.decode_info(wire.encode_info(64)) == 64


# ------------------------------------------------------------ socket server


@pytest.fixture()
def sock_env():
    srv, versions = _seeded_server()
    sock_srv = SocketRegistryServer(srv)
    transports = []

    def connect(**kw):
        t = SocketTransport(sock_srv.address, **kw)
        transports.append(t)
        return t

    yield srv, sock_srv, versions, connect
    for t in transports:
        t.close()
    sock_srv.stop()


class TestSocketServer:
    def test_pull_and_materialize(self, sock_env):
        srv, sock_srv, versions, connect = sock_env
        cl = ImageClient(connect(), cdc_params=PARAMS, cdmt_params=P)
        rep = cl.pull("app", "v2")
        assert cl.materialize("app", "v2") == versions[2]
        assert rep.transport == "socket"
        assert rep.chunks_moved == rep.chunks_total

    def test_streamed_want_multi_frame(self, sock_env):
        """A WANT larger than the server's batch split comes back as several
        CHUNK_BATCH frames inside one response — one round, many frames."""
        srv, sock_srv, versions, connect = sock_env
        t = connect(batch_chunks=1024)
        cl = ImageClient(t, cdc_params=PARAMS, cdmt_params=P,
                         batch_chunks=1024)
        plan = cl.plan_pull("app", "v0")
        assert plan.chunks_to_fetch > srv.max_batch_chunks
        rep = cl.execute(plan)
        leg = rep.sources["registry"]
        assert leg.rounds == 1                    # one request round-trip…
        assert rep.chunks_moved == plan.chunks_to_fetch
        # …whose framing matched the server's split exactly, per the quote
        assert (rep.index_bytes + rep.recipe_bytes + rep.chunk_bytes) \
            == plan.expected_wire_bytes

    def test_envelope_overhead_identity_on_index(self, sock_env):
        """Socket meters == frame meters + exactly the envelope bytes."""
        srv, sock_srv, versions, connect = sock_env
        t = connect()
        s0, f0 = sock_srv.snapshot(), srv.snapshot()
        idx, nbytes = t.get_index("app", "v1")
        s1, f1 = sock_srv.snapshot(), srv.snapshot()
        frame_len = f1.egress_bytes - f0.egress_bytes
        req_len = wire.request_envelope_bytes("app", "v1", [])
        assert s1.ingress_bytes - s0.ingress_bytes == req_len
        assert s1.egress_bytes - s0.egress_bytes \
            == wire.response_envelope_bytes([frame_len])
        assert nbytes == req_len + wire.response_envelope_bytes([frame_len])

    def test_tags_over_socket_metered(self, sock_env):
        srv, sock_srv, versions, connect = sock_env
        t = connect()
        f0 = srv.snapshot()
        assert t.tags("app") == ["v0", "v1", "v2"]
        f1 = srv.snapshot()
        assert f1.tags_requests == f0.tags_requests + 1
        assert f1.ingress_bytes > f0.ingress_bytes
        assert f1.egress_bytes > f0.egress_bytes

    def test_remote_errors_reraise_matching_exceptions(self, sock_env):
        srv, sock_srv, versions, connect = sock_env
        t = connect()
        cl = ImageClient(t, cdc_params=PARAMS, cdmt_params=P)
        with pytest.raises(DeliveryError):
            cl.pull("ghost", "v0")             # unknown lineage
        with pytest.raises(DeliveryError):
            cl.pull("app", "v99")              # unknown tag
        # a push whose claimed root is a lie is rejected server-side and
        # re-raised client-side as PushRejected, not a generic failure
        cl.commit("b", "v0", _rand(40_000, seed=71))
        real_index_for_tag = cl.index_for_tag

        def lying(lineage, tag):
            import copy
            forged = copy.copy(real_index_for_tag(lineage, tag))
            forged.root = hashing.chunk_fingerprint(b"forged")
            return forged

        cl.index_for_tag = lying
        with pytest.raises(PushRejected):
            cl.push("b", "v0")

    def test_garbage_envelope_gets_error_reply_then_close(self, sock_env):
        """A client speaking the wrong protocol gets one ERROR frame and a
        closed connection — the server neither crashes a thread nor hangs,
        and keeps serving real clients."""
        import socket as socket_mod
        srv, sock_srv, versions, connect = sock_env
        s = socket_mod.create_connection(sock_srv.address)
        s.sendall(b"GET / HTTP/1.1\r\n\r\n")
        s.settimeout(5)
        status, frames = wire.decode_response(s.recv(4096))
        assert status == wire.STATUS_ERROR
        code, _msg = wire.decode_error(frames[0])
        assert code is wire.ErrorCode.WIRE
        assert s.recv(100) == b""              # connection closed after
        s.close()
        assert sock_srv.snapshot().errors >= 1
        cl = ImageClient(connect(), cdc_params=PARAMS, cdmt_params=P)
        cl.pull("app", "v1")
        assert cl.materialize("app", "v1") == versions[1]

    def test_malformed_body_frame_is_wire_error(self, sock_env):
        srv, sock_srv, versions, connect = sock_env
        t = connect()
        with pytest.raises(wire.WireError):
            t._exchange(wire.Op.WANT, "app", "v0", [b"garbage-not-a-frame"])

    def test_connection_refused_is_delivery_error(self, sock_env):
        srv, sock_srv, versions, connect = sock_env
        host, port = sock_srv.address
        sock_srv.stop()
        with pytest.raises(DeliveryError):
            SocketTransport((host, port), timeout=2.0)

    def test_push_roundtrip_receipt(self, sock_env):
        srv, sock_srv, versions, connect = sock_env
        t = connect()
        cl = ImageClient(t, cdc_params=PARAMS, cdmt_params=P)
        data = _rand(60_000, seed=72)
        cl.commit("fresh", "v0", data)
        rep = cl.push("fresh", "v0")
        assert rep.chunks_moved > 0
        puller = ImageClient(connect(), cdc_params=PARAMS, cdmt_params=P)
        puller.pull("fresh", "v0")
        assert puller.materialize("fresh", "v0") == data

    def test_empty_artifact_over_socket(self, sock_env):
        srv, sock_srv, versions, connect = sock_env
        cl = ImageClient(connect(), cdc_params=PARAMS, cdmt_params=P)
        cl.commit("empty", "v0", b"")
        cl.push("empty", "v0")
        puller = ImageClient(connect(), cdc_params=PARAMS, cdmt_params=P)
        puller.pull("empty", "v0")
        assert puller.materialize("empty", "v0") == b""

    def test_concurrent_pullers_share_server(self, sock_env):
        srv, sock_srv, versions, connect = sock_env
        n = 4
        clients = [ImageClient(connect(), cdc_params=PARAMS, cdmt_params=P)
                   for _ in range(n)]
        errors = []

        def pull(cl):
            try:
                cl.pull("app", "v2")
            except BaseException as e:   # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=pull, args=(c,)) for c in clients]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert not errors
        for cl in clients:
            assert cl.materialize("app", "v2") == versions[2]

    def test_connection_pool_reuses_sockets(self, sock_env):
        srv, sock_srv, versions, connect = sock_env
        t = connect()
        cl = ImageClient(t, cdc_params=PARAMS, cdmt_params=P,
                         pipeline_depth=1)
        cl.pull("app", "v0")
        cl.pull("app", "v1")
        cl.pull("app", "v2")
        # sequential traffic rides one pooled connection (plus none extra)
        assert sock_srv.snapshot().connections == 1

    def test_stalled_mid_request_client_is_dropped(self):
        """A client that starts a request and stalls must not pin a server
        connection thread forever — after ``io_timeout`` the server drops
        the connection (idle *between* requests is separately bounded by
        ``idle_timeout`` when configured; pooled clients survive that reap
        via the stale-connection retry)."""
        import socket as socket_mod
        srv, _versions = _seeded_server()
        sock_srv = SocketRegistryServer(srv, io_timeout=0.5)
        try:
            s = socket_mod.create_connection(sock_srv.address)
            s.sendall(wire.REQUEST_MAGIC)      # request started, then stall
            s.settimeout(5)
            assert s.recv(100) == b""          # server gave up and closed
            s.close()
            # the server is healthy and still answers real clients
            t = SocketTransport(sock_srv.address)
            assert t.tags("app") == ["v0", "v1", "v2"]
            t.close()
        finally:
            sock_srv.stop()

    def test_oversized_length_prefix_rejected_before_allocation(self,
                                                                sock_env):
        srv, sock_srv, versions, connect = sock_env
        import socket as socket_mod
        s = socket_mod.create_connection(sock_srv.address)
        # op INDEX, then a lineage length prefix claiming ~2^35 bytes
        s.sendall(wire.REQUEST_MAGIC + bytes((wire.VERSION, wire.Op.INDEX))
                  + wire.encode_uvarint(1 << 35))
        s.settimeout(5)
        status, frames = wire.decode_response(s.recv(4096))
        assert status == wire.STATUS_ERROR
        code, msg = wire.decode_error(frames[0])
        assert code is wire.ErrorCode.WIRE
        assert "exceeds" in msg
        s.close()

    def test_closed_transport_refuses(self, sock_env):
        srv, sock_srv, versions, connect = sock_env
        t = connect()
        t.close()
        with pytest.raises(DeliveryError):
            t.tags("app")
