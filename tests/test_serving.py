"""Serving engine: greedy decode consistency, batching, metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.models.api import build_model
from repro.serving import Request, ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def setup():
    model = build_model("olmo-1b", reduced=True)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, ServeConfig(batch_size=4, max_len=192))
    return model, params, engine


def test_greedy_matches_teacher_forcing(setup):
    """Engine greedy decode == repeated argmax over full forwards."""
    model, params, engine = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, model.cfg.vocab, size=24, dtype=np.int32)
    req = Request(id=0, prompt=prompt, max_new_tokens=5)
    engine.serve_batch([req])

    toks = list(prompt)
    for _ in range(5):
        batch = {"tokens": jnp.asarray(np.asarray(toks, np.int32))[None]}
        hidden = lm.family_hidden(params, batch, model.cfg, remat=False)
        logits = lm.logits_last(params, hidden, model.cfg)
        toks.append(int(jnp.argmax(logits[0, -1])))
    np.testing.assert_array_equal(req.output, np.asarray(toks[24:], np.int32))


def test_batch_of_equal_prompts_identical_outputs(setup):
    model, params, engine = setup
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, model.cfg.vocab, size=16, dtype=np.int32)
    reqs = [Request(id=i, prompt=prompt.copy(), max_new_tokens=4)
            for i in range(3)]
    engine.serve_batch(reqs)
    np.testing.assert_array_equal(reqs[0].output, reqs[1].output)
    np.testing.assert_array_equal(reqs[0].output, reqs[2].output)


def test_serve_many_batches_metrics(setup):
    model, params, engine = setup
    rng = np.random.default_rng(2)
    reqs = [Request(id=i, prompt=rng.integers(0, model.cfg.vocab, 8,
                                              dtype=np.int32),
                    max_new_tokens=2) for i in range(10)]
    m = engine.serve(reqs)
    assert m["requests"] == 10
    assert m["tokens_per_s"] > 0
    assert all(r.output is not None and len(r.output) == 2 for r in reqs)
