"""Trainer: loss decreases, checkpoint/restart resumes exactly, straggler
reassignment, peer chunk fetch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig
from repro.core import cdc
from repro.core.pushpull import Client
from repro.core.registry import Registry
from repro.data import DataConfig
from repro.models.api import build_model
from repro.optim import AdamWConfig
from repro.runtime.straggler import StragglerConfig, StragglerTracker, peer_fetch
from repro.runtime.trainer import SimulatedFailure, Trainer, TrainerConfig
from repro.runtime.train_step import TrainConfig

CDC = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)


def _trainer(registry=None, total_steps=12, fail_at=None, n_micro=1,
             every=5):
    model = build_model("olmo-1b", reduced=True)
    data = DataConfig(vocab=model.cfg.vocab, seq_len=64, global_batch=4,
                      n_hosts=1, seed=1)
    cfg = TrainerConfig(
        total_steps=total_steps,
        ckpt=CheckpointConfig(lineage="t", n_groups=2, every_steps=every,
                              cdc_params=CDC),
        train=TrainConfig(n_micro=n_micro,
                          adamw=AdamWConfig(lr=1e-3),
                          warmup_steps=5, total_steps=total_steps),
        fail_at_step=fail_at,
    )
    return Trainer(model, data, cfg, registry=registry)


class TestTraining:
    def test_loss_decreases(self):
        tr = _trainer(total_steps=30)
        tr.run()
        first = np.mean([m["loss"] for m in tr.metrics_log[:5]])
        last = np.mean([m["loss"] for m in tr.metrics_log[-5:]])
        assert last < first - 0.3, (first, last)

    def test_grad_accumulation_equivalent(self):
        """n_micro=2 must produce (nearly) the same first-step loss/grads as
        n_micro=1 on the same global batch."""
        t1 = _trainer(total_steps=1, n_micro=1)
        t2 = _trainer(total_steps=1, n_micro=2)
        t1.run(); t2.run()
        assert abs(t1.metrics_log[0]["loss"] - t2.metrics_log[0]["loss"]) < 1e-2


class TestFaultTolerance:
    def test_crash_restart_resumes_and_matches(self):
        """Train A: 12 steps straight.  Train B: crash at 7, restart from the
        step-5 checkpoint, continue.  Both must land on identical losses —
        checkpoint + stateless data pipeline make recovery exact."""
        reg_a = Registry()
        a = _trainer(registry=reg_a, total_steps=12)
        a.run()

        reg_b = Registry()
        b = _trainer(registry=reg_b, total_steps=12, fail_at=7)
        with pytest.raises(SimulatedFailure):
            b.run()
        # "restarted process": fresh Trainer against the same registry
        b2 = _trainer(registry=reg_b, total_steps=12)
        state = b2.init_or_restore()
        assert int(state.step) == 5          # resumed from checkpoint
        b2.run(state)

        # steps 10.. of both runs must match exactly
        la = [round(m["loss"], 5) for m in a.metrics_log[10:12]]
        lb = [round(m["loss"], 5) for m in b2.metrics_log[-2:]]
        assert la == lb

    def test_checkpoint_cadence(self):
        tr = _trainer(total_steps=12, every=4)
        tr.run()
        assert [i.step for i in tr.ckpt.history] == [4, 8, 12]

    def test_incremental_checkpoint_wire_properties(self):
        """Honest wire-cost invariants.  Dense AdamW perturbs every float
        between saves, so step-to-step chunk dedup is ~0 (measured; see
        bench_checkpoint_delivery) — the index/recipe overhead must stay
        bounded, and the *restore* path must be nearly free on a warm disk
        (that is where the paper's technique pays off for training)."""
        tr = _trainer(total_steps=10, every=2)
        tr.run()
        for i in tr.ckpt.history:
            assert i.total_wire_bytes < 1.15 * i.raw_bytes   # overhead ≤15%
        # warm-disk restore of the version just saved moves ~no chunks
        from repro.runtime.train_step import abstract_train_state
        abstract = abstract_train_state(tr.model, tr.cfg.train)
        _, _, wire = tr.ckpt.restore(abstract)
        assert sum(w.chunk_bytes for w in wire) == 0
        # a frozen-subset fork (the fine-tune case) dedups heavily
        import jax
        state = jax.tree.map(np.asarray, tr.init_or_restore()._asdict())
        info0 = tr.ckpt.save(state, step=100)
        state["params"]["lm_head"] = state["params"]["lm_head"] + 1e-3
        info1 = tr.ckpt.save(state, step=101)
        assert info1.savings_vs_raw > 0.5


class TestStraggler:
    def test_tracker_flags_slow_host(self):
        t = StragglerTracker(4, StragglerConfig(threshold=1.5, min_history=2))
        for _ in range(4):
            t.record_step([1.0, 1.0, 1.0, 3.0])
        assert t.stragglers() == [3]
        re = t.reassignment()
        assert 3 in re and re[3] != 3

    def test_no_false_positives(self):
        t = StragglerTracker(4)
        for _ in range(5):
            t.record_step([1.0, 1.1, 0.9, 1.05])
        assert t.stragglers() == []

    def test_recovers_when_speed_returns(self):
        t = StragglerTracker(2, StragglerConfig(threshold=1.5, ewma=0.3,
                                                min_history=2))
        for _ in range(3):
            t.record_step([1.0, 4.0])
        assert t.stragglers() == [1]
        for _ in range(6):
            t.record_step([1.0, 1.0])
        assert t.stragglers() == []

    def test_peer_fetch(self):
        """Chunk-granular peer serving (BitTorrent-style restore)."""
        rng = np.random.default_rng(0)
        data = rng.bytes(50_000)
        peer = Client(cdc_params=CDC)
        recipe = peer.commit("x", "v0", data)
        me = Client(cdc_params=CDC)
        served = peer_fetch(me, [peer], recipe.fps)
        assert len(served) == len(set(recipe.fps))
        me.store.recipes["x:v0"] = recipe
        assert me.store.restore("x:v0") == data
