"""Regression tests for the error-taxonomy contract the err-contract
analyzer enforces (`docs/CONTRACTS.md`): every public surface raises the
typed taxonomy (`DeliveryError` / `PushRejected` / `WireError` /
`JournalError` / `ValueError`), never a bare `KeyError` / `OSError`.

Each test here pins one escape path the analyzer found (and this PR
fixed), asserting both the exception *type* and a *message* a caller can
act on.  The analyzer proves no such path exists statically; these tests
prove the replacement behavior dynamically.
"""

import socket

import numpy as np
import pytest

from repro.core.cdmt import CDMTParams
from repro.core.errors import DeliveryError
from repro.core.registry import Registry
from repro.core.store import DedupStore
from repro.delivery import ImageClient, LocalTransport
from repro.delivery.net import SocketTransport

P = CDMTParams(window=4, rule_bits=2)


class TestRestorePaths:
    """`DedupStore.restore`/`restore_into` used to leak KeyError for an
    unknown recipe name and for a chunk dropped by GC."""

    def test_unknown_recipe_raises_delivery_error(self):
        store = DedupStore()
        with pytest.raises(DeliveryError, match="unknown recipe 'app:v9'"):
            store.restore("app:v9")

    def test_unknown_recipe_restore_into_raises_delivery_error(self):
        store = DedupStore()
        out = np.zeros(16, dtype=np.uint8)
        with pytest.raises(DeliveryError, match="unknown recipe"):
            store.restore_into("app:v9", out)

    def test_swept_chunk_raises_delivery_error_naming_the_chunk(self):
        store = DedupStore()
        store.ingest("app:v1", b"payload" * 4096)
        store.chunks.compact(live=set())        # GC drops every chunk
        with pytest.raises(DeliveryError,
                           match="restore app:v1: chunk .* is missing"):
            store.restore("app:v1")

    def test_swept_chunk_restore_into_raises_delivery_error(self):
        store = DedupStore()
        recipe = store.ingest("app:v1", b"payload" * 4096)
        store.chunks.compact(live=set())
        out = np.zeros(recipe.total_size, dtype=np.uint8)
        with pytest.raises(DeliveryError, match="is missing from the store"):
            store.restore_into("app:v1", out)


class TestClientPaths:
    """`ImageClient.index_for_tag` / `push` used to leak KeyError for a
    tag that was never committed or pulled locally."""

    def test_index_for_tag_unknown_raises_delivery_error(self):
        client = ImageClient(None, cdmt_params=P)
        with pytest.raises(DeliveryError,
                           match="'app:v9' has never been committed"):
            client.index_for_tag("app", "v9")

    def test_push_of_uncommitted_version_raises_delivery_error(self):
        client = ImageClient(LocalTransport(Registry(cdmt_params=P)),
                             cdmt_params=P)
        with pytest.raises(DeliveryError,
                           match=r"push app:v9: version was never committed"):
            client.push("app", "v9")

    def test_materialize_unknown_raises_delivery_error(self):
        client = ImageClient(None, cdmt_params=P)
        with pytest.raises(DeliveryError, match="unknown recipe"):
            client.materialize("app", "v9")


class TestTransportPaths:
    def test_local_fetch_of_unknown_chunk_raises_delivery_error(self):
        """`ChunkStore.get`'s KeyError must not reach the transport: the
        registry wraps it naming the fingerprint."""
        transport = LocalTransport(Registry(cdmt_params=P))
        with pytest.raises(DeliveryError,
                           match="cannot serve unknown chunk"):
            transport.fetch_chunks("app", "v1", [b"\x00" * 16])

    def test_socket_transport_dead_endpoint_raises_delivery_error(self):
        """Connection refusal surfaces as DeliveryError naming the
        endpoint, not a raw OSError from the socket layer."""
        probe = socket.socket()
        probe.bind(("127.0.0.1", 0))
        port = probe.getsockname()[1]
        probe.close()                          # nothing listens here now
        with pytest.raises(DeliveryError, match="cannot connect"):
            SocketTransport(("127.0.0.1", port)).tags("app")
