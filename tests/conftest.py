import os
import sys

# tests run on the single real CPU device; the dry-run (and only it) forces
# 512 placeholder devices in its own entrypoint.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
