"""Transport conformance: one ``ImageClient``, six ``Transport``s.

The same scenario must move the same chunks through every transport, with
byte counts equal up to framing overhead — and for the socket and mux
transports, equal to the wire transport's bytes **plus exactly the
envelope overhead** (plain or multiplexed respectively);
swarm pulls must survive provider death mid-pull (failover to the next
source, then the registry); a replicated pull must fan chunk reads across
journal-shipped standbys (and survive primary death by promotion — see
``tests/test_replication.py`` for the replication protocol itself); and the
server's restart warm-up must serve a recovered registry's first wave from
RAM.
"""

import threading
import time

import pytest

from repro.core import cdc, hashing
from repro.core.cdmt import CDMT, CDMTParams
from repro.core.errors import DeliveryError
from repro.core.registry import Registry
from repro.core.store import Recipe
from repro.delivery import (AsyncRegistryServer, FetchResult, ImageClient,
                            JournalFollower, LocalTransport,
                            MuxSocketTransport, PullPlan, RegistryServer,
                            ReplicatedTransport, SocketRegistryServer,
                            SocketTransport, SourceLeg, SwarmNode,
                            SwarmTracker, SwarmTransport, TransferReport,
                            WireTransport, swarm_pull, wire)

PARAMS = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)
P = CDMTParams(window=4, rule_bits=2)
TRANSPORTS = ["local", "wire", "socket", "mux", "swarm", "replicated"]


def _rand(n, seed=0):
    import numpy as np
    return np.random.default_rng(seed).integers(
        0, 256, size=n, dtype=np.uint8).tobytes()


def _versions(n_versions=5, size=150_000, seed=0):
    import numpy as np
    rng = np.random.default_rng(seed)
    data = bytearray(_rand(size, seed))
    out = [bytes(data)]
    for _ in range(n_versions - 1):
        for _ in range(3):
            pos = rng.integers(0, len(data) - 100)
            data[pos:pos + 64] = rng.bytes(64)
        ins = rng.integers(0, len(data))
        data[ins:ins] = rng.bytes(rng.integers(1, 256))
        out.append(bytes(data))
    return out


def _seed_registry(versions, lineage="app"):
    reg = Registry(cdmt_params=P)
    pub = ImageClient(LocalTransport(reg), cdc_params=PARAMS, cdmt_params=P)
    for i, v in enumerate(versions):
        pub.commit(lineage, f"v{i}", v)
        pub.push(lineage, f"v{i}")
    return reg


def _replicated_env(reg, n_standbys=2):
    """Primary + synced standbys, each behind its own socket server, plus a
    ``ReplicatedTransport`` over all of them (primary first).  Returns
    ``(transport, cleanup_objects)``."""
    servers = [SocketRegistryServer(RegistryServer(reg))]
    primary_wire = WireTransport(servers[0].server)
    for i in range(n_standbys):
        sreg = Registry(cdmt_params=P)
        # catch_up, not sync_once: the first standby's ack trims the
        # primary's log, so later standbys join via snapshot bootstrap
        JournalFollower(sreg, primary_wire, name=f"s{i}").catch_up()
        servers.append(SocketRegistryServer(RegistryServer(sreg)))
    transports = [SocketTransport(s.address) for s in servers]
    return ReplicatedTransport(transports), transports + servers


def _fresh_client(kind, reg, provisioned_tags=()):
    """A cold ImageClient over transport ``kind``.  For swarm, one peer is
    pre-provisioned per tag in ``provisioned_tags`` so providers exist.
    Socket/replicated clients carry their servers on ``_cleanup`` — call
    ``_cleanup_client`` when done."""
    if kind == "local":
        return ImageClient(LocalTransport(reg), cdc_params=PARAMS,
                           cdmt_params=P)
    if kind == "replicated":
        transport, cleanup = _replicated_env(reg)
        cl = ImageClient(transport, cdc_params=PARAMS, cdmt_params=P)
        cl._cleanup = cleanup
        return cl
    srv = RegistryServer(reg)
    if kind == "wire":
        return ImageClient(WireTransport(srv), cdc_params=PARAMS,
                           cdmt_params=P)
    if kind == "socket":
        sock_srv = SocketRegistryServer(srv)
        transport = SocketTransport(sock_srv.address)
        cl = ImageClient(transport, cdc_params=PARAMS, cdmt_params=P)
        cl._cleanup = (transport, sock_srv)
        return cl
    if kind == "mux":
        asrv = AsyncRegistryServer(srv)
        transport = MuxSocketTransport(asrv.address)
        cl = ImageClient(transport, cdc_params=PARAMS, cdmt_params=P)
        cl._cleanup = (transport, asrv)
        return cl
    tracker = SwarmTracker()
    for i, tag in enumerate(provisioned_tags):
        peer = SwarmNode(f"seed{i}", cdc_params=PARAMS, cdmt_params=P)
        swarm_pull(peer, srv, tracker, "app", tag)
    node = SwarmNode("me", cdc_params=PARAMS, cdmt_params=P)
    transport = SwarmTransport(node, tracker, srv)
    return ImageClient(transport, store=node.client.store,
                       indexes=node.client.indexes,
                       tag_trees=node.client.tag_trees,
                       cdc_params=PARAMS, cdmt_params=P)


def _close_all(objs):
    for obj in objs:                          # transports first, then servers
        for meth in ("close", "stop"):
            fn = getattr(obj, meth, None)
            if fn is not None:
                fn()


def _cleanup_client(cl):
    _close_all(getattr(cl, "_cleanup", ()))


# ------------------------------------------------------------- conformance

class TestConformance:
    @pytest.fixture(scope="class")
    def scenario(self):
        """Cold pull of v0 then warm upgrade to the head, once per
        transport, against identically-seeded registries."""
        versions = _versions(6, seed=40)
        head = f"v{len(versions) - 1}"
        out = {}
        for kind in TRANSPORTS:
            reg = _seed_registry(versions)
            cl = _fresh_client(kind, reg, provisioned_tags=("v0", head))
            try:
                cold = cl.pull("app", "v0")
                warm = cl.pull("app", head)
                out[kind] = {
                    "cold": cold, "warm": warm,
                    "v0": cl.materialize("app", "v0"),
                    "head": cl.materialize("app", head),
                }
            finally:
                _cleanup_client(cl)
        return versions, out

    def test_materialization_identical(self, scenario):
        versions, out = scenario
        for kind in TRANSPORTS:
            assert out[kind]["v0"] == versions[0], kind
            assert out[kind]["head"] == versions[-1], kind

    def test_identical_chunks_moved(self, scenario):
        _, out = scenario
        for phase in ("cold", "warm"):
            moved = {k: out[k][phase].chunks_moved for k in TRANSPORTS}
            assert len(set(moved.values())) == 1, moved
            totals = {k: out[k][phase].chunks_total for k in TRANSPORTS}
            assert len(set(totals.values())) == 1, totals
            comps = {k: out[k][phase].comparisons for k in TRANSPORTS}
            assert len(set(comps.values())) == 1, comps

    def test_index_and_recipe_bytes_exact_local_vs_wire(self, scenario):
        """The local transport's arithmetic sizing must equal the wire
        transport's real frame lengths byte-for-byte."""
        _, out = scenario
        for phase in ("cold", "warm"):
            a, b = out["local"][phase], out["wire"][phase]
            assert a.index_bytes == b.index_bytes
            assert a.recipe_bytes == b.recipe_bytes
            assert a.chunk_bytes == b.chunk_bytes

    def test_chunk_bytes_within_framing_overhead(self, scenario):
        _, out = scenario
        for phase in ("cold", "warm"):
            ref = out["local"][phase].chunk_bytes
            for kind in TRANSPORTS:
                got = out[kind][phase].chunk_bytes
                assert abs(got - ref) <= 0.02 * ref + 512, (kind, phase)

    def test_reports_carry_transport_and_sources(self, scenario):
        _, out = scenario
        for kind in TRANSPORTS:
            rep = out[kind]["warm"]
            assert isinstance(rep, TransferReport)
            assert rep.transport == kind
            assert sum(l.chunks for l in rep.sources.values()) \
                == rep.chunks_moved
            assert sum(l.chunk_bytes for l in rep.sources.values()) \
                == rep.chunk_bytes

    def test_swarm_pulled_mostly_from_peers(self, scenario):
        _, out = scenario
        warm = out["swarm"]["warm"]
        assert warm.chunks_from_peers >= 0.5 * warm.chunks_moved
        assert warm.peer_offload_fraction >= 0.5


class TestSocketConformance:
    """The socket transport's acceptance gate: same chunks as local/wire
    over real TCP, bytes equal to the wire path plus exactly the envelope
    overhead, plans quoted to the byte, and a mid-pull server death that
    commits nothing."""

    def _socket_client(self, reg, **transport_kw):
        srv = RegistryServer(reg)
        sock_srv = SocketRegistryServer(srv)
        transport = SocketTransport(sock_srv.address, **transport_kw)
        cl = ImageClient(transport, cdc_params=PARAMS, cdmt_params=P)
        cl._cleanup = (transport, sock_srv)
        return cl, srv, sock_srv

    def test_socket_bytes_are_wire_bytes_plus_envelope(self):
        versions = _versions(4, seed=58)
        wire_cl = _fresh_client("wire", _seed_registry(versions))
        sock_cl = _fresh_client("socket", _seed_registry(versions))
        try:
            wplan = wire_cl.plan_pull("app", "v0")
            wrep = wire_cl.execute(wplan)
            splan = sock_cl.plan_pull("app", "v0")
            srep = sock_cl.execute(splan)
            assert splan.missing == wplan.missing

            # chunk traffic: same CHUNK_BATCH frames, plus one response
            # envelope per WANT round
            size_of = dict(zip(splan.recipe.fps, splan.recipe.sizes))
            sizes = [size_of[fp] for fp in splan.missing]
            sub = sock_cl.transport.response_batch_chunks
            envelope = 0
            for start in range(0, len(sizes), sock_cl.batch_chunks):
                lens = wire.chunk_batch_frame_lens(
                    sizes[start:start + sock_cl.batch_chunks], sub)
                envelope += wire.response_envelope_bytes(lens) - sum(lens)
            assert srep.chunk_bytes == wrep.chunk_bytes + envelope

            # control traffic: the same INDEX/RECIPE frame, plus request
            # envelope (new on socket) and response envelope
            for sock_b, frame_len in ((srep.index_bytes, wrep.index_bytes),
                                      (srep.recipe_bytes, wrep.recipe_bytes)):
                assert sock_b == (
                    wire.request_envelope_bytes("app", "v0", [])
                    + wire.response_envelope_bytes([frame_len]))
        finally:
            _cleanup_client(wire_cl)
            _cleanup_client(sock_cl)

    def test_plan_quote_exact_with_server_split_and_envelope(self):
        """Client batches larger than the server's response split stream as
        several frames inside one envelope — the plan quotes all of it."""
        versions = _versions(3, seed=59)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg, max_batch_chunks=16)
        sock_srv = SocketRegistryServer(srv)
        transport = SocketTransport(sock_srv.address, batch_chunks=256)
        try:
            cl = ImageClient(transport, cdc_params=PARAMS, cdmt_params=P,
                             batch_chunks=256)
            assert transport.response_batch_chunks == 16   # INFO handshake
            plan = cl.plan_pull("app", "v2")
            assert plan.chunks_to_fetch > 16               # forces a split
            report = cl.execute(plan)
            assert (report.index_bytes + report.recipe_bytes
                    + report.chunk_bytes) == plan.expected_wire_bytes
        finally:
            transport.close()
            sock_srv.stop()

    def test_mid_pull_server_death_commits_nothing(self):
        """The server dies after streaming one CHUNK_BATCH of a multi-frame
        response: the client must surface DeliveryError (not hang, not a
        bare socket error) with nothing committed to the local store."""
        versions = _versions(3, seed=60)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg, max_batch_chunks=8)
        sock_srv = SocketRegistryServer(srv)
        transport = SocketTransport(sock_srv.address, batch_chunks=1024)
        try:
            cl = ImageClient(transport, cdc_params=PARAMS, cdmt_params=P,
                             batch_chunks=1024)
            plan = cl.plan_pull("app", "v0")
            assert plan.chunks_to_fetch > 8    # multi-frame response

            real_want_plan = srv.want_plan

            def dying_want_plan(want_frame):
                n, frames = real_want_plan(want_frame)

                def die_after_first():
                    yield next(iter(frames))
                    raise RuntimeError("registry crashed mid-stream")

                return n, die_after_first()

            srv.want_plan = dying_want_plan
            chunks_before = cl.store.chunks.n_chunks()
            with pytest.raises(DeliveryError):
                cl.execute(plan)
            assert "app:v0" not in cl.store.recipes
            assert cl.store.chunks.n_chunks() == chunks_before
            assert "app" not in cl.indexes
        finally:
            transport.close()
            sock_srv.stop()

    def test_mux_bytes_are_wire_bytes_plus_mux_envelope(self):
        """The multiplexed transport's byte accounting must relate to the
        frame-level wire transport exactly like the plain socket's does —
        same frames, plus exactly the mux envelope (HEADER + per-frame
        FRAME messages, fixed-width stream ids)."""
        versions = _versions(4, seed=58)
        wire_cl = _fresh_client("wire", _seed_registry(versions))
        mux_cl = _fresh_client("mux", _seed_registry(versions))
        try:
            wplan = wire_cl.plan_pull("app", "v0")
            wrep = wire_cl.execute(wplan)
            mplan = mux_cl.plan_pull("app", "v0")
            mrep = mux_cl.execute(mplan)
            assert mplan.missing == wplan.missing

            size_of = dict(zip(mplan.recipe.fps, mplan.recipe.sizes))
            sizes = [size_of[fp] for fp in mplan.missing]
            sub = mux_cl.transport.response_batch_chunks
            envelope = 0
            for start in range(0, len(sizes), mux_cl.batch_chunks):
                lens = wire.chunk_batch_frame_lens(
                    sizes[start:start + mux_cl.batch_chunks], sub)
                envelope += wire.mux_response_envelope_bytes(lens) - sum(lens)
            assert mrep.chunk_bytes == wrep.chunk_bytes + envelope

            for mux_b, frame_len in ((mrep.index_bytes, wrep.index_bytes),
                                     (mrep.recipe_bytes, wrep.recipe_bytes)):
                assert mux_b == (
                    wire.mux_request_envelope_bytes("app", "v0", [])
                    + wire.mux_response_envelope_bytes([frame_len]))
        finally:
            _cleanup_client(wire_cl)
            _cleanup_client(mux_cl)

    def test_mux_plan_quote_exact_with_server_split(self):
        versions = _versions(3, seed=59)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg, max_batch_chunks=16)
        asrv = AsyncRegistryServer(srv)
        transport = MuxSocketTransport(asrv.address, batch_chunks=256)
        try:
            cl = ImageClient(transport, cdc_params=PARAMS, cdmt_params=P,
                             batch_chunks=256)
            assert transport.response_batch_chunks == 16   # INFO handshake
            plan = cl.plan_pull("app", "v2")
            assert plan.chunks_to_fetch > 16               # forces a split
            report = cl.execute(plan)
            assert (report.index_bytes + report.recipe_bytes
                    + report.chunk_bytes) == plan.expected_wire_bytes
        finally:
            transport.close()
            asrv.stop()

    def test_mux_mid_pull_server_death_commits_nothing(self):
        """A handler crash after the stream header committed its frame
        count kills the connection; the client must surface DeliveryError
        with nothing committed — identical to the threaded contract."""
        versions = _versions(3, seed=60)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg, max_batch_chunks=8)
        asrv = AsyncRegistryServer(srv)
        transport = MuxSocketTransport(asrv.address, batch_chunks=1024)
        try:
            cl = ImageClient(transport, cdc_params=PARAMS, cdmt_params=P,
                             batch_chunks=1024)
            plan = cl.plan_pull("app", "v0")
            assert plan.chunks_to_fetch > 8    # multi-frame response

            real_want_plan = srv.want_plan

            def dying_want_plan(want_frame):
                n, frames = real_want_plan(want_frame)

                def die_after_first():
                    yield next(iter(frames))
                    raise RuntimeError("registry crashed mid-stream")

                return n, die_after_first()

            srv.want_plan = dying_want_plan
            chunks_before = cl.store.chunks.n_chunks()
            with pytest.raises(DeliveryError):
                cl.execute(plan)
            assert "app:v0" not in cl.store.recipes
            assert cl.store.chunks.n_chunks() == chunks_before
            assert "app" not in cl.indexes
        finally:
            transport.close()
            asrv.stop()

    def test_swarm_over_socket_registry_fallback(self):
        """SwarmTransport composes peers over *any* registry transport —
        here the fallback crosses a real socket."""
        versions = _versions(3, seed=61)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        sock_srv = SocketRegistryServer(srv)
        fallback = SocketTransport(sock_srv.address)
        try:
            tracker = SwarmTracker()
            node = SwarmNode("s0", cdc_params=PARAMS, cdmt_params=P)
            transport = SwarmTransport(node, tracker, fallback)
            cl = ImageClient(transport, store=node.client.store,
                             indexes=node.client.indexes,
                             tag_trees=node.client.tag_trees,
                             cdc_params=PARAMS, cdmt_params=P)
            rep = cl.pull("app", "v2")
            assert cl.materialize("app", "v2") == versions[2]
            assert rep.transport == "swarm"
            assert rep.registry_chunk_bytes > 0    # fallback carried it
            # the next swarm puller rides the first as a peer, fetching
            # only the remainder over the socket
            node2 = SwarmNode("s1", cdc_params=PARAMS, cdmt_params=P)
            t2 = SwarmTransport(node2, tracker, fallback)
            cl2 = ImageClient(t2, store=node2.client.store,
                              indexes=node2.client.indexes,
                              tag_trees=node2.client.tag_trees,
                              cdc_params=PARAMS, cdmt_params=P)
            rep2 = cl2.pull("app", "v2")
            assert cl2.materialize("app", "v2") == versions[2]
            assert rep2.chunks_from_peers > 0
        finally:
            fallback.close()
            sock_srv.stop()


# ------------------------------------------- snapshot-bootstrapped standby

class TestBootstrappedStandby:
    """A standby that joined via snapshot bootstrap (the primary's log was
    trimmed, so no offset-0 history existed to replay) must be
    indistinguishable from a history-replayed one: byte-identical pulls on
    every remote transport, and exact plan quotes through the replicated
    transport."""

    def _bootstrapped_standby(self, versions):
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        t = WireTransport(srv)
        # every record acked -> the log trims to its head; the fresh
        # standby below cannot replay history and must bootstrap
        t.ack_journal("acked", reg.replication.epoch, reg.replication.head())
        assert reg.replication.base == reg.replication.head()
        sreg = Registry(cdmt_params=P)
        JournalFollower(sreg, t, name="s0").catch_up()
        assert srv.snapshot().snapshot_requests == 1
        return reg, sreg

    @pytest.mark.parametrize("kind", ["wire", "socket", "mux"])
    def test_serves_byte_identical_pulls(self, kind):
        versions = _versions(4, seed=67)
        reg, sreg = self._bootstrapped_standby(versions)
        head = f"v{len(versions) - 1}"
        ref_cl = _fresh_client(kind, reg)
        cl = _fresh_client(kind, sreg)
        try:
            for tag, data in (("v0", versions[0]), (head, versions[-1])):
                want = ref_cl.pull("app", tag)
                got = cl.pull("app", tag)
                assert cl.materialize("app", tag) == data
                assert got.index_bytes == want.index_bytes
                assert got.recipe_bytes == want.recipe_bytes
                assert got.chunk_bytes == want.chunk_bytes
                assert got.chunks_moved == want.chunks_moved
        finally:
            _cleanup_client(ref_cl)
            _cleanup_client(cl)

    def test_replicated_plan_quote_exact_with_bootstrapped_replica(self):
        """``_replicated_env``'s second standby joins via bootstrap (the
        first standby's ack trimmed the log) — the replicated plan must
        still quote socket bytes to the byte, and the bootstrapped standby
        must pass the freshness probe like any other replica."""
        versions = _versions(3, seed=68)
        reg = _seed_registry(versions)
        rt, cleanup = _replicated_env(reg)
        try:
            assert reg.replication.base > 0     # the log really was trimmed
            cl = ImageClient(rt, cdc_params=PARAMS, cdmt_params=P)
            plan = cl.plan_pull("app", "v2")
            report = cl.execute(plan)
            assert (report.index_bytes + report.recipe_bytes
                    + report.chunk_bytes) == plan.expected_wire_bytes
            assert cl.materialize("app", "v2") == versions[2]
            assert rt.stale_detected == 0   # bootstrapped standby is fresh
        finally:
            _close_all(cleanup)

    def test_quote_chunk_batches_routes_per_replica(self):
        versions = _versions(2, seed=69)
        reg = _seed_registry(versions)
        rt, cleanup = _replicated_env(reg)
        try:
            sizes = [500, 9_000, 3, 70_000]
            assert rt.quote_chunk_batches(sizes) \
                == rt.primary_transport.quote_chunk_batches(sizes)
            for i, t in enumerate(rt.replicas):
                assert rt.quote_chunk_batches(sizes, replica=i) \
                    == t.quote_chunk_batches(sizes)
            with pytest.raises(ValueError):
                rt.quote_chunk_batches(sizes, replica=len(rt.replicas))
        finally:
            _close_all(cleanup)


class TestPushConformance:
    @pytest.mark.parametrize("kind", TRANSPORTS)
    def test_push_lands_identically(self, kind):
        versions = _versions(3, seed=41)
        reg = Registry(cdmt_params=P)
        cleanup = []
        if kind == "local":
            transport = LocalTransport(reg)
        elif kind == "wire":
            transport = WireTransport(RegistryServer(reg))
        elif kind == "socket":
            sock_srv = SocketRegistryServer(RegistryServer(reg))
            transport = SocketTransport(sock_srv.address)
            cleanup = [transport, sock_srv]
        elif kind == "replicated":
            # pushes route to the primary; standbys never see them directly
            transport, cleanup = _replicated_env(reg)
        else:
            node = SwarmNode("pub", cdc_params=PARAMS, cdmt_params=P)
            transport = SwarmTransport(node, SwarmTracker(),
                                       RegistryServer(reg))
        try:
            pub = ImageClient(transport, cdc_params=PARAMS, cdmt_params=P)
            reference = _seed_registry(versions)
            for i, v in enumerate(versions):
                pub.commit("app", f"v{i}", v)
                st = pub.push("app", f"v{i}")
                assert st.chunks_moved <= st.chunks_total
            assert reg.tags("app") == reference.tags("app")
            for tag in reg.tags("app"):
                assert reg.index_for_tag("app", tag).root \
                    == reference.index_for_tag("app", tag).root
        finally:
            _close_all(cleanup)

    @pytest.mark.parametrize("kind", ["local", "wire"])
    def test_has_chunks_gives_cross_lineage_push_dedup(self, kind):
        """A push ships only chunks the backend truly lacks — shared chunks
        already stored under another lineage stay home."""
        base = _rand(100_000, seed=42)
        reg = Registry(cdmt_params=P)
        transport = (LocalTransport(reg) if kind == "local"
                     else WireTransport(RegistryServer(reg)))
        pub = ImageClient(transport, cdc_params=PARAMS, cdmt_params=P)
        pub.commit("a", "v0", base)
        pub.push("a", "v0")
        pub.commit("b", "v0", base + _rand(10_000, seed=43))
        st = pub.push("b", "v0")
        # lineage b is new (no index to diff against) yet most chunks are
        # already stored under lineage a — the presence check finds them
        assert st.chunks_moved < 0.5 * st.chunks_total
        assert st.want_bytes >= 0
        fresh = ImageClient(transport, cdc_params=PARAMS, cdmt_params=P)
        fresh.pull("b", "v0")
        assert fresh.materialize("b", "v0") == pub.materialize("b", "v0")


# ------------------------------------------------------------ plan/execute

class TestPlanExecute:
    def test_plan_is_inspectable_and_execute_matches(self):
        versions = _versions(4, seed=44)
        reg = _seed_registry(versions)
        cl = _fresh_client("wire", reg)
        cl.pull("app", "v0")
        plan = cl.plan_pull("app", "v3")
        assert isinstance(plan, PullPlan)
        assert 0 < plan.chunks_to_fetch < plan.chunks_total
        assert plan.comparisons > 0
        assert plan.expected_chunk_bytes < plan.raw_bytes
        # nothing moved yet: planning is free of data-plane traffic
        assert "app:v3" not in cl.store.recipes
        report = cl.execute(plan)
        assert report.chunks_moved == plan.chunks_to_fetch
        assert report.comparisons == plan.comparisons
        # the plan's quote is exact (want/control frames excluded by design)
        assert (report.index_bytes + report.recipe_bytes
                + report.chunk_bytes) == plan.expected_wire_bytes
        assert cl.materialize("app", "v3") == versions[3]

    def test_plan_quote_exact_for_local_too(self):
        versions = _versions(3, seed=45)
        reg = _seed_registry(versions)
        cl = _fresh_client("local", reg)
        plan = cl.plan_pull("app", "v0")
        report = cl.execute(plan)
        assert (report.index_bytes + report.recipe_bytes
                + report.chunk_bytes) == plan.expected_wire_bytes

    def test_plan_quote_exact_when_server_splits_batches(self):
        """A client request batch larger than the server's response batch
        limit gets split into more frames — the plan must quote that."""
        versions = _versions(3, seed=48)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg, max_batch_chunks=16)
        cl = ImageClient(WireTransport(srv), cdc_params=PARAMS,
                         cdmt_params=P, batch_chunks=256)
        plan = cl.plan_pull("app", "v2")
        assert plan.chunks_to_fetch > 16          # forces a server split
        report = cl.execute(plan)
        assert (report.index_bytes + report.recipe_bytes
                + report.chunk_bytes) == plan.expected_wire_bytes

    def test_plan_wrong_transport_rejected(self):
        versions = _versions(2, seed=46)
        reg = _seed_registry(versions)
        plan = _fresh_client("local", reg).plan_pull("app", "v0")
        with pytest.raises(DeliveryError):
            _fresh_client("wire", reg).execute(plan)

    def test_upgrade_pulls_head(self):
        versions = _versions(4, seed=47)
        reg = _seed_registry(versions)
        cl = _fresh_client("wire", reg)
        rep = cl.upgrade("app")
        assert rep.tag == "v3"
        assert cl.materialize("app", "v3") == versions[3]
        with pytest.raises(DeliveryError):
            cl.upgrade("ghost")


# ---------------------------------------------------------------- failover

class TestFailover:
    def _swarm_env(self, n_versions=4, seed=50):
        versions = _versions(n_versions, seed=seed)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        tracker = SwarmTracker()
        head = f"v{n_versions - 1}"
        peer = SwarmNode("p0", cdc_params=PARAMS, cdmt_params=P)
        swarm_pull(peer, srv, tracker, "app", head)
        return versions, srv, tracker, peer, head

    def test_dead_peer_falls_over_to_registry(self):
        versions, srv, tracker, peer, head = self._swarm_env()
        peer.kill()
        node = SwarmNode("n1", cdc_params=PARAMS, cdmt_params=P)
        st = swarm_pull(node, srv, tracker, "app", head, batch_chunks=16)
        assert node.client.materialize("app", head) == versions[-1]
        assert st.failovers >= 1
        assert st.chunks_from_peers == 0
        assert st.peer_offload_fraction == 0.0
        assert st.registry_chunk_bytes > 0
        leg = st.sources[f"peer:{peer.name}"]
        assert leg.failures >= 1 and leg.chunks == 0

    def test_peer_dies_mid_pull(self):
        """The provider answers the first batch, then goes dark — the pull
        must complete against the registry with the death recorded as a
        failover, not fail or hang."""
        versions, srv, tracker, peer, head = self._swarm_env()
        real_serve = peer.serve_want
        served = []

        def dying_serve(want_frame):
            if served:
                peer.kill()
            served.append(1)
            return real_serve(want_frame)

        peer.serve_want = dying_serve
        node = SwarmNode("n1", cdc_params=PARAMS, cdmt_params=P)
        st = swarm_pull(node, srv, tracker, "app", head, batch_chunks=8)
        assert node.client.materialize("app", head) == versions[-1]
        assert st.chunks_from_peers > 0          # first batch came from it
        assert st.failovers >= 1                 # later batches hit the corpse
        assert st.registry_chunk_bytes > 0       # registry served the rest
        assert st.chunks_moved == st.chunks_total

    def test_dead_provider_benched_after_threshold_then_revived(self):
        """Tracker health (churn): a provider that keeps failing is benched
        after ``failure_threshold`` consecutive failures — later batches and
        later pullers stop paying one failed round each — and ``revive()``
        re-registers it on every tracker it joined."""
        versions, srv, tracker, peer, head = self._swarm_env()
        peer.kill()
        node = SwarmNode("n1", cdc_params=PARAMS, cdmt_params=P)
        st = swarm_pull(node, srv, tracker, "app", head, batch_chunks=8)
        assert node.client.materialize("app", head) == versions[-1]
        # enough batches ran to exceed the threshold many times over, but
        # the corpse only cost threshold failed rounds before the bench
        assert st.rounds > tracker.failure_threshold
        assert st.failovers == tracker.failure_threshold
        assert tracker.is_benched(peer)
        # a benched provider is invisible to the next puller
        node2 = SwarmNode("n2", cdc_params=PARAMS, cdmt_params=P)
        st2 = swarm_pull(node2, srv, tracker, "app", head, batch_chunks=8)
        assert st2.failovers == 0
        assert f"peer:{peer.name}" not in st2.sources
        # revive: back online, backoff cleared, serving again
        peer.revive()
        assert not tracker.is_benched(peer)
        node3 = SwarmNode("n3", cdc_params=PARAMS, cdmt_params=P)
        st3 = swarm_pull(node3, srv, tracker, "app", head, batch_chunks=8)
        assert st3.failovers == 0
        assert st3.chunks_from_peers > 0

    def test_success_resets_failure_streak(self):
        """Failures must be *consecutive* to bench: a flaky peer that
        recovers before the threshold keeps serving."""
        versions, srv, tracker, peer, head = self._swarm_env()
        for _ in range(tracker.failure_threshold - 1):
            tracker.report_failure(peer)
        assert not tracker.is_benched(peer)
        tracker.report_success(peer)
        assert tracker.consecutive_failures(peer) == 0
        for _ in range(tracker.failure_threshold - 1):
            tracker.report_failure(peer)
        assert not tracker.is_benched(peer)
        assert peer in tracker.providers("app", head)

    def test_live_provider_preferred_over_dead(self):
        """The tracker orders live nodes ahead of dead ones in each tier, so
        a lingering corpse neither crowds out the live provider nor costs a
        failed round when the live one can serve everything."""
        versions, srv, tracker, peer, head = self._swarm_env()
        backup = SwarmNode("p1", cdc_params=PARAMS, cdmt_params=P)
        swarm_pull(backup, srv, tracker, "app", head)
        peer.kill()
        node = SwarmNode("n2", cdc_params=PARAMS, cdmt_params=P)
        st = swarm_pull(node, srv, tracker, "app", head, batch_chunks=16)
        assert node.client.materialize("app", head) == versions[-1]
        # the live provider served the bytes; the corpse was never consulted
        assert st.failovers == 0
        assert st.chunks_from_peers == st.chunks_moved
        assert st.sources[f"peer:{backup.name}"].chunks > 0
        assert f"peer:{peer.name}" not in st.sources


# ---------------------------------------------------------- pipeline bound


class _CountingTransport:
    """Fake transport serving canned chunks, counting fetch rounds."""

    name = "fake"
    verifies_payloads = True

    def __init__(self, chunks):
        self.chunks = dict(chunks)
        self.calls = 0
        self._lock = threading.Lock()

    def fetch_chunks(self, lineage, tag, fps):
        with self._lock:
            self.calls += 1
        time.sleep(0.002)                   # give the pipeline time to race
        got = {fp: self.chunks[fp] for fp in fps if fp in self.chunks}
        leg = SourceLeg(source="registry", chunks=len(got),
                        chunk_bytes=sum(len(v) for v in got.values()),
                        rounds=1)
        return FetchResult(chunks=got, legs=[leg])

    def notify_pulled(self, lineage, tag):
        pass


class TestPipelineBound:
    def _plan(self, n_chunks=24):
        payloads = [bytes([i]) * (50 + i) for i in range(n_chunks)]
        fps = [hashing.chunk_fingerprint(d) for d in payloads]
        recipe = Recipe(name="app:v0", fps=fps,
                        sizes=[len(d) for d in payloads])
        plan = PullPlan(lineage="app", tag="v0", transport="fake",
                        index=CDMT.build(fps, params=P), recipe=recipe,
                        missing=list(fps), chunks_total=len(fps),
                        raw_bytes=sum(recipe.sizes))
        return plan, dict(zip(fps, payloads)), b"".join(payloads)

    def test_at_most_pipeline_depth_batches_in_flight(self, monkeypatch):
        """The documented bound is ``pipeline_depth`` batches in flight;
        the old loop drained only *after* submitting, keeping depth+1."""
        from repro.delivery import client as client_mod
        outstanding = {"now": 0, "max": 0}
        lock = threading.Lock()
        real_executor = client_mod.ThreadPoolExecutor

        class ProbeFuture:
            def __init__(self, fut):
                self._fut = fut

            def result(self):
                out = self._fut.result()
                with lock:
                    outstanding["now"] -= 1
                return out

        class ProbeExecutor(real_executor):
            def submit(self, fn, *args, **kw):
                with lock:
                    outstanding["now"] += 1
                    outstanding["max"] = max(outstanding["max"],
                                             outstanding["now"])
                return ProbeFuture(super().submit(fn, *args, **kw))

        monkeypatch.setattr(client_mod, "ThreadPoolExecutor", ProbeExecutor)
        plan, chunks, raw = self._plan()
        transport = _CountingTransport(chunks)
        cl = ImageClient(transport, cdc_params=PARAMS, cdmt_params=P,
                         batch_chunks=2, pipeline_depth=3)
        report = cl.execute(plan)
        assert transport.calls == 12            # 24 chunks / batches of 2
        assert report.chunks_moved == 24
        assert outstanding["max"] == 3          # == depth, never depth + 1
        assert cl.materialize("app", "v0") == raw


# ----------------------------------------------------------- push integrity


class TestPushLocalStore:
    def test_missing_local_candidate_is_delivery_error(self):
        """A candidate fp the local store cannot produce must fail as a
        protocol-level DeliveryError naming the fp, not a bare KeyError."""
        reg = Registry(cdmt_params=P)
        cl = ImageClient(LocalTransport(reg), cdc_params=PARAMS,
                         cdmt_params=P)
        recipe = cl.commit("app", "v0", _rand(60_000, seed=62))
        victim = recipe.fps[0]
        real_get = cl.store.chunks.get

        def missing_get(fp):
            if fp == victim:
                raise KeyError(fp)
            return real_get(fp)

        cl.store.chunks.get = missing_get
        with pytest.raises(DeliveryError) as ei:
            cl.push("app", "v0")
        assert victim.hex()[:12] in str(ei.value)


# ------------------------------------------------------- tag-listing frames


class TestTagsFrames:
    def test_wire_tags_are_metered_protocol_data(self):
        """Tag queries flow through TAGS/TAG_LIST frames and the server's
        meters — not an attribute reach into the registry."""
        versions = _versions(2, seed=63)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        t = WireTransport(srv)
        s0 = srv.snapshot()
        assert t.tags("app") == ["v0", "v1"]
        s1 = srv.snapshot()
        assert s1.tags_requests == s0.tags_requests + 1
        assert s1.ingress_bytes > s0.ingress_bytes
        assert s1.egress_bytes > s0.egress_bytes
        assert t.tags("ghost") == []


# ------------------------------------------------------- HAS/MISSING frames

class TestPresenceFrames:
    def test_roundtrip(self):
        fps = [hashing.chunk_fingerprint(bytes([i])) for i in range(9)]
        assert wire.decode_has(wire.encode_has(fps)) == fps
        assert wire.decode_missing(wire.encode_missing(fps)) == fps
        with pytest.raises(wire.WireError):
            wire.decode_has(wire.encode_missing(fps))   # type mismatch
        with pytest.raises(wire.WireError):
            wire.decode_has(wire.encode_has(fps)[:-1])  # truncation

    def test_server_answers_presence(self):
        versions = _versions(2, seed=51)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        known = next(iter(reg.store.chunks.fingerprints()))
        ghost = hashing.chunk_fingerprint(b"never pushed")
        resp = srv.handle_has(wire.encode_has([known, ghost]))
        assert wire.decode_missing(resp) == [ghost]
        assert srv.snapshot().has_requests == 1


# ----------------------------------------------------------- restart warm-up

class TestWarmStart:
    def _durable_registry(self, tmp_path, versions):
        reg = Registry(directory=str(tmp_path), cdmt_params=P)
        pub = ImageClient(LocalTransport(reg), cdc_params=PARAMS,
                          cdmt_params=P)
        for i, v in enumerate(versions):
            pub.commit("app", f"v{i}", v)
            pub.push("app", f"v{i}")
        reg.close()
        return Registry(directory=str(tmp_path), cdmt_params=P)

    def test_recovered_registry_serves_from_warm_cache(self, tmp_path):
        versions = _versions(3, seed=52)
        reg = self._durable_registry(tmp_path, versions)
        try:
            srv = RegistryServer(reg)
            s0 = srv.snapshot()
            assert s0.warmed_chunks == reg.store.chunks.n_chunks()
            cl = ImageClient(WireTransport(srv), cdc_params=PARAMS,
                             cdmt_params=P)
            cl.pull("app", "v2")
            assert cl.materialize("app", "v2") == versions[2]
            s = srv.snapshot()
            assert s.warm_hits > 0
            # the whole working set was pre-warmed: no cold store reads
            assert srv.cache.stats.misses == 0
        finally:
            reg.close()

    def test_warm_start_opt_out(self, tmp_path):
        versions = _versions(2, seed=53)
        reg = self._durable_registry(tmp_path, versions)
        try:
            srv = RegistryServer(reg, warm_start=False)
            assert srv.snapshot().warmed_chunks == 0
            assert srv.cache.stats.resident_bytes == 0
        finally:
            reg.close()

    def test_warm_start_respects_capacity(self, tmp_path):
        versions = _versions(3, seed=54)
        reg = self._durable_registry(tmp_path, versions)
        try:
            srv = RegistryServer(reg, cache_bytes=20_000)
            s = srv.snapshot()
            assert 0 < s.warmed_chunks < reg.store.chunks.n_chunks()
            assert srv.cache.stats.resident_bytes <= 20_000
        finally:
            reg.close()

    def test_memory_registry_not_warmed(self):
        versions = _versions(2, seed=55)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        assert srv.snapshot().warmed_chunks == 0

    def _chunked_store(self, tmp_path, small_n=20, small_size=1000,
                       big_size=50_000):
        """A durable store whose most recent chunk is far larger than the
        warm budget, with plenty of older small chunks behind it."""
        reg = Registry(directory=str(tmp_path), cdmt_params=P)
        smalls = []
        for i in range(small_n):
            data = _rand(small_size, seed=100 + i)
            reg.store.chunks.put(hashing.chunk_fingerprint(data), data)
            smalls.append(hashing.chunk_fingerprint(data))
        big = _rand(big_size, seed=99)
        big_fp = hashing.chunk_fingerprint(big)
        reg.store.chunks.put(big_fp, big)          # most recently appended
        reg.close()
        return Registry(directory=str(tmp_path), cdmt_params=P), big_fp

    def test_warm_skips_oversized_recent_chunk(self, tmp_path):
        """Regression: one big recent chunk used to stop warming at the
        first reject, leaving the rest of the budget cold even though many
        smaller older chunks still fit."""
        reg, big_fp = self._chunked_store(tmp_path)
        try:
            srv = RegistryServer(reg, cache_bytes=10_000)
            s = srv.snapshot()
            assert s.warmed_chunks >= 9            # ~10 × 1000B fit
            assert big_fp not in srv.cache.resident_fps()
            assert srv.cache.stats.resident_bytes <= 10_000
        finally:
            reg.close()

    def test_warm_scan_limit_bounds_startup(self, tmp_path):
        reg, _big_fp = self._chunked_store(tmp_path)
        try:
            srv = RegistryServer(reg, warm_scan_limit=5)
            # the scan stopped after 5 index entries (big one included)
            assert srv.snapshot().warmed_chunks <= 5
            assert srv.snapshot().warmed_chunks > 0
        finally:
            reg.close()


# --------------------------------------------------- metrics conformance

class TestMetricsConformance:
    """Per-transport metric byte totals must equal the TransferReport for
    the same traffic, byte for byte — the metrics layer is an alternative
    view of the same measurement points, not a second estimate."""

    def _categories(self, transport):
        snap = transport.metrics.snapshot()

        def val(cat):
            return snap.value("transport_bytes_total",
                              {"transport": transport.name, "category": cat})
        return {cat: val(cat) for cat in ("index", "recipe", "want", "chunk")}

    @pytest.mark.parametrize("kind", TRANSPORTS)
    def test_pull_bytes_match_report_exactly(self, kind):
        versions = _versions(4, seed=61)
        reg = _seed_registry(versions)
        cl = _fresh_client(kind, reg, provisioned_tags=("v1",))
        try:
            rep = cl.pull("app", "v2")
            got = self._categories(cl.transport)
            assert got == {"index": rep.index_bytes,
                           "recipe": rep.recipe_bytes,
                           "want": rep.want_bytes,
                           "chunk": rep.chunk_bytes}
            assert sum(got.values()) == rep.total_wire_bytes
        finally:
            _cleanup_client(cl)

    @pytest.mark.parametrize("kind", TRANSPORTS)
    def test_push_bytes_match_report_exactly(self, kind):
        versions = _versions(3, seed=62)
        reg = _seed_registry(versions)
        cl = _fresh_client(kind, reg)
        try:
            data = versions[-1] + _rand(5000, seed=63)
            cl.commit("app", "v9", data)
            rep = cl.push("app", "v9")
            got = self._categories(cl.transport)
            assert got == {"index": rep.index_bytes,
                           "recipe": rep.recipe_bytes,
                           "want": rep.want_bytes,
                           "chunk": rep.chunk_bytes}
        finally:
            _cleanup_client(cl)

    def test_client_adopts_transport_registry(self):
        reg = _seed_registry(_versions(2, seed=64))
        cl = _fresh_client("local", reg)
        assert cl.metrics is cl.transport.metrics
        cl.pull("app", "v1")
        snap = cl.metrics.snapshot()
        h = snap.histogram("client_pull_seconds", {"transport": "local"})
        assert h is not None and h.count == 1


class TestMetricsScrape:
    """``Op.METRICS`` over a live socket: the scraped snapshot must match
    the in-process one (same registry, same numbers)."""

    def test_scrape_matches_in_process_snapshot(self):
        versions = _versions(3, seed=65)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        with SocketRegistryServer(srv) as sock_srv, \
                SocketTransport(sock_srv.address) as transport:
            cl = ImageClient(transport, cdc_params=PARAMS, cdmt_params=P)
            cl.pull("app", "v2")
            scraped = transport.scrape_metrics()
            local = srv.metrics.snapshot()
            # request-latency histogram: same op counts over the wire
            for op in ("index", "recipe", "want"):
                got = scraped.histogram("registry_request_seconds",
                                        {"op": op})
                want = local.histogram("registry_request_seconds",
                                       {"op": op})
                assert got is not None and got.count == want.count
            # counters and cache numbers identical (a scrape adds only
            # "metrics"-op and socket-level series, counted after snapshot)
            assert scraped.value("registry_requests_total", {"op": "want"}) \
                == local.value("registry_requests_total", {"op": "want"})
            assert scraped.value("cache_hits_total", {}) \
                == local.value("cache_hits_total", {})
            assert scraped.value("cache_misses_total", {}) \
                == local.value("cache_misses_total", {})
            # socket envelope series ride in the same scrape
            assert scraped.value("socket_requests_total", {}) >= 1

    def test_scrape_reports_standby_lag(self):
        versions = _versions(3, seed=66)
        reg = _seed_registry(versions)
        srv = RegistryServer(reg)
        with SocketRegistryServer(srv) as sock_srv, \
                SocketTransport(sock_srv.address) as transport:
            standby = Registry(cdmt_params=P)
            try:
                JournalFollower(standby, transport, name="s0").sync_once()
                scraped = transport.scrape_metrics()
                lag = scraped.value("replication_standby_lag",
                                    {"replica": "s0"}, default=None)
                assert lag == 0            # fully caught up and acked
            finally:
                standby.close()
