"""CDMT-dedup checkpointing: serialization, save/restore, incremental wire
savings — the paper's push/pull as the framework's checkpoint transport."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointConfig, DedupCheckpointManager,
                              deserialize_tree, serialize_tree, tree_manifest)
from repro.core import cdc
from repro.core.registry import Registry

CDC = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)


def _state(seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w1": rng.standard_normal((64, 64)).astype(np.float32) * scale,
                   "w2": rng.standard_normal((32, 128)).astype(np.float32) * scale,
                   "emb": rng.standard_normal((100, 16)).astype(np.float32)},
        "opt": {"m": np.zeros((64, 64), np.float32),
                "count": np.int32(7)},
    }


class TestSerializer:
    @pytest.mark.parametrize("groups", [1, 2, 4])
    def test_roundtrip(self, groups):
        st = _state()
        streams = serialize_tree(st, groups)
        manifest = tree_manifest(st)
        back = deserialize_tree(streams, manifest, st)
        for (ka, a), (kb, b) in zip(
                jax.tree_util.tree_flatten_with_path(st)[0],
                jax.tree_util.tree_flatten_with_path(back)[0]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_stable_layout_across_identical_states(self):
        a = serialize_tree(_state(seed=1), 2)
        b = serialize_tree(_state(seed=1), 2)
        assert a == b

    def test_small_change_localized(self):
        """One changed leaf leaves the other groups' streams byte-identical."""
        s1, s2 = _state(seed=2), _state(seed=2)
        s2["params"]["w1"][0, 0] += 1.0
        g1 = serialize_tree(s1, 4)
        g2 = serialize_tree(s2, 4)
        assert sum(a != b for a, b in zip(g1, g2)) == 1


class TestManager:
    def _mgr(self, **kw):
        reg = Registry()
        cfg = CheckpointConfig(lineage="test", n_groups=2, cdc_params=CDC, **kw)
        return DedupCheckpointManager(reg, cfg), reg

    def test_save_restore_exact(self):
        mgr, _ = self._mgr()
        st = _state(seed=3)
        mgr.save(st, step=10)
        back, step, _ = mgr.restore(st)
        assert step == 10
        np.testing.assert_array_equal(back["params"]["w1"], st["params"]["w1"])
        assert int(back["opt"]["count"]) == 7

    def test_restore_latest(self):
        mgr, _ = self._mgr()
        for s in (10, 20, 30):
            st = _state(seed=s)
            mgr.save(st, step=s)
        assert mgr.latest_step() == 30
        back, step, _ = mgr.restore(_state())
        assert step == 30
        np.testing.assert_array_equal(back["params"]["w1"],
                                      _state(seed=30)["params"]["w1"])

    def test_incremental_save_moves_few_bytes(self):
        """The paper's claim on checkpoints: consecutive versions dedup."""
        mgr, _ = self._mgr()
        st = _state(seed=4)
        info0 = mgr.save(st, step=0)
        # small update: one tensor nudged (most low-order bytes change in
        # just that leaf; the rest of the stream is identical)
        st["params"]["w1"][:4] += 0.01
        info1 = mgr.save(st, step=1)
        assert info1.total_wire_bytes < 0.5 * info0.total_wire_bytes
        assert info1.savings_vs_raw > 0.5

    def test_fresh_host_pull_then_incremental(self):
        """Elastic scaling: a new host pays full cost once, then deltas."""
        reg = Registry()
        cfg = CheckpointConfig(lineage="run", n_groups=2, cdc_params=CDC)
        producer = DedupCheckpointManager(reg, cfg)
        st = _state(seed=5)
        producer.save(st, step=0)
        st["params"]["w2"][0] += 0.5
        producer.save(st, step=1)

        joiner = DedupCheckpointManager(reg, cfg)
        joiner.manifests = dict(producer.manifests)
        _, _, wire0 = joiner.restore(st, step=0)
        _, _, wire1 = joiner.restore(st, step=1)
        full = sum(w.chunk_bytes for w in wire0)
        delta = sum(w.chunk_bytes for w in wire1)
        assert delta < 0.5 * full

    def test_restore_from_manifest_in_registry(self):
        """A different process (no local manifest cache) can restore."""
        reg = Registry()
        cfg = CheckpointConfig(lineage="run", n_groups=2, cdc_params=CDC)
        a = DedupCheckpointManager(reg, cfg)
        st = _state(seed=6)
        a.save(st, step=5)
        b = DedupCheckpointManager(reg, cfg)
        back, step, _ = b.restore(st, step=5)
        np.testing.assert_array_equal(back["params"]["emb"], st["params"]["emb"])

    def test_async_save(self):
        mgr, _ = self._mgr(async_push=True)
        st = _state(seed=7)
        mgr.save(st, step=1, block=False)
        mgr.wait()
        back, step, _ = mgr.restore(st)
        assert step == 1
        np.testing.assert_array_equal(back["params"]["w1"], st["params"]["w1"])


# Hypothesis property tests live in tests/test_properties.py (optional dep).
