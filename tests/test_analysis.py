"""The static-analysis gate itself (`src/repro/analysis/`).

Three properties, mirrored from `tools/analyze.py` across all six
analyzers (guarded-by, lock-order, wire-drift, layers, err-contract,
durability):

  * the **grammar** works — each annotation form (`guarded-by`,
    `external(...)`, `requires-lock`, `unguarded-ok`, `# api-boundary`,
    `# raises-ok:`, `# durability-ok:`, the ``GUARDED_FIELDS`` registry
    and ``LAYER_EXCEPTIONS`` allowlist) does what `docs/CONCURRENCY.md`
    and `docs/CONTRACTS.md` say;
  * the **repo is clean** — running all six analyzers over the real
    source trees yields zero findings, which is exactly what the `analyze`
    CI job gates on — and stays load-bearing: deleting any declared layer
    exception or any `raises-ok`/`durability-ok` pragma makes it fail;
  * the gate **provably bites** — the deliberately broken fixtures
    (`tests/fixtures/analysis_broken.py`, `wire_spec_broken.md`,
    `layers_broken.py`, `errcontract_broken.py`, `durability_broken.py`)
    produce the seeded findings, with `file:line` positions.
"""

import glob
import json
import os
import subprocess
import sys
import textwrap
import threading

import pytest

from repro.analysis import (durability, errcontract, guarded, layers,
                            lockorder, runtime, wiredrift)
from repro.obs.metrics import MetricsRegistry

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(ROOT, "tests", "fixtures")


def scan_paths():
    out = []
    for sub in ("core", "delivery", "obs"):
        out.extend(sorted(glob.glob(
            os.path.join(ROOT, "src", "repro", sub, "*.py"))))
    return out


def _check(source, path="mod.py"):
    return guarded.check_file(path, source=textwrap.dedent(source))


# ------------------------------------------------------- guarded-by grammar


class TestGuardedGrammar:
    def test_access_outside_lock_is_flagged_with_line(self):
        findings = _check("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.items = []  # guarded-by: _lock

                def bad(self):
                    return len(self.items)
            """)
        assert len(findings) == 1
        assert findings[0].line == 9
        assert "guarded by '_lock'" in findings[0].message
        assert "mod.py:9:" in str(findings[0])

    def test_access_under_the_declared_lock_is_clean(self):
        findings = _check("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.items = []  # guarded-by: _lock

                def ok(self):
                    with self._lock:
                        self.items.append(1)
                        return list(self.items)
            """)
        assert findings == []

    def test_wrong_lock_does_not_satisfy_the_declaration(self):
        findings = _check("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._other = threading.Lock()
                    self.items = []  # guarded-by: _lock

                def bad(self):
                    with self._other:
                        self.items.append(1)
            """)
        assert [f.line for f in findings] == [11]

    def test_init_is_exempt(self):
        findings = _check("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.items = []  # guarded-by: _lock
                    self.items.append(0)   # construction: not shared yet
            """)
        assert findings == []

    def test_external_fields_are_documented_not_enforced(self):
        findings = _check("""\
            import threading

            class J:
                def __init__(self):
                    self.pending = []  # guarded-by: external(single writer)

                def add(self, x):
                    self.pending.append(x)
            """)
        assert findings == []

    def test_requires_lock_treats_body_as_held(self):
        findings = _check("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.items = []  # guarded-by: _lock

                def _admit(self, x):  # requires-lock: _lock
                    self.items.append(x)
            """)
        assert findings == []

    def test_unguarded_ok_pragma_silences_one_line(self):
        findings = _check("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.flag = False  # guarded-by: _lock

                def fast(self):
                    if self.flag:  # unguarded-ok: benign stale read
                        return True
                    return self.flag
            """)
        # only the line WITHOUT the pragma is flagged
        assert [f.line for f in findings] == [11]

    def test_closures_are_analyzed_with_empty_held_set(self):
        """A nested def may outlive the with-block (thread target), so the
        lock held at the definition site must NOT leak into its body."""
        findings = _check("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.items = []  # guarded-by: _lock

                def spawn(self):
                    with self._lock:
                        def worker():
                            self.items.append(1)
                        return worker
            """)
        assert [f.line for f in findings] == [11]

    def test_guarded_fields_registry_covers_slots_classes(self):
        """`metrics._Counter._value` is declared centrally (the class uses
        __slots__ and cannot carry a trailing comment)."""
        assert guarded.GUARDED_FIELDS[("metrics", "_Counter")] \
            == {"_value": "_lock"}
        findings = guarded.check_file("metrics.py", source=textwrap.dedent("""\
            import threading

            class _Counter:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._value = 0

                def inc(self):
                    self._value += 1
            """))
        assert [f.line for f in findings] == [9]

    def test_stats_are_counted(self):
        stats = guarded.new_stats()
        guarded.check_file("mod.py", source=textwrap.dedent("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.items = []  # guarded-by: _lock

                def ok(self):
                    with self._lock:
                        return list(self.items)
            """), stats=stats)
        assert stats["classes"] == 1
        assert stats["guarded_fields"] == 1
        assert stats["accesses_checked"] >= 1


# ---------------------------------------------------------------- lockorder


class TestLockOrder:
    def test_repo_edges_match_the_committed_hierarchy(self):
        result = lockorder.analyze_files(scan_paths())
        assert result.findings == []
        # the three load-bearing edges the codebase actually has
        edges = {(a, b) for (a, b) in result.edges}
        assert ("RegistryServer._registry_lock",
                "MetricsRegistry._lock") in edges
        assert ("RegistryServer._registry_lock",
                "ReplicationLog._lock") in edges
        assert ("TieredChunkCache._lock",
                "MetricsRegistry._lock") in edges

    def test_every_discovered_lock_is_ranked(self):
        result = lockorder.analyze_files(scan_paths())
        for node in result.nodes:
            assert node in lockorder.LOCK_RANKS, f"unranked lock {node}"

    def test_inversion_cycle_is_detected(self):
        fixture = os.path.join(FIXTURES, "analysis_broken.py")
        result = lockorder.analyze_files([fixture], check_ranks=False)
        msgs = [f.message for f in result.findings]
        assert any("cycle" in m for m in msgs), msgs

    def test_rank_violation_is_detected(self, tmp_path):
        src = textwrap.dedent("""\
            import threading

            class Backwards:
                def __init__(self):
                    self._hi = threading.Lock()
                    self._lo = threading.Lock()

                def bad(self):
                    with self._hi:
                        with self._lo:
                            pass
            """)
        p = tmp_path / "backwards.py"
        p.write_text(src)
        result = lockorder.analyze_files(
            [str(p)], ranks={"Backwards._hi": 20, "Backwards._lo": 10})
        assert any("rank" in f.message for f in result.findings)

    def test_hierarchy_markdown_is_deterministic(self):
        a = lockorder.hierarchy_markdown(lockorder.analyze_files(scan_paths()))
        b = lockorder.hierarchy_markdown(lockorder.analyze_files(scan_paths()))
        assert a == b
        assert "| rank | lock | kind |" in a


# ---------------------------------------------------------------- wiredrift


class TestWireDrift:
    def test_real_doc_and_codecs_are_clean(self):
        findings, stats = wiredrift.check_all(
            os.path.join(ROOT, "docs", "WIRE_PROTOCOL.md"))
        assert findings == []
        assert stats["round_trips"] >= 16
        assert stats["sizing_checks"] >= 15

    def test_every_frame_type_has_an_exemplar(self):
        from repro.delivery import wire
        assert set(wiredrift.EXEMPLARS) == set(wire.FrameType)

    def test_broken_doc_yields_the_seeded_findings(self):
        findings, _ = wiredrift.check_doc(
            os.path.join(FIXTURES, "wire_spec_broken.md"))
        msgs = [f.message for f in findings]
        assert any("METRICS" in m and "no row" in m for m in msgs)
        assert any("no matching enum member" in m for m in msgs)
        assert any("but the enum member is" in m for m in msgs)

    def test_codec_round_trips_and_sizing_identities(self):
        assert wiredrift.check_codecs()[0] == []
        assert wiredrift.check_sizing()[0] == []


# ------------------------------------------------------------------- layers


ARCH_DOC = os.path.join(ROOT, "docs", "ARCHITECTURE.md")


class TestLayers:
    def test_repo_import_graph_is_clean(self):
        result = layers.analyze_paths(scan_paths(), doc=ARCH_DOC)
        assert result.findings == []
        assert result.stats["modules"] >= 20
        assert result.stats["edges"] >= 50

    def test_every_upward_edge_in_the_repo_is_lazy_and_allowlisted(self):
        result = layers.analyze_paths(scan_paths(), doc=ARCH_DOC)
        upward = [(s, d, lazy) for s, d, lazy, _, _ in result.edges
                  if result.assignments.get(d, 0)
                  > result.assignments.get(s, 9)]
        assert upward, "expected the declared upward edges to exist"
        for src, dst, lazy in upward:
            assert (src, dst) in layers.LAYER_EXCEPTIONS
            assert lazy, f"{src} -> {dst} must be a call-time import"

    def test_doc_table_covers_every_scanned_module(self):
        with open(ARCH_DOC, encoding="utf-8") as f:
            assignments = layers.parse_layer_doc(f.read())
        for path in scan_paths():
            stem = os.path.splitext(os.path.basename(path))[0]
            if stem == "__init__" or f"{os.sep}obs{os.sep}" in path:
                continue
            assert stem in assignments, f"{stem} missing from layer table"

    def test_broken_fixture_findings_carry_file_and_line(self):
        fixture = os.path.join(FIXTURES, "layers_broken.py")
        with open(ARCH_DOC, encoding="utf-8") as f:
            assignments = layers.parse_layer_doc(f.read())
        assignments["layers_broken"] = 2
        exceptions = dict(layers.LAYER_EXCEPTIONS)
        exceptions[("layers_broken", "wire")] = "seeded"
        result = layers.analyze_paths([fixture], assignments=assignments,
                                      exceptions=exceptions)
        by_line = {f.line: f.message for f in result.findings}
        assert "upward import" in by_line[17]
        assert "module level" in by_line[18]
        for f in result.findings:
            assert str(f).startswith(f"{fixture}:{f.line}:")

    def test_deleting_any_declared_exception_fails_the_gate(self):
        """Each LAYER_EXCEPTIONS entry is load-bearing: removing it turns
        the matching (real, existing) upward edge into a finding."""
        for removed in layers.LAYER_EXCEPTIONS:
            pruned = {k: v for k, v in layers.LAYER_EXCEPTIONS.items()
                      if k != removed}
            result = layers.analyze_paths(scan_paths(), doc=ARCH_DOC,
                                          exceptions=pruned)
            src, dst = removed
            assert any("upward import" in f.message
                       and f"'{src}'" in f.message and f"'{dst}'" in f.message
                       for f in result.findings), \
                f"removing {removed} produced no finding"

    def test_module_without_a_declared_layer_is_flagged(self, tmp_path):
        p = tmp_path / "newmod.py"
        p.write_text("import os\n")
        result = layers.analyze_paths([str(p)], doc=ARCH_DOC)
        assert any("no declared layer" in f.message
                   for f in result.findings)

    def test_markdown_is_deterministic_and_tabular(self):
        r1 = layers.analyze_paths(scan_paths(), doc=ARCH_DOC)
        r2 = layers.analyze_paths(scan_paths(), doc=ARCH_DOC)
        md = layers.layers_markdown(r1)
        assert md == layers.layers_markdown(r2)
        assert "| layer | modules |" in md
        assert "`registry`" in md


# ------------------------------------------------------------- err-contract


class TestErrContract:
    def test_repo_boundaries_are_clean(self):
        findings, stats = errcontract.analyze_files(scan_paths())
        assert findings == []
        assert stats["boundaries"] >= 60
        assert stats["raise_sites"] >= 100

    def test_broken_fixture_findings_carry_file_and_line(self):
        fixture = os.path.join(FIXTURES, "errcontract_broken.py")
        findings, _ = errcontract.analyze_files([fixture])
        by_line = {f.line: f.message for f in findings}
        assert "raise of banned type KeyError" in by_line[19]
        assert "can leak KeyError" in by_line[28]
        assert "errcontract_broken.py:19" in by_line[28]  # cites the origin
        assert not any("safe_fetch" in m for m in by_line.values())

    def test_deleting_the_store_pragma_fails_the_gate(self):
        """`ChunkStore.get`'s raises-ok pragma is load-bearing."""
        path = next(p for p in scan_paths() if p.endswith("core/store.py"))
        with open(path, encoding="utf-8") as f:
            source = f.read()
        assert "# raises-ok:" in source
        stripped = "\n".join(
            line.split("# raises-ok:")[0].rstrip()
            for line in source.splitlines())
        findings, _ = errcontract.analyze_files(
            scan_paths(), overrides={path: stripped})
        assert any(f.path == path
                   and "raise of banned type KeyError" in f.message
                   for f in findings)

    def test_deleting_the_net_pragma_fails_the_gate(self):
        """The bare OSError re-raise in the socket server's `_answer` is
        allowed only because it carries a reasoned pragma."""
        path = next(p for p in scan_paths() if p.endswith("delivery/net.py"))
        with open(path, encoding="utf-8") as f:
            source = f.read()
        stripped = "\n".join(
            line.split("# raises-ok:")[0].rstrip()
            for line in source.splitlines())
        findings, _ = errcontract.analyze_files(
            scan_paths(), overrides={path: stripped})
        assert any(f.path == path and "OSError" in f.message
                   for f in findings)

    def test_boundary_leak_through_a_call_chain_is_detected(self):
        findings, _ = errcontract.analyze_files(["api.py"], overrides={
            "api.py": textwrap.dedent("""\
                def helper(d, k):
                    return d[k] if k in d else _boom(k)

                def _boom(k):
                    raise OSError(f"no {k}")

                class Api:
                    # api-boundary
                    def read(self, d, k):
                        return helper(d, k)
                """)})
        assert any("'Api.read' can leak OSError" in f.message
                   for f in findings)

    def test_taxonomy_wrapping_satisfies_the_boundary(self):
        findings, _ = errcontract.analyze_files(["api.py"], overrides={
            "api.py": textwrap.dedent("""\
                def _boom(k):
                    raise KeyError(k)  # raises-ok: wrapped by every caller

                class Api:
                    # api-boundary
                    def read(self, d, k):
                        try:
                            return _boom(k)
                        except KeyError:
                            raise ValueError(f"unknown {k}") from None
                """)})
        assert findings == []

    def test_pragma_on_a_raise_keeps_the_escape_summary(self):
        """raises-ok silences the local site but the type still escapes —
        an unwrapped boundary caller is still flagged."""
        findings, _ = errcontract.analyze_files(["api.py"], overrides={
            "api.py": textwrap.dedent("""\
                def _boom(k):
                    raise KeyError(k)  # raises-ok: callers must wrap

                class Api:
                    # api-boundary
                    def read(self, d, k):
                        return _boom(k)
                """)})
        assert len(findings) == 1
        assert "'Api.read' can leak KeyError" in findings[0].message


# --------------------------------------------------------------- durability


class TestDurability:
    def test_repo_commit_paths_are_clean(self):
        findings, stats = durability.check_files(scan_paths())
        assert findings == []
        assert stats["replace_sites"] >= 5
        assert stats["commit_paths"] == 3
        assert stats["journaled_paths"] == 4

    def test_broken_fixture_findings_carry_file_and_line(self):
        fixture = os.path.join(FIXTURES, "durability_broken.py")
        paths = {("BrokenRegistry", "receive_push")}
        findings = durability.check_file(fixture, commit_paths=paths,
                                         journaled_paths=paths)
        messages = {(f.line, f.message) for f in findings}
        lines = sorted(ln for ln, _ in messages)
        assert lines == [22, 22, 32, 33]
        assert any(ln == 22 and "preceding os.fsync" in m
                   for ln, m in messages)
        assert any(ln == 22 and "never fsynced afterwards" in m
                   for ln, m in messages)
        assert any(ln == 32 and "mutates in-memory state" in m
                   for ln, m in messages)
        assert any(ln == 33 and "before chunks.sync()" in m
                   for ln, m in messages)

    def test_deleting_the_store_pragma_fails_the_gate(self):
        """`_finish_compaction`'s durability-ok pragma is load-bearing."""
        path = next(p for p in scan_paths() if p.endswith("core/store.py"))
        with open(path, encoding="utf-8") as f:
            source = f.read()
        assert "# durability-ok:" in source
        stripped = "\n".join(
            line.split("# durability-ok:")[0].rstrip()
            for line in source.splitlines())
        findings = durability.check_file(path, source=stripped)
        assert any("preceding os.fsync" in f.message for f in findings)

    def test_correct_rename_discipline_is_clean(self):
        findings = durability.check_file("mod.py", source=textwrap.dedent("""\
            import os

            def atomic_write(tmp, path, fsync_dir):
                with open(tmp, "wb") as f:
                    f.write(b"x")
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, path)
                fsync_dir(os.path.dirname(path))
            """))
        assert findings == []

    def test_rename_without_fsync_is_flagged(self):
        findings = durability.check_file("mod.py", source=textwrap.dedent("""\
            import os

            def sloppy(tmp, path):
                os.replace(tmp, path)
            """))
        assert len(findings) == 2

    def test_durability_ok_pragma_silences_a_site(self):
        findings = durability.check_file("mod.py", source=textwrap.dedent("""\
            import os

            def recovery(tmp, path):
                os.replace(tmp, path)  # durability-ok: inputs were fsynced
            """))
        assert findings == []


# --------------------------------------------------------- repo-wide clean


class TestRepoClean:
    def test_guarded_lint_is_clean_over_the_real_trees(self):
        findings, stats = guarded.check_files(scan_paths())
        assert findings == []
        assert stats["guarded_fields"] >= 30
        assert stats["accesses_checked"] >= 150

    def test_broken_fixture_findings_carry_file_and_line(self):
        fixture = os.path.join(FIXTURES, "analysis_broken.py")
        findings = guarded.check_file(fixture)
        assert [f.line for f in findings] == [26, 29]
        for f in findings:
            assert str(f).startswith(f.path)

    def test_cli_strict_exits_zero_on_the_repo(self):
        proc = subprocess.run(
            [sys.executable, os.path.join(ROOT, "tools", "analyze.py"),
             "--strict"],
            capture_output=True, text=True,
            env={**os.environ,
                 "PYTHONPATH": os.path.join(ROOT, "src")})
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "analysis clean" in proc.stdout

    def test_cli_self_test_catches_all_seeded_defects(self):
        proc = subprocess.run(
            [sys.executable, os.path.join(ROOT, "tools", "analyze.py"),
             "--self-test"],
            capture_output=True, text=True,
            env={**os.environ,
                 "PYTHONPATH": os.path.join(ROOT, "src")})
        assert proc.returncode == 0, proc.stdout + proc.stderr
        # one "caught:" line per analyzer family at minimum
        for token in ("guarded-by", "lock-order", "wire-drift", "layers",
                      "err-contract", "durability"):
            assert f"[{token}]" in proc.stdout, token


# -------------------------------------------------------------- CLI formats


def _load_cli():
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "analyze_cli", os.path.join(ROOT, "tools", "analyze.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestCliFormats:
    def test_json_format_on_the_clean_repo(self):
        proc = subprocess.run(
            [sys.executable, os.path.join(ROOT, "tools", "analyze.py"),
             "--strict", "--format", "json"],
            capture_output=True, text=True,
            env={**os.environ,
                 "PYTHONPATH": os.path.join(ROOT, "src")})
        assert proc.returncode == 0, proc.stdout + proc.stderr
        out = json.loads(proc.stdout)
        assert out["clean"] is True
        assert out["findings"] == []
        assert set(out["stats"]) == {"guarded_by", "lock_order",
                                     "wire_drift", "layers",
                                     "err_contract", "durability"}
        assert out["stats"]["err_contract"]["boundaries"] >= 60

    def test_github_format_emits_error_annotations(self, monkeypatch,
                                                   capsys):
        mod = _load_cli()
        _, stats, lo, ly = mod.run_analyzers(False)
        from repro.analysis.report import Finding
        seeded = [Finding("layers", "src/repro/core/x.py", 7, "boom, twice")]
        monkeypatch.setattr(mod, "run_analyzers",
                            lambda strict: (seeded, stats, lo, ly))
        rc = mod.main(["--format", "github"])
        out = capsys.readouterr().out
        assert rc == 1
        assert ("::error file=src/repro/core/x.py,line=7,"
                "title=layers::boom, twice") in out

    def test_github_format_is_quiet_when_clean(self, capsys):
        mod = _load_cli()
        rc = mod.main(["--format", "github"])
        out = capsys.readouterr().out
        assert rc == 0
        assert "::error" not in out
        assert "analysis clean" in out


# ---------------------------------------------------------------- DebugLock


class TestDebugLock:
    def test_rank_increasing_acquisition_is_clean(self):
        log = runtime.ViolationLog()
        lo = runtime.DebugLock("lo", 10, threading.Lock(), log)
        hi = runtime.DebugLock("hi", 40, threading.Lock(), log)
        with lo:
            with hi:
                pass
        assert log.violations == []

    def test_inversion_is_recorded(self):
        log = runtime.ViolationLog()
        lo = runtime.DebugLock("lo", 10, threading.Lock(), log)
        hi = runtime.DebugLock("hi", 40, threading.Lock(), log)
        with hi:
            with lo:
                pass
        assert len(log.violations) == 1
        assert "rank 40" in log.violations[0]

    def test_equal_rank_nesting_is_a_violation(self):
        """Ranks must be STRICTLY increasing along an acquisition path."""
        log = runtime.ViolationLog()
        a = runtime.DebugLock("a", 20, threading.Lock(), log)
        b = runtime.DebugLock("b", 20, threading.Lock(), log)
        with a:
            with b:
                pass
        assert len(log.violations) == 1

    def test_reentrant_rlock_is_allowed(self):
        log = runtime.ViolationLog()
        r = runtime.DebugLock("r", 10, threading.RLock(), log)
        with r:
            with r:
                pass
        assert log.violations == []

    def test_unranked_lock_is_a_violation(self):
        log = runtime.ViolationLog()
        x = runtime.DebugLock("x", None, threading.Lock(), log)
        with x:
            pass
        assert len(log.violations) == 1
        assert "no rank" in log.violations[0]

    def test_raise_immediately_mode(self):
        log = runtime.ViolationLog(raise_immediately=True)
        lo = runtime.DebugLock("lo", 10, threading.Lock(), log)
        hi = runtime.DebugLock("hi", 40, threading.Lock(), log)
        with pytest.raises(runtime.LockOrderViolation):
            with hi:
                with lo:
                    pass
        # the failed acquisition must not leave state behind
        assert hi.locked() is False

    def test_held_stack_is_per_thread(self):
        log = runtime.ViolationLog()
        hi = runtime.DebugLock("hi", 40, threading.Lock(), log)
        lo = runtime.DebugLock("lo", 10, threading.Lock(), log)
        errs = []

        def other():
            try:
                with lo:      # fresh thread: empty held stack, no inversion
                    pass
            except Exception as e:   # pragma: no cover - diagnostic
                errs.append(e)

        with hi:
            t = threading.Thread(target=other)
            t.start()
            t.join()
        assert errs == []
        assert log.violations == []


class TestInstrument:
    def test_metrics_children_share_one_wrapper(self):
        """`_Counter._lock` IS the registry's lock: instrument() must wrap
        the shared instance exactly once (identity, not per-attribute)."""
        reg = MetricsRegistry()
        c = reg.counter("x_total")
        log = runtime.ViolationLog()
        n = runtime.instrument(reg, log=log)
        assert n >= 1
        assert isinstance(reg._lock, runtime.DebugLock)
        assert reg._lock is c._lock
        assert reg._lock.rank == lockorder.LOCK_RANKS["MetricsRegistry._lock"]
        # the instrumented registry still works
        c.inc(3)
        assert c.value() == 3
        assert log.violations == []

    def test_instrument_is_idempotent_on_debuglocks(self):
        reg = MetricsRegistry()
        log = runtime.ViolationLog()
        runtime.instrument(reg, log=log)
        wrapped = reg._lock
        runtime.instrument(reg, log=log)
        assert reg._lock is wrapped
