"""The static-analysis gate itself (`src/repro/analysis/`).

Three properties, mirrored from `tools/analyze.py`:

  * the **grammar** works — each annotation form (`guarded-by`,
    `external(...)`, `requires-lock`, `unguarded-ok`, the
    ``GUARDED_FIELDS`` registry) does what `docs/CONCURRENCY.md` says;
  * the **repo is clean** — running all three analyzers over the real
    source trees yields zero findings, which is exactly what the `analyze`
    CI job gates on;
  * the gate **provably bites** — the deliberately broken fixtures
    (`tests/fixtures/analysis_broken.py`, `wire_spec_broken.md`) produce
    the seeded findings, with `file:line` positions.
"""

import glob
import os
import subprocess
import sys
import textwrap
import threading

import pytest

from repro.analysis import guarded, lockorder, runtime, wiredrift
from repro.obs.metrics import MetricsRegistry

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(ROOT, "tests", "fixtures")


def scan_paths():
    out = []
    for sub in ("core", "delivery", "obs"):
        out.extend(sorted(glob.glob(
            os.path.join(ROOT, "src", "repro", sub, "*.py"))))
    return out


def _check(source, path="mod.py"):
    return guarded.check_file(path, source=textwrap.dedent(source))


# ------------------------------------------------------- guarded-by grammar


class TestGuardedGrammar:
    def test_access_outside_lock_is_flagged_with_line(self):
        findings = _check("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.items = []  # guarded-by: _lock

                def bad(self):
                    return len(self.items)
            """)
        assert len(findings) == 1
        assert findings[0].line == 9
        assert "guarded by '_lock'" in findings[0].message
        assert "mod.py:9:" in str(findings[0])

    def test_access_under_the_declared_lock_is_clean(self):
        findings = _check("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.items = []  # guarded-by: _lock

                def ok(self):
                    with self._lock:
                        self.items.append(1)
                        return list(self.items)
            """)
        assert findings == []

    def test_wrong_lock_does_not_satisfy_the_declaration(self):
        findings = _check("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._other = threading.Lock()
                    self.items = []  # guarded-by: _lock

                def bad(self):
                    with self._other:
                        self.items.append(1)
            """)
        assert [f.line for f in findings] == [11]

    def test_init_is_exempt(self):
        findings = _check("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.items = []  # guarded-by: _lock
                    self.items.append(0)   # construction: not shared yet
            """)
        assert findings == []

    def test_external_fields_are_documented_not_enforced(self):
        findings = _check("""\
            import threading

            class J:
                def __init__(self):
                    self.pending = []  # guarded-by: external(single writer)

                def add(self, x):
                    self.pending.append(x)
            """)
        assert findings == []

    def test_requires_lock_treats_body_as_held(self):
        findings = _check("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.items = []  # guarded-by: _lock

                def _admit(self, x):  # requires-lock: _lock
                    self.items.append(x)
            """)
        assert findings == []

    def test_unguarded_ok_pragma_silences_one_line(self):
        findings = _check("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.flag = False  # guarded-by: _lock

                def fast(self):
                    if self.flag:  # unguarded-ok: benign stale read
                        return True
                    return self.flag
            """)
        # only the line WITHOUT the pragma is flagged
        assert [f.line for f in findings] == [11]

    def test_closures_are_analyzed_with_empty_held_set(self):
        """A nested def may outlive the with-block (thread target), so the
        lock held at the definition site must NOT leak into its body."""
        findings = _check("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.items = []  # guarded-by: _lock

                def spawn(self):
                    with self._lock:
                        def worker():
                            self.items.append(1)
                        return worker
            """)
        assert [f.line for f in findings] == [11]

    def test_guarded_fields_registry_covers_slots_classes(self):
        """`metrics._Counter._value` is declared centrally (the class uses
        __slots__ and cannot carry a trailing comment)."""
        assert guarded.GUARDED_FIELDS[("metrics", "_Counter")] \
            == {"_value": "_lock"}
        findings = guarded.check_file("metrics.py", source=textwrap.dedent("""\
            import threading

            class _Counter:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._value = 0

                def inc(self):
                    self._value += 1
            """))
        assert [f.line for f in findings] == [9]

    def test_stats_are_counted(self):
        stats = guarded.new_stats()
        guarded.check_file("mod.py", source=textwrap.dedent("""\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.items = []  # guarded-by: _lock

                def ok(self):
                    with self._lock:
                        return list(self.items)
            """), stats=stats)
        assert stats["classes"] == 1
        assert stats["guarded_fields"] == 1
        assert stats["accesses_checked"] >= 1


# ---------------------------------------------------------------- lockorder


class TestLockOrder:
    def test_repo_edges_match_the_committed_hierarchy(self):
        result = lockorder.analyze_files(scan_paths())
        assert result.findings == []
        # the three load-bearing edges the codebase actually has
        edges = {(a, b) for (a, b) in result.edges}
        assert ("RegistryServer._registry_lock",
                "MetricsRegistry._lock") in edges
        assert ("RegistryServer._registry_lock",
                "ReplicationLog._lock") in edges
        assert ("TieredChunkCache._lock",
                "MetricsRegistry._lock") in edges

    def test_every_discovered_lock_is_ranked(self):
        result = lockorder.analyze_files(scan_paths())
        for node in result.nodes:
            assert node in lockorder.LOCK_RANKS, f"unranked lock {node}"

    def test_inversion_cycle_is_detected(self):
        fixture = os.path.join(FIXTURES, "analysis_broken.py")
        result = lockorder.analyze_files([fixture], check_ranks=False)
        msgs = [f.message for f in result.findings]
        assert any("cycle" in m for m in msgs), msgs

    def test_rank_violation_is_detected(self, tmp_path):
        src = textwrap.dedent("""\
            import threading

            class Backwards:
                def __init__(self):
                    self._hi = threading.Lock()
                    self._lo = threading.Lock()

                def bad(self):
                    with self._hi:
                        with self._lo:
                            pass
            """)
        p = tmp_path / "backwards.py"
        p.write_text(src)
        result = lockorder.analyze_files(
            [str(p)], ranks={"Backwards._hi": 20, "Backwards._lo": 10})
        assert any("rank" in f.message for f in result.findings)

    def test_hierarchy_markdown_is_deterministic(self):
        a = lockorder.hierarchy_markdown(lockorder.analyze_files(scan_paths()))
        b = lockorder.hierarchy_markdown(lockorder.analyze_files(scan_paths()))
        assert a == b
        assert "| rank | lock | kind |" in a


# ---------------------------------------------------------------- wiredrift


class TestWireDrift:
    def test_real_doc_and_codecs_are_clean(self):
        findings, stats = wiredrift.check_all(
            os.path.join(ROOT, "docs", "WIRE_PROTOCOL.md"))
        assert findings == []
        assert stats["round_trips"] >= 16
        assert stats["sizing_checks"] >= 15

    def test_every_frame_type_has_an_exemplar(self):
        from repro.delivery import wire
        assert set(wiredrift.EXEMPLARS) == set(wire.FrameType)

    def test_broken_doc_yields_the_seeded_findings(self):
        findings, _ = wiredrift.check_doc(
            os.path.join(FIXTURES, "wire_spec_broken.md"))
        msgs = [f.message for f in findings]
        assert any("METRICS" in m and "no row" in m for m in msgs)
        assert any("no matching enum member" in m for m in msgs)
        assert any("but the enum member is" in m for m in msgs)

    def test_codec_round_trips_and_sizing_identities(self):
        assert wiredrift.check_codecs()[0] == []
        assert wiredrift.check_sizing()[0] == []


# --------------------------------------------------------- repo-wide clean


class TestRepoClean:
    def test_guarded_lint_is_clean_over_the_real_trees(self):
        findings, stats = guarded.check_files(scan_paths())
        assert findings == []
        assert stats["guarded_fields"] >= 30
        assert stats["accesses_checked"] >= 150

    def test_broken_fixture_findings_carry_file_and_line(self):
        fixture = os.path.join(FIXTURES, "analysis_broken.py")
        findings = guarded.check_file(fixture)
        assert [f.line for f in findings] == [26, 29]
        for f in findings:
            assert str(f).startswith(f.path)

    def test_cli_strict_exits_zero_on_the_repo(self):
        proc = subprocess.run(
            [sys.executable, os.path.join(ROOT, "tools", "analyze.py"),
             "--strict"],
            capture_output=True, text=True,
            env={**os.environ,
                 "PYTHONPATH": os.path.join(ROOT, "src")})
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "analysis clean" in proc.stdout

    def test_cli_self_test_catches_all_seeded_defects(self):
        proc = subprocess.run(
            [sys.executable, os.path.join(ROOT, "tools", "analyze.py"),
             "--self-test"],
            capture_output=True, text=True,
            env={**os.environ,
                 "PYTHONPATH": os.path.join(ROOT, "src")})
        assert proc.returncode == 0, proc.stdout + proc.stderr


# ---------------------------------------------------------------- DebugLock


class TestDebugLock:
    def test_rank_increasing_acquisition_is_clean(self):
        log = runtime.ViolationLog()
        lo = runtime.DebugLock("lo", 10, threading.Lock(), log)
        hi = runtime.DebugLock("hi", 40, threading.Lock(), log)
        with lo:
            with hi:
                pass
        assert log.violations == []

    def test_inversion_is_recorded(self):
        log = runtime.ViolationLog()
        lo = runtime.DebugLock("lo", 10, threading.Lock(), log)
        hi = runtime.DebugLock("hi", 40, threading.Lock(), log)
        with hi:
            with lo:
                pass
        assert len(log.violations) == 1
        assert "rank 40" in log.violations[0]

    def test_equal_rank_nesting_is_a_violation(self):
        """Ranks must be STRICTLY increasing along an acquisition path."""
        log = runtime.ViolationLog()
        a = runtime.DebugLock("a", 20, threading.Lock(), log)
        b = runtime.DebugLock("b", 20, threading.Lock(), log)
        with a:
            with b:
                pass
        assert len(log.violations) == 1

    def test_reentrant_rlock_is_allowed(self):
        log = runtime.ViolationLog()
        r = runtime.DebugLock("r", 10, threading.RLock(), log)
        with r:
            with r:
                pass
        assert log.violations == []

    def test_unranked_lock_is_a_violation(self):
        log = runtime.ViolationLog()
        x = runtime.DebugLock("x", None, threading.Lock(), log)
        with x:
            pass
        assert len(log.violations) == 1
        assert "no rank" in log.violations[0]

    def test_raise_immediately_mode(self):
        log = runtime.ViolationLog(raise_immediately=True)
        lo = runtime.DebugLock("lo", 10, threading.Lock(), log)
        hi = runtime.DebugLock("hi", 40, threading.Lock(), log)
        with pytest.raises(runtime.LockOrderViolation):
            with hi:
                with lo:
                    pass
        # the failed acquisition must not leave state behind
        assert hi.locked() is False

    def test_held_stack_is_per_thread(self):
        log = runtime.ViolationLog()
        hi = runtime.DebugLock("hi", 40, threading.Lock(), log)
        lo = runtime.DebugLock("lo", 10, threading.Lock(), log)
        errs = []

        def other():
            try:
                with lo:      # fresh thread: empty held stack, no inversion
                    pass
            except Exception as e:   # pragma: no cover - diagnostic
                errs.append(e)

        with hi:
            t = threading.Thread(target=other)
            t.start()
            t.join()
        assert errs == []
        assert log.violations == []


class TestInstrument:
    def test_metrics_children_share_one_wrapper(self):
        """`_Counter._lock` IS the registry's lock: instrument() must wrap
        the shared instance exactly once (identity, not per-attribute)."""
        reg = MetricsRegistry()
        c = reg.counter("x_total")
        log = runtime.ViolationLog()
        n = runtime.instrument(reg, log=log)
        assert n >= 1
        assert isinstance(reg._lock, runtime.DebugLock)
        assert reg._lock is c._lock
        assert reg._lock.rank == lockorder.LOCK_RANKS["MetricsRegistry._lock"]
        # the instrumented registry still works
        c.inc(3)
        assert c.value() == 3
        assert log.violations == []

    def test_instrument_is_idempotent_on_debuglocks(self):
        reg = MetricsRegistry()
        log = runtime.ViolationLog()
        runtime.instrument(reg, log=log)
        wrapped = reg._lock
        runtime.instrument(reg, log=log)
        assert reg._lock is wrapped
