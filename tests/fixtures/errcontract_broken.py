"""Deliberately error-contract-violating module for
``tools/analyze.py --self-test``.

Never imported by product code.  The err-contract analyzer must produce:

  * a **banned raise** finding — ``lookup_helper`` raises a bare
    ``KeyError`` with no ``# raises-ok:`` pragma;
  * an **api-boundary leak** finding — ``BrokenStore.fetch`` is marked
    ``# api-boundary`` and calls ``lookup_helper`` without catching or
    wrapping, so the ``KeyError`` escapes the public surface.

``safe_fetch`` wraps the same helper in ``ValueError`` (taxonomy-typed)
and must NOT be flagged.
"""


def lookup_helper(table, key):
    if key not in table:
        raise KeyError(key)   # seeded defect: bare banned raise, no pragma
    return table[key]


class BrokenStore:
    def __init__(self):
        self.table = {}

    # api-boundary
    def fetch(self, key):
        # seeded defect: KeyError from lookup_helper escapes unwrapped
        return lookup_helper(self.table, key)

    # api-boundary
    def safe_fetch(self, key):
        try:
            return lookup_helper(self.table, key)
        except KeyError:
            raise ValueError(f"unknown key {key!r}") from None
