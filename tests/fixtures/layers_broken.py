"""Deliberately layer-violating module for ``tools/analyze.py --self-test``.

Never imported by product code.  Analyzed standalone against the real
layer map plus a seeded ``("layers_broken", "wire")`` allowlist entry, it
must produce:

  * an **upward import** finding — the module-level import of
    ``repro.delivery.client`` (L4) from this seeded L2 module is not on
    the allowlist;
  * an **eager allowlisted edge** finding — the ``wire`` edge *is*
    allowlisted, but the exception requires a lazy call-time import and
    this one runs at module level.

The lazy downward import in ``ok_lazy_use`` must NOT be flagged.
"""

from repro.delivery import client   # seeded defect: upward, not allowlisted
from repro.delivery import wire     # seeded defect: allowlisted but eager


def ok_lazy_use():
    from repro.core import journal  # downward + lazy: always fine
    return journal, client, wire
