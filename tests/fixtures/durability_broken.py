"""Deliberately crash-unsafe module for ``tools/analyze.py --self-test``.

Never imported by product code.  Checked with
``commit_paths={("BrokenRegistry", "receive_push")}`` and the same
``journaled_paths``, the durability lint must produce:

  * two **rename** findings — ``rename_without_fsync`` calls
    ``os.replace`` with no preceding ``os.fsync`` and never fsyncs the
    target's parent directory afterwards;
  * a **commit-order** finding — ``BrokenRegistry.receive_push`` appends
    the journal record before ``chunks.sync()``;
  * a **journal-order** finding — it also mutates in-memory state
    (``self.tags[tag] = …``) before the journal append.
"""

import os


def rename_without_fsync(tmp, path):
    with open(tmp, "wb") as f:
        f.write(b"data")
    os.replace(tmp, path)   # seeded defect: no fsync before, no dir fsync


class BrokenRegistry:
    def __init__(self, journal, chunks):
        self.journal = journal
        self.chunks = chunks
        self.tags = {}

    def receive_push(self, tag, record):
        self.tags[tag] = record          # seeded defect: mutate pre-append
        self.journal.append_raw(record)  # seeded defect: append pre-sync
        self.chunks.sync()
