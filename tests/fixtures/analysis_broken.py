"""Deliberately broken module: the static-analysis self-test target.

Never imported by product code.  ``tools/analyze.py --self-test`` (and the
``analyze`` CI job) runs the guarded-by lint and the lock-order analyzer
over this file and fails if the seeded defects below are NOT caught — the
gate must provably bite before it is allowed to gate anything.

Seeded defects:
  1. ``BrokenCounter.bump``    — writes a guarded field without the lock.
  2. ``BrokenCounter.drain``   — reads a guarded field without the lock.
  3. ``ab()`` vs ``ba()``      — opposite nesting of the same two locks:
                                 a potential-deadlock cycle.
"""

import threading


class BrokenCounter:
    def __init__(self):
        self._lock = threading.Lock()
        self._other = threading.Lock()
        self.count = 0        # guarded-by: _lock
        self.drained = 0      # guarded-by: _lock

    def bump(self) -> None:
        self.count += 1       # defect 1: unguarded write

    def drain(self) -> int:
        n = self.count        # defect 2: unguarded read
        with self._lock:
            self.drained += n
            self.count = 0
        return n

    def ab(self) -> None:
        with self._lock:
            with self._other:
                pass

    def ba(self) -> None:
        with self._other:
            with self._lock:  # defect 3: inversion of ab()'s order
                pass
