"""Durable registry: journal + snapshot recovery, torn-write repair, chunk
store crash safety, and metadata persistence.

The acceptance bar: a registry populated with ≥3 versions, reconstructed
from its directory alone, serves identical roots, recipes, tags, and
byte-identical pulls; truncating the journal or chunk files mid-record
still recovers to the last complete commit.
"""

import os

import numpy as np
import pytest

from repro.core import cdc, hashing
from repro.core.errors import DeliveryError, JournalError
from repro.core.journal import Journal, write_snapshot
from repro.core.pushpull import Client
from repro.core.registry import Registry
from repro.core.store import ChunkStore

PARAMS = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)


def _rand(n, seed=0):
    return np.random.default_rng(seed).integers(0, 256, size=n,
                                                dtype=np.uint8).tobytes()


def _versions(n_versions=3, size=60_000, seed=0):
    rng = np.random.default_rng(seed)
    data = bytearray(_rand(size, seed))
    out = [bytes(data)]
    for _ in range(n_versions - 1):
        pos = rng.integers(0, len(data) - 200)
        data[pos:pos + 64] = rng.bytes(64)
        ins = rng.integers(0, len(data))
        data[ins:ins] = rng.bytes(rng.integers(1, 128))
        out.append(bytes(data))
    return out


def _populate(reg, versions, lineage="app"):
    cl = Client(cdc_params=PARAMS)
    for i, v in enumerate(versions):
        cl.commit(lineage, f"v{i}", v)
        cl.push(reg, lineage, f"v{i}")


def _state_of(reg, lineage="app"):
    lin = reg.lineages[lineage]
    return (reg.tags(lineage),
            [(r.version, r.tag, r.root, r.parent, r.n_leaves)
             for r in lin.version_records()],
            {t: reg.recipe_for(lineage, t).fps for t in reg.tags(lineage)})


class TestRecovery:
    def test_reopen_serves_identical_state_and_pulls(self, tmp_path):
        versions = _versions(4, seed=1)
        reg = Registry(str(tmp_path / "reg"))
        _populate(reg, versions)
        reg.put_metadata("app", "v0", b"manifest-blob")
        want = _state_of(reg)
        reg.close()

        reg2 = Registry(str(tmp_path / "reg"))
        assert _state_of(reg2) == want
        assert reg2.get_metadata("app", "v0") == b"manifest-blob"
        # byte-identical restore for every version through a fresh client
        for i, v in enumerate(versions):
            cl = Client(cdc_params=PARAMS)
            cl.pull(reg2, "app", f"v{i}")
            assert cl.materialize("app", f"v{i}") == v
        reg2.close()

    def test_fresh_and_empty_directories(self, tmp_path):
        reg = Registry(str(tmp_path / "empty"))
        reg.close()
        reg2 = Registry(str(tmp_path / "empty"))
        assert reg2.lineages == {}
        reg2.close()

    def test_recovered_registry_accepts_new_pushes(self, tmp_path):
        versions = _versions(4, seed=2)
        reg = Registry(str(tmp_path / "reg"))
        _populate(reg, versions[:2])
        reg.close()
        reg2 = Registry(str(tmp_path / "reg"))
        cl = Client(cdc_params=PARAMS)
        cl.pull(reg2, "app", "v1")
        cl.commit("app", "v2", versions[2])
        cl.push(reg2, "app", "v2")
        reg2.close()
        reg3 = Registry(str(tmp_path / "reg"))
        assert reg3.tags("app") == ["v0", "v1", "v2"]
        reg3.close()


class TestTornWrites:
    def test_torn_journal_tail_recovers_to_last_commit(self, tmp_path):
        versions = _versions(3, seed=3)
        reg = Registry(str(tmp_path / "reg"))
        _populate(reg, versions)
        reg.close()
        jpath = tmp_path / "reg" / "registry.journal"
        size = os.path.getsize(jpath)
        with open(jpath, "r+b") as f:       # chop into the last record
            f.truncate(size - 7)
        reg2 = Registry(str(tmp_path / "reg"))
        assert reg2.tags("app") == ["v0", "v1"]     # last complete commits
        cl = Client(cdc_params=PARAMS)
        cl.pull(reg2, "app", "v1")
        assert cl.materialize("app", "v1") == versions[1]
        # the torn tail was truncated: the journal is appendable again
        cl.commit("app", "v2b", versions[2])
        cl.push(reg2, "app", "v2b")
        reg2.close()
        reg3 = Registry(str(tmp_path / "reg"))
        assert reg3.tags("app") == ["v0", "v1", "v2b"]
        reg3.close()

    def test_corrupt_journal_byte_stops_at_last_good_record(self, tmp_path):
        versions = _versions(3, seed=4)
        reg = Registry(str(tmp_path / "reg"))
        _populate(reg, versions)
        reg.close()
        jpath = tmp_path / "reg" / "registry.journal"
        blob = bytearray(open(jpath, "rb").read())
        blob[len(blob) - 20] ^= 0xFF        # bit rot inside the last record
        open(jpath, "wb").write(bytes(blob))
        reg2 = Registry(str(tmp_path / "reg"))
        assert reg2.tags("app") == ["v0", "v1"]
        reg2.close()

    def test_torn_chunk_files_recover(self, tmp_path):
        versions = _versions(3, seed=5)
        reg = Registry(str(tmp_path / "reg"))
        _populate(reg, versions)
        reg.close()
        # crash mid-put: orphan log bytes with no index entry, and a partial
        # index record
        with open(tmp_path / "reg" / "chunks.log", "ab") as f:
            f.write(b"\xde\xad\xbe\xef" * 10)
        with open(tmp_path / "reg" / "chunks.idx", "ab") as f:
            f.write(b"\x01" * 20)           # < one 32-byte entry
        reg2 = Registry(str(tmp_path / "reg"))
        assert reg2.store.chunks.recovered_torn_bytes == 40 + 20
        for i, v in enumerate(versions):
            cl = Client(cdc_params=PARAMS)
            cl.pull(reg2, "app", f"v{i}")
            assert cl.materialize("app", f"v{i}") == v
        reg2.close()

    def test_unsynced_log_data_that_never_landed_is_dropped(self, tmp_path):
        """An fsync-less crash can persist the index entry and the log's
        *length* without the log's data blocks.  Entries past the clean
        marker must be payload-verified on recovery, not trusted."""
        st = ChunkStore(str(tmp_path / "cs"))
        fp1 = hashing.chunk_fingerprint(b"hello")
        st.put(fp1, b"hello")
        st.sync()                               # fp1 is durable + trusted
        fp2 = hashing.chunk_fingerprint(b"world")
        st.put(fp2, b"world")                   # flushed, never fsynced
        st._log_f.close(); st._idx_f.close(); os.close(st._read_fd)
        st._log_f = st._idx_f = st._read_fd = None   # simulate hard crash
        # the crash: log length survived but the data blocks did not
        with open(tmp_path / "cs" / "chunks.log", "r+b") as f:
            f.seek(5)
            f.write(b"\x00" * 5)
        st2 = ChunkStore(str(tmp_path / "cs"))
        assert st2.get(fp1) == b"hello"         # trusted (within marker)
        assert not st2.has(fp2)                 # garbage payload: dropped
        assert st2.put(fp2, b"world")           # and re-uploadable
        assert st2.get(fp2) == b"world"
        st2.close()

    def test_closed_store_refuses_reads_and_writes(self, tmp_path):
        st = ChunkStore(str(tmp_path / "cs"))
        fp = hashing.chunk_fingerprint(b"x")
        st.put(fp, b"x")
        st.close()
        with pytest.raises(RuntimeError):
            st.put(b"\x07" * 16, b"y")          # must not fall back to memory
        with pytest.raises(RuntimeError):
            st.get(fp)                          # on-disk but store is closed
        st2 = ChunkStore(str(tmp_path / "cs"))
        assert st2.get(fp) == b"x"
        st2.close()

    def test_chunk_index_entry_past_log_end_dropped(self, tmp_path):
        st = ChunkStore(str(tmp_path / "cs"))
        st.put(b"\x01" * 16, b"hello")
        st.put(b"\x02" * 16, b"world")
        st.close()
        # log lost its tail (e.g. truncated by a crash before fsync)
        with open(tmp_path / "cs" / "chunks.log", "r+b") as f:
            f.truncate(5)
        st2 = ChunkStore(str(tmp_path / "cs"))
        assert st2.has(b"\x01" * 16)
        assert not st2.has(b"\x02" * 16)    # entry referenced missing bytes
        assert st2.get(b"\x01" * 16) == b"hello"
        # and the store still accepts appends at the repaired offset
        assert st2.put(b"\x03" * 16, b"again")
        assert st2.get(b"\x03" * 16) == b"again"
        st2.close()


class TestSnapshotCompaction:
    def test_compact_then_more_pushes_then_reopen(self, tmp_path):
        versions = _versions(4, seed=6)
        reg = Registry(str(tmp_path / "reg"))
        _populate(reg, versions[:2])
        reg.put_metadata("app", "v0", b"m0")
        pre_compact = reg.journal_size_bytes()
        reg.compact()
        # truncated to just the compaction boundary marker (~a dozen bytes)
        assert 0 < reg.journal_size_bytes() <= 32
        assert reg.journal_size_bytes() < pre_compact
        cl = Client(cdc_params=PARAMS)
        cl.pull(reg, "app", "v1")
        cl.commit("app", "v2", versions[2])
        cl.push(reg, "app", "v2")
        reg.close()
        reg2 = Registry(str(tmp_path / "reg"))
        assert reg2.tags("app") == ["v0", "v1", "v2"]
        assert reg2.get_metadata("app", "v0") == b"m0"
        for i in range(3):
            c = Client(cdc_params=PARAMS)
            c.pull(reg2, "app", f"v{i}")
            assert c.materialize("app", f"v{i}") == versions[i]
        reg2.close()

    def test_corrupt_snapshot_fails_loudly(self, tmp_path):
        """A snapshot is written atomically, so a record that fails to
        decode is real corruption — recovery must raise, not silently drop
        every version after the bad byte."""
        versions = _versions(3, seed=9)
        reg = Registry(str(tmp_path / "reg"))
        _populate(reg, versions)
        reg.compact()
        reg.close()
        spath = tmp_path / "reg" / "registry.snap"
        blob = bytearray(open(spath, "rb").read())
        blob[len(blob) // 2] ^= 0xFF
        open(spath, "wb").write(bytes(blob))
        with pytest.raises(JournalError):
            Registry(str(tmp_path / "reg"))

    def test_crash_between_snapshot_and_truncate_is_idempotent(self, tmp_path):
        """Simulate dying after the snapshot rename but before the journal
        truncation: recovery replays both; commit replay must dedup."""
        versions = _versions(2, seed=7)
        reg = Registry(str(tmp_path / "reg"))
        _populate(reg, versions)
        stale_journal = open(tmp_path / "reg" / "registry.journal", "rb").read()
        reg.compact()
        reg.close()
        with open(tmp_path / "reg" / "registry.journal", "wb") as f:
            f.write(stale_journal)          # pretend the truncate never hit
        reg2 = Registry(str(tmp_path / "reg"))
        assert reg2.tags("app") == ["v0", "v1"]      # no duplicates
        assert len(reg2.lineages["app"].version_records()) == 2
        reg2.close()


class TestWriteAheadOrdering:
    def test_failed_journal_append_leaves_index_untouched(self, tmp_path,
                                                          monkeypatch):
        """The commit record is journaled BEFORE in-memory state changes: a
        failed append must error the push without committing, and a retry
        must succeed AND be journaled (no deduplicated-but-lost version)."""
        versions = _versions(2, seed=8)
        reg = Registry(str(tmp_path / "reg"))
        _populate(reg, versions[:1])
        cl = Client(cdc_params=PARAMS)
        cl.pull(reg, "app", "v0")
        cl.commit("app", "v1", versions[1])

        real_append = Journal.append_raw        # the primitive every append
                                                # path (incl. replication
                                                # raw writes) funnels through

        def failing_append(self, raw_record):
            raise OSError("disk full")

        monkeypatch.setattr(Journal, "append_raw", failing_append)
        with pytest.raises(OSError):
            cl.push(reg, "app", "v1")
        assert reg.tags("app") == ["v0"]        # index untouched
        monkeypatch.setattr(Journal, "append_raw", real_append)
        cl.push(reg, "app", "v1")               # retry: full push, journaled
        assert reg.tags("app") == ["v0", "v1"]
        reg.close()
        reg2 = Registry(str(tmp_path / "reg"))
        assert reg2.tags("app") == ["v0", "v1"]  # v1 survived the restart
        c = Client(cdc_params=PARAMS)
        c.pull(reg2, "app", "v1")
        assert c.materialize("app", "v1") == versions[1]
        reg2.close()


class TestJournalUnit:
    def test_append_replay_roundtrip(self, tmp_path):
        j = Journal(str(tmp_path / "j"))
        j.append(1, b"alpha")
        j.append(2, b"")
        j.append(7, b"x" * 1000)
        j.close()
        j2 = Journal(str(tmp_path / "j"))
        assert j2.replay() == [(1, b"alpha"), (2, b""), (7, b"x" * 1000)]
        assert j2.replay() == []            # consumed
        assert j2.torn_bytes_discarded == 0
        j2.close()

    def test_torn_tail_truncated_once(self, tmp_path):
        j = Journal(str(tmp_path / "j"))
        j.append(1, b"alpha")
        j.append(2, b"beta")
        j.close()
        with open(tmp_path / "j", "ab") as f:
            f.write(b"CL\x01\x03\x20partial")          # half a record
        j2 = Journal(str(tmp_path / "j"))
        assert j2.replay() == [(1, b"alpha"), (2, b"beta")]
        assert j2.torn_bytes_discarded > 0
        j2.append(3, b"gamma")
        j2.close()
        j3 = Journal(str(tmp_path / "j"))
        assert [r[0] for r in j3.replay()] == [1, 2, 3]
        assert j3.torn_bytes_discarded == 0
        j3.close()

    def test_write_snapshot_atomic_replaces(self, tmp_path):
        p = str(tmp_path / "snap")
        write_snapshot(p, [(1, b"a")])
        write_snapshot(p, [(2, b"b"), (3, b"c")])
        j = Journal(p)
        assert j.replay() == [(2, b"b"), (3, b"c")]
        j.close()
        assert not os.path.exists(p + ".tmp")


class TestMetadataAndErrors:
    def test_metadata_durable_and_overwritable(self, tmp_path):
        reg = Registry(str(tmp_path / "reg"))
        reg.put_metadata("l", "t", b"one")
        reg.put_metadata("l", "t", b"two")
        reg.close()
        reg2 = Registry(str(tmp_path / "reg"))
        assert reg2.get_metadata("l", "t") == b"two"
        with pytest.raises(DeliveryError):
            reg2.get_metadata("l", "missing")
        reg2.close()


class TestBranchHistory:
    """Branch-at-version queries (``Registry.branch_root_at``) answer from
    ``VersionedCDMT.mod_history`` — state that exists only in memory, and
    is rebuilt from journaled commit records.  The queries must therefore
    give identical answers before a restart, after a restart, and after a
    snapshot compaction (which rewrites the journal entirely)."""

    def _seed(self, reg, versions):
        """Tags follow the branch@rev convention: three commits advance
        ``main``, one forks ``dev`` in between."""
        cl = Client(cdc_params=PARAMS)
        tags = ["main@1", "main@2", "dev@1", "main@3"]
        for tag, v in zip(tags, versions):
            cl.commit("app", tag, v)
            cl.push(reg, "app", tag)
        return tags

    def _answers(self, reg):
        lin = reg.lineages["app"]
        return ([reg.branch_root_at("app", "main", v) for v in range(4)],
                [reg.branch_root_at("app", "dev", v) for v in range(4)],
                lin.branch_history("main"), lin.branch_history("dev"))

    def test_branch_at_version_resolves_interleaved_branches(self, tmp_path):
        versions = _versions(4, seed=21)
        reg = Registry(str(tmp_path / "reg"))
        tags = self._seed(reg, versions)
        roots = {t: reg.index_for_tag("app", t).root for t in tags}
        # main advanced at versions 0, 1, 3; dev forked at version 2
        assert reg.branch_root_at("app", "main", 0) == roots["main@1"]
        assert reg.branch_root_at("app", "main", 1) == roots["main@2"]
        assert reg.branch_root_at("app", "main", 2) == roots["main@2"]
        assert reg.branch_root_at("app", "main", 3) == roots["main@3"]
        assert reg.branch_root_at("app", "dev", 1) is None
        assert reg.branch_root_at("app", "dev", 2) == roots["dev@1"]
        assert reg.branch_root_at("app", "dev", 3) == roots["dev@1"]
        assert reg.lineages["app"].branch_history("main") == [
            (0, roots["main@1"]), (1, roots["main@2"]),
            (3, roots["main@3"])]
        reg.close()

    def test_answers_survive_restart(self, tmp_path):
        versions = _versions(4, seed=22)
        reg = Registry(str(tmp_path / "reg"))
        self._seed(reg, versions)
        before = self._answers(reg)
        reg.close()
        reg2 = Registry(str(tmp_path / "reg"))
        assert self._answers(reg2) == before
        reg2.close()

    def test_answers_survive_compaction_and_restart(self, tmp_path):
        """Compaction replaces the journal with a snapshot; the snapshot
        replay must rebuild the SAME mod_history, including entries for
        versions committed after the compact."""
        versions = _versions(5, seed=23)
        reg = Registry(str(tmp_path / "reg"))
        self._seed(reg, versions[:4])
        reg.compact()
        cl = Client(cdc_params=PARAMS)
        cl.commit("app", "main@4", versions[4])
        cl.push(reg, "app", "main@4")
        before = self._answers(reg)
        assert reg.branch_root_at("app", "main", 4) \
            == reg.index_for_tag("app", "main@4").root
        reg.close()
        reg2 = Registry(str(tmp_path / "reg"))
        assert self._answers(reg2) == before
        assert reg2.branch_root_at("app", "main", 4) \
            == reg2.index_for_tag("app", "main@4").root
        reg2.close()

    def test_unknown_lineage_raises(self, tmp_path):
        reg = Registry(str(tmp_path / "reg"))
        with pytest.raises(DeliveryError):
            reg.branch_root_at("nope", "main", 0)
        reg.close()
