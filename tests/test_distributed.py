"""Distribution machinery: logical sharding rules, cell construction and
small-mesh lowering, the HLO cost walker, pipeline parallelism.

Multi-device tests run in a subprocess (XLA device count is locked at
first jax init, and the main test process must stay single-device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestShardingRules:
    def test_no_mesh_is_identity(self):
        import jax.numpy as jnp
        from repro.parallel.sharding import constrain
        x = jnp.ones((4, 4))
        assert constrain(x, "batch", None) is x

    def test_pspec_resolution(self):
        out = run_py("""
            import jax
            from repro.launch.mesh import make_mesh
            from repro.parallel import sharding as sh
            from jax.sharding import PartitionSpec as P
            mesh = make_mesh((2, 4), ("data", "model"))
            with sh.use_mesh(mesh):
                assert sh.logical_to_pspec(("embed", "mlp")) == P("data", "model")
                assert sh.logical_to_pspec(("batch", None)) == P(("data",), None)
                # duplicate mesh axis resolves once
                assert sh.logical_to_pspec(("heads", "mlp")) == P("model", None)
            print("OK")
        """)
        assert "OK" in out

    def test_rules_override(self):
        out = run_py("""
            from repro.launch.mesh import make_mesh
            from repro.parallel import sharding as sh
            from jax.sharding import PartitionSpec as P
            mesh = make_mesh((2, 4), ("data", "model"))
            with sh.use_mesh(mesh, {"mlp": None}):
                assert sh.logical_to_pspec(("embed", "mlp")) == P("data", None)
            print("OK")
        """)
        assert "OK" in out


class TestCells:
    def test_train_cell_lowers_and_costs(self):
        out = run_py("""
            import jax, json
            from repro.launch.mesh import make_mesh
            from repro.launch.cells import build_cell, lower_cell
            from repro.launch.hlo_cost import HloCostModel
            mesh = make_mesh((2, 4), ("data", "model"))
            cell = build_cell("olmo-1b", "train_4k", mesh, n_micro=4)
            compiled = lower_cell(cell).compile()
            cost = HloCostModel(compiled.as_text()).entry_cost()
            assert cost.flops > 1e9, cost.flops
            assert cost.collective_bytes > 0
            ma = compiled.memory_analysis()
            assert ma.temp_size_in_bytes > 0
            print("OK", int(cost.flops))
        """)
        assert "OK" in out

    def test_decode_cell_lowers(self):
        out = run_py("""
            from repro.launch.mesh import make_mesh
            from repro.launch.cells import build_cell, lower_cell
            mesh = make_mesh((2, 4), ("data", "model"))
            cell = build_cell("olmo-1b", "decode_32k", mesh)
            compiled = lower_cell(cell).compile()
            print("OK")
        """)
        assert "OK" in out

    def test_divisibility_overrides(self):
        """rwkv (40 heads) and MQA (kv=1) must adapt rules, not crash."""
        out = run_py("""
            from repro.launch.mesh import make_mesh
            from repro.launch.cells import baseline_rule_overrides
            from repro.configs.base import get_config, SHAPES
            mesh = make_mesh((2, 16), ("data", "model"))
            r = baseline_rule_overrides(get_config("rwkv6-3b"),
                                        SHAPES["decode_32k"], mesh)
            assert r["act_heads"] is None and r["cache_heads"] is None
            r = baseline_rule_overrides(get_config("granite-20b"),
                                        SHAPES["decode_32k"], mesh)
            assert r["cache_heads"] is None and r["cache_seq"] == "model"
            print("OK")
        """, devices=32)
        assert "OK" in out


class TestHloCostWalker:
    def test_scan_trip_count_multiplied(self):
        out = run_py("""
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.mesh import make_mesh
            from repro.launch.hlo_cost import HloCostModel
            mesh = make_mesh((2, 4), ("data", "model"))
            L, B, D = 16, 64, 512
            def step(w, x):
                def body(h, wl):
                    return jnp.tanh(h @ wl), None
                return jnp.sum(jax.lax.scan(body, x, w)[0])
            w = jax.ShapeDtypeStruct((L, D, D), jnp.bfloat16)
            x = jax.ShapeDtypeStruct((B, D), jnp.bfloat16)
            c = jax.jit(step, in_shardings=(
                NamedSharding(mesh, P(None, "data", "model")),
                NamedSharding(mesh, P("data", None)))).lower(w, x).compile()
            cost = HloCostModel(c.as_text()).entry_cost()
            expected = L * 2 * B * D * D / 8      # per-device dot flops
            ratio = cost.flops / expected
            assert 0.9 < ratio < 1.5, ratio       # elementwise adds ~8%
            assert cost.collective_bytes > 0
            print("OK", ratio)
        """)
        assert "OK" in out

    def test_shape_parsing(self):
        from repro.launch.hlo_cost import shape_bytes, shape_elems
        assert shape_bytes("bf16[32,128]{1,0}") == 32 * 128 * 2
        assert shape_bytes("(s32[], f32[4,4]{1,0})") == 4 + 64
        assert shape_bytes("f32[]") == 4
        assert shape_elems("pred[8,2]") == 16


class TestPipeline:
    def test_pipeline_matches_straight_scan(self):
        out = run_py("""
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.mesh import make_mesh
            from repro.parallel.pipeline import make_pipelined_fwd, stage_layers
            mesh = make_mesh((4, 2), ("pod", "model"))
            L, D, M, mb = 8, 32, 8, 4
            w = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.1
            def block_fn(lp, h):
                return jnp.tanh(h @ lp), None
            def ref(x):
                def body(h, wl): return jnp.tanh(h @ wl), None
                return jax.lax.scan(body, x, w)[0]
            x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, D))
            out = jax.jit(make_pipelined_fwd(mesh, block_fn, 4))(
                jax.device_put(stage_layers(w, 4), NamedSharding(mesh, P("pod"))), x)
            err = float(jnp.max(jnp.abs(out - jax.vmap(ref)(x))))
            assert err < 1e-5, err
            print("OK")
        """)
        assert "OK" in out

    def test_bubble_fraction(self):
        from repro.parallel.pipeline import bubble_fraction
        assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
        assert bubble_fraction(1, 8) == 0.0


class TestCompressionCollective:
    def test_cross_pod_allreduce_compressed(self):
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.launch.mesh import make_mesh
            from repro.optim.compression import cross_pod_allreduce_compressed
            mesh = make_mesh((4,), ("pod",))
            g = jax.random.normal(jax.random.PRNGKey(0), (4, 1024))
            err = jnp.zeros((4, 1024))
            def body(g, e):
                return cross_pod_allreduce_compressed(g[0], e[0], axis="pod",
                                                      density=0.05)
            from repro.parallel.compat import shard_map
            avg, new_err = jax.jit(shard_map(
                body, mesh=mesh, in_specs=(P("pod"), P("pod")),
                out_specs=(P(), P("pod")), check_vma=False))(g, err)
            # mass conservation per shard: sent + err == g
            print("OK", float(jnp.sum(jnp.abs(avg))) > 0)
        """)
        assert "OK True" in out


class TestExpertFFNShardMap:
    def test_matches_plain_einsum(self):
        """all-to-all + reduce-scatter expert FFN == plain einsums."""
        out = run_py("""
            import jax, jax.numpy as jnp
            from repro.launch.mesh import make_mesh
            from repro.parallel import sharding as sh
            from repro.models import layers as L
            from repro.configs.base import get_config
            mesh = make_mesh((2, 4), ("data", "model"))
            cfg = get_config("olmoe-1b-7b", reduced=True).replace(
                d_model=64, d_ff=32, n_experts=8, moe_ffn_tp=True)
            plain = cfg.replace(moe_ffn_tp=False)
            g, e, c, d, f = 4, 8, 16, 64, 32
            ks = jax.random.split(jax.random.PRNGKey(0), 4)
            params = {"w1": jax.random.normal(ks[0], (e, d, f)) * 0.05,
                      "w3": jax.random.normal(ks[1], (e, d, f)) * 0.05,
                      "w2": jax.random.normal(ks[2], (e, f, d)) * 0.05}
            xin = jax.random.normal(ks[3], (g, e, c, d), jnp.float32)
            with sh.use_mesh(mesh):
                y_tp = jax.jit(lambda p, x: L._expert_ffn(p, x, cfg, jnp.float32))(params, xin)
                y_pl = jax.jit(lambda p, x: L._expert_ffn(p, x, plain, jnp.float32))(params, xin)
                err = float(jnp.max(jnp.abs(y_tp - y_pl)))
                assert err < 1e-5, err
                gtp = jax.jit(jax.grad(lambda p, x: jnp.sum(
                    L._expert_ffn(p, x, cfg, jnp.float32) ** 2)))(params, xin)
                gpl = jax.jit(jax.grad(lambda p, x: jnp.sum(
                    L._expert_ffn(p, x, plain, jnp.float32) ** 2)))(params, xin)
                gerr = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
                    jax.tree.leaves(gtp), jax.tree.leaves(gpl)))
                assert gerr < 1e-4, gerr
            print("OK")
        """)
        assert "OK" in out


class TestPipelinedTraining:
    def test_pipelined_loss_and_grads_match_straight(self):
        """GPipe over pod with TP (model axis) auto inside the stages:
        loss and grads match the plain scanned model."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.mesh import make_mesh
            from repro.parallel import sharding as sh
            from repro.parallel.pipeline import pipelined_loss_fn
            from repro.configs.base import get_config
            from repro.models.api import Model
            mesh = make_mesh((4, 2), ("pod", "model"))
            cfg = get_config("olmo-1b", reduced=True).replace(
                n_layers=4, remat=False)
            m = Model(cfg)
            params = m.init_params(jax.random.PRNGKey(0))
            batch = m.make_batch("train", 4, 64)
            ref = float(m.loss(params, batch))
            g_ref = jax.grad(lambda p: m.loss(p, batch))(params)
            p2 = dict(params)
            p2["blocks"] = jax.tree.map(
                lambda a: a.reshape((4, 1) + a.shape[1:]), params["blocks"])
            with sh.use_mesh(mesh):
                loss_fn = pipelined_loss_fn(cfg, mesh, n_stages=4, n_micro=2)
                p2["blocks"] = jax.device_put(
                    p2["blocks"], NamedSharding(mesh, P("pod")))
                pl = float(jax.jit(loss_fn)(p2, batch))
                assert abs(pl - ref) < 1e-3, (pl, ref)
                g = jax.jit(jax.grad(loss_fn))(p2, batch)
                d = float(jnp.max(jnp.abs(g["embed"] - g_ref["embed"])))
                assert d < 1e-3, d
            print("OK")
        """)
        assert "OK" in out
