"""The delivery stack: wire format round-trips + truncation errors, tiered
cache accounting, concurrent coalescing frontend, pipelined delta sessions,
push verification, and the peer swarm."""

import threading

import numpy as np
import pytest

from repro.core import cdc, hashing
from repro.core.cdmt import CDMT, CDMTParams
from repro.core.pushpull import Client
from repro.core.registry import PushRejected, Registry
from repro.core.store import ChunkStore, Recipe
from repro.delivery import (DeliveryError, DeltaSession, RegistryServer,
                            SwarmNode, SwarmTracker, TieredChunkCache,
                            swarm_pull, wire)

PARAMS = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)
P = CDMTParams(window=4, rule_bits=2)


def _rand(n, seed=0):
    return np.random.default_rng(seed).integers(0, 256, size=n,
                                                dtype=np.uint8).tobytes()


def _fps(n, seed=0):
    rng = np.random.default_rng(seed)
    return [hashing.chunk_fingerprint(rng.bytes(32)) for _ in range(n)]


def _versions(n_versions=5, size=150_000, seed=0):
    rng = np.random.default_rng(seed)
    data = bytearray(_rand(size, seed))
    out = [bytes(data)]
    for _ in range(n_versions - 1):
        for _ in range(3):
            pos = rng.integers(0, len(data) - 100)
            data[pos:pos + 64] = rng.bytes(64)
        ins = rng.integers(0, len(data))
        data[ins:ins] = rng.bytes(rng.integers(1, 256))
        out.append(bytes(data))
    return out


# ---------------------------------------------------------------- wire format

class TestWireRoundtrip:
    @pytest.mark.parametrize("n", [0, 1, 2, 7, 40, 300])
    def test_index(self, n):
        t = CDMT.build(_fps(n), P)
        back = wire.decode_index(wire.encode_index(t))
        assert back.root == t.root
        assert back.levels == t.levels
        assert set(back.nodes) == set(t.nodes)
        assert back.params == t.params

    def test_index_with_duplicate_leaves(self):
        fps = _fps(20)
        seq = fps + fps[:5] + fps  # repeated chunks in one artifact
        t = CDMT.build(seq, P)
        back = wire.decode_index(wire.encode_index(t))
        assert back.root == t.root and back.leaf_fps() == seq

    def test_index_is_compact(self):
        """The ship-leaves-recompute-parents encoding stays near the
        information floor (~digest bytes per leaf), well under the node
        estimate the core used before."""
        t = CDMT.build(_fps(1000), P)
        assert len(wire.encode_index(t)) < 1.15 * 1000 * hashing.DIGEST_SIZE
        assert len(wire.encode_index(t)) < t.index_size_bytes()

    def test_recipe(self):
        fps = _fps(30, seed=1)
        r = Recipe(name="app:v1", fps=fps, sizes=list(range(30)))
        back = wire.decode_recipe(wire.encode_recipe(r))
        assert (back.name, back.fps, back.sizes) == (r.name, r.fps, r.sizes)

    def test_chunk_batch(self):
        blobs = [_rand(n, seed=n) for n in (0, 1, 100, 5000)]
        chunks = {hashing.chunk_fingerprint(b): b for b in blobs}
        assert wire.decode_chunk_batch(wire.encode_chunk_batch(chunks)) == chunks

    def test_want(self):
        fps = _fps(17, seed=3)
        assert wire.decode_want(wire.encode_want(fps)) == fps

    def test_push_header(self):
        h = wire.PushHeader(lineage="app", tag="v3", root=_fps(1)[0],
                            parent_version=7, params=P)
        back = wire.decode_push_header(wire.encode_push_header(h))
        assert back == h
        h2 = wire.PushHeader(lineage="app", tag="v0", root=_fps(1, 9)[0],
                             parent_version=None,
                             params=CDMTParams())    # defaulted params
        assert wire.decode_push_header(wire.encode_push_header(h2)) == h2
        h3 = wire.PushHeader(lineage="app", tag="v0", root=None,
                             parent_version=None)   # empty artifact
        assert wire.decode_push_header(wire.encode_push_header(h3)) == h3
        with pytest.raises(wire.WireError):          # malformed claimed root
            wire.encode_push_header(wire.PushHeader(
                lineage="a", tag="t", root=b"short", parent_version=None))

    def test_uvarint_boundaries(self):
        for n in (0, 1, 127, 128, 16383, 16384, 2**32, 2**64 - 1):
            enc = wire.encode_uvarint(n)
            assert wire.decode_uvarint(enc) == (n, len(enc))
            assert wire.uvarint_len(n) == len(enc)

    def test_size_helpers_match_encoding(self):
        """Arithmetic sizes must equal real frame lengths byte-for-byte."""
        chunks = {hashing.chunk_fingerprint(b): b
                  for b in (b"", _rand(1), _rand(200, 1), _rand(5000, 2))}
        assert wire.chunk_batch_wire_bytes(chunks) \
            == len(wire.encode_chunk_batch(chunks))
        assert wire.chunk_batch_wire_bytes({}) \
            == len(wire.encode_chunk_batch({}))
        r = Recipe(name="app:v1", fps=_fps(30), sizes=list(range(30)))
        assert wire.recipe_wire_bytes(r) == len(wire.encode_recipe(r))


class TestWireErrors:
    def test_truncation_always_raises(self):
        t = CDMT.build(_fps(50), P)
        chunks = {hashing.chunk_fingerprint(b): b
                  for b in (_rand(200, 1), _rand(300, 2))}
        r = Recipe(name="x", fps=_fps(5), sizes=[1, 2, 3, 4, 5])
        frames = [
            (wire.encode_index(t), wire.decode_index),
            (wire.encode_chunk_batch(chunks), wire.decode_chunk_batch),
            (wire.encode_recipe(r), wire.decode_recipe),
            (wire.encode_want(_fps(9)), wire.decode_want),
        ]
        for frame, decode in frames:
            for cut in range(0, len(frame), max(1, len(frame) // 37)):
                with pytest.raises(wire.WireError):
                    decode(frame[:cut])

    def test_bad_magic_and_type(self):
        frame = wire.encode_want(_fps(2))
        with pytest.raises(wire.WireError):
            wire.decode_want(b"XX" + frame[2:])
        with pytest.raises(wire.WireError):
            wire.decode_want(frame[:3] + bytes([99]) + frame[4:])

    def test_wrong_frame_type_rejected(self):
        with pytest.raises(wire.WireError):
            wire.decode_recipe(wire.encode_want(_fps(2)))

    def test_trailing_garbage_rejected(self):
        frame = wire.encode_want(_fps(2))
        with pytest.raises(wire.WireError):
            wire.decode_want(frame + b"\x00")

    def test_tampered_chunk_payload_rejected(self):
        data = _rand(500, seed=4)
        frame = bytearray(wire.encode_chunk_batch(
            {hashing.chunk_fingerprint(data): data}))
        frame[-1] ^= 0xFF
        with pytest.raises(wire.WireError):
            wire.decode_chunk_batch(bytes(frame))

    def test_tampered_index_changes_root(self):
        """Internal ids are recomputed on decode, so leaf tampering yields a
        *different* root — the claimed root check catches it upstream."""
        t = CDMT.build(_fps(64), P)
        frame = bytearray(wire.encode_index(t))
        frame[30] ^= 0x01   # inside the leaf fp region
        try:
            back = wire.decode_index(bytes(frame))
            assert back.root != t.root
        except wire.WireError:
            pass            # structural damage is also acceptable


# ---------------------------------------------------------------- chunk cache

class TestTieredCache:
    def test_hit_miss_promotion(self):
        store = ChunkStore()
        data = _rand(1000)
        fp = hashing.chunk_fingerprint(data)
        store.put(fp, data)
        cache = TieredChunkCache(store, capacity_bytes=10_000)
        assert cache.get(fp) == data            # miss → promote
        assert cache.get(fp) == data            # hit
        s = cache.stats
        assert (s.hits, s.misses) == (1, 1)
        assert s.hit_rate == 0.5

    def test_lru_eviction_accounting(self):
        cache = TieredChunkCache(ChunkStore(), capacity_bytes=2500)
        blobs = [_rand(1000, seed=i) for i in range(4)]
        fps = [hashing.chunk_fingerprint(b) for b in blobs]
        for fp, b in zip(fps, blobs):
            cache.put(fp, b)
        s = cache.stats
        assert s.evictions == 2                 # capacity fits 2 of 4
        assert s.resident_bytes <= 2500
        # the two most recent stay resident
        assert set(cache.resident_fps()) == set(fps[2:])
        # evicted chunks still come back from the backing tier
        assert cache.get(fps[0]) == blobs[0]

    def test_oversized_chunk_bypasses_memory(self):
        cache = TieredChunkCache(ChunkStore(), capacity_bytes=100)
        data = _rand(1000, seed=9)
        fp = hashing.chunk_fingerprint(data)
        cache.put(fp, data)
        assert cache.stats.resident_bytes == 0
        assert cache.get(fp) == data

    def test_absent_raises_keyerror(self):
        cache = TieredChunkCache(ChunkStore())
        with pytest.raises(KeyError):
            cache.get(b"\x00" * hashing.DIGEST_SIZE)


# ----------------------------------------------------------- registry server

def _loaded_server(n_versions=5, seed=3, **kw):
    reg, cl = Registry(), Client(cdc_params=PARAMS)
    versions = _versions(n_versions, seed=seed)
    for i, v in enumerate(versions):
        cl.commit("app", f"v{i}", v)
        cl.push(reg, "app", f"v{i}")
    return RegistryServer(reg, **kw), versions


class TestRegistryServer:
    def test_index_and_recipe_frames_decode(self):
        srv, _ = _loaded_server()
        idx = wire.decode_index(srv.get_index("app", "v0"))
        assert idx.root is not None
        recipe = wire.decode_recipe(srv.get_recipe("app", "v0"))
        assert recipe.total_size > 0
        assert srv.snapshot().egress_bytes > 0

    def test_want_batching(self):
        srv, _ = _loaded_server()
        recipe = wire.decode_recipe(srv.get_recipe("app", "v0"))
        fps = list(dict.fromkeys(recipe.fps))
        frames = srv.handle_want(wire.encode_want(fps))
        assert len(frames) == -(-len(fps) // srv.max_batch_chunks)
        got = {}
        for f in frames:
            got.update(wire.decode_chunk_batch(f))
        assert set(got) == set(fps)

    def test_unknown_fps_omitted(self):
        srv, _ = _loaded_server()
        frames = srv.handle_want(wire.encode_want(_fps(3, seed=99)))
        assert all(wire.decode_chunk_batch(f) == {} for f in frames)

    def test_concurrent_pullers_coalesce(self):
        srv, _ = _loaded_server(n_versions=2, seed=6)
        recipe = wire.decode_recipe(srv.get_recipe("app", "v1"))
        want = wire.encode_want(list(dict.fromkeys(recipe.fps)))
        n_threads, results, errors = 8, [], []

        barrier = threading.Barrier(n_threads)

        def puller():
            try:
                barrier.wait()
                got = {}
                for f in srv.handle_want(want):
                    got.update(wire.decode_chunk_batch(f))
                results.append(got)
            except Exception as e:  # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=puller) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert all(r == results[0] for r in results)
        s = srv.snapshot()
        # every requested chunk was read at most once per wave from the
        # cache/store; the rest piggy-backed on in-flight reads or hit the LRU
        assert s.store_reads + s.coalesced_reads \
            == n_threads * len(wire.decode_want(want))


# ------------------------------------------------------------- delta sessions

class TestDeltaSession:
    def test_pull_materializes_and_saves_wire(self):
        srv, versions = _loaded_server(n_versions=8, seed=8)
        cl = Client(cdc_params=PARAMS)
        sess = DeltaSession(cl, srv, batch_chunks=16, pipeline_depth=3)
        s0 = sess.pull("app", "v0")
        assert cl.materialize("app", "v0") == versions[0]
        assert s0.chunks_moved == s0.chunks_total

        naive_total = cdmt_total = 0
        for i in range(1, len(versions)):
            st = sess.pull("app", f"v{i}")
            assert cl.materialize("app", f"v{i}") == versions[i]
            naive_total += st.raw_bytes
            cdmt_total += st.total_wire_bytes
        # acceptance: warm-lineage pulls move ≥40% fewer *serialized* bytes
        assert cdmt_total < 0.6 * naive_total

    def test_pull_pipelines_rounds(self):
        srv, _ = _loaded_server(n_versions=2, seed=11)
        cl = Client(cdc_params=PARAMS)
        st = DeltaSession(cl, srv, batch_chunks=8).pull("app", "v1")
        assert st.rounds > 1                   # transfer was actually batched
        assert st.want_bytes > 0

    def test_wire_push_roundtrip(self):
        reg = Registry()
        srv = RegistryServer(reg)
        cl = Client(cdc_params=PARAMS)
        versions = _versions(3, seed=12)
        sess = DeltaSession(cl, srv)
        for i, v in enumerate(versions):
            cl.commit("app", f"v{i}", v)
            st = sess.push("app", f"v{i}")
            assert st.chunks_moved <= st.chunks_total
        assert reg.tags("app") == ["v0", "v1", "v2"]
        fresh = Client(cdc_params=PARAMS)
        DeltaSession(fresh, srv).pull("app", "v2")
        assert fresh.materialize("app", "v2") == versions[2]
        # incremental push moved only the edits
        assert cl.log == []                    # sessions do their own logging

    def test_empty_artifact_roundtrip(self):
        srv = RegistryServer(Registry())
        pub = Client(cdc_params=PARAMS)
        pub.commit("empty", "v0", b"")
        DeltaSession(pub, srv).push("empty", "v0")
        cl = Client(cdc_params=PARAMS)
        DeltaSession(cl, srv).pull("empty", "v0")
        assert cl.materialize("empty", "v0") == b""

    def test_rootless_nonempty_push_rejected(self):
        srv = RegistryServer(Registry())
        pub = Client(cdc_params=PARAMS)
        pub.commit("app", "v0", _rand(30_000, seed=5))
        recipe = pub.store.recipes["app:v0"]
        hdr = wire.encode_push_header(wire.PushHeader(
            lineage="app", tag="v0", root=None, parent_version=None))
        chunks = {fp: pub.store.chunks.get(fp) for fp in recipe.fps}
        with pytest.raises(wire.WireError):
            srv.handle_push(hdr, wire.encode_recipe(recipe),
                            [wire.encode_chunk_batch(chunks)])

    def test_omitted_chunks_raise_delivery_error(self, monkeypatch):
        """If the registry cannot serve a chunk the index promised, the pull
        must fail loudly instead of committing a partial artifact."""
        srv, _ = _loaded_server(n_versions=1, seed=14)
        victim = srv.registry.recipe_for("app", "v0").fps[0]
        real_get = TieredChunkCache.get

        def flaky_get(self, fp):
            if fp == victim:
                raise KeyError(fp.hex())
            return real_get(self, fp)

        monkeypatch.setattr(TieredChunkCache, "get", flaky_get)
        cl = Client(cdc_params=PARAMS)
        with pytest.raises(DeliveryError):
            DeltaSession(cl, srv).pull("app", "v0")
        assert "app:v0" not in cl.store.recipes   # nothing half-committed

    def test_delta_equals_plain_client_bytes(self):
        """The session protocol must not move MORE than the plain in-process
        protocol — pipelining changes latency, not byte counts (modulo the
        per-batch WANT/frame overhead)."""
        srv, versions = _loaded_server(n_versions=5, seed=13)
        a, b = Client(cdc_params=PARAMS), Client(cdc_params=PARAMS)
        sess = DeltaSession(a, srv, batch_chunks=10_000)  # one batch
        plain_reg = srv.registry
        for tag in ("v0", "v4"):
            sa = sess.pull("app", tag)
            sb = b.pull(plain_reg, "app", tag)
            assert sa.chunk_bytes <= 1.02 * sb.chunk_bytes + 64


# ---------------------------------------------------------- server error paths

class TestServerErrorPaths:
    def test_unknown_lineage_and_tag_surface_as_delivery_error(self):
        """The wire frontend must hand clients a protocol-level error, not a
        bare KeyError, for unknown lineages/tags."""
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        cl.commit("app", "v0", _rand(30_000, seed=30))
        srv = RegistryServer(reg)
        DeltaSession(cl, srv).push("app", "v0")
        fresh = Client(cdc_params=PARAMS)
        with pytest.raises(DeliveryError):
            DeltaSession(fresh, srv).pull("ghost-lineage", "v0")
        with pytest.raises(DeliveryError):
            DeltaSession(fresh, srv).pull("app", "ghost-tag")
        assert "ghost-lineage:v0" not in fresh.store.recipes

    def test_wire_record_roundtrip_and_corruption(self):
        rec = wire.encode_record(3, b"journal payload")
        rtype, payload, off = wire.decode_record(rec)
        assert (rtype, payload, off) == (3, b"journal payload", len(rec))
        for cut in (1, 5, len(rec) - 1):
            with pytest.raises(wire.WireError):
                wire.decode_record(rec[:cut])
        flipped = rec[:-1] + bytes([rec[-1] ^ 0xFF])
        with pytest.raises(wire.WireError):
            wire.decode_record(flipped)

    def test_wire_tag_repush_semantics(self):
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        srv = RegistryServer(reg)
        sess = DeltaSession(cl, srv)
        data = _rand(40_000, seed=31)
        cl.commit("app", "v0", data)
        sess.push("app", "v0")
        # same tag, same content: idempotent (no duplicate version)
        sess.push("app", "v0")
        assert reg.tags("app") == ["v0"]
        # same tag, different content: rejected at the registry
        cl.commit("app", "v0", _rand(40_000, seed=32))
        with pytest.raises(PushRejected):
            sess.push("app", "v0")
        assert reg.tags("app") == ["v0"]


# ----------------------------------------------------------- push verification

class TestPushVerification:
    def test_root_mismatch_rejected_and_state_untouched(self):
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        cl.commit("app", "v0", _rand(50_000, seed=1))
        recipe = cl.store.recipes["app:v0"]
        payload = {fp: cl.store.chunks.get(fp) for fp in recipe.fps}
        with pytest.raises(PushRejected):
            reg.receive_push("app", "v0", recipe, payload,
                             claimed_root=b"\xde\xad" * 8)
        assert reg.tags("app") == []
        assert reg.store.chunks.n_chunks() == 0

    def test_recipe_chunk_mismatch_rejected(self):
        """A recipe whose leaf sequence doesn't hash to the claimed root is
        exactly the forged-index attack the root check exists for."""
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        cl.commit("app", "v0", _rand(50_000, seed=2))
        recipe = cl.store.recipes["app:v0"]
        payload = {fp: cl.store.chunks.get(fp) for fp in recipe.fps}
        claimed = cl.indexes["app"].root
        forged = Recipe(name=recipe.name, fps=list(reversed(recipe.fps)),
                        sizes=list(reversed(recipe.sizes)))
        with pytest.raises(PushRejected):
            reg.receive_push("app", "v0", forged, payload,
                             claimed_root=claimed)

    def test_tampered_chunk_rejected(self):
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        cl.commit("app", "v0", _rand(50_000, seed=3))
        recipe = cl.store.recipes["app:v0"]
        payload = {fp: cl.store.chunks.get(fp) for fp in recipe.fps}
        victim = recipe.fps[0]
        payload[victim] = payload[victim][:-1] + b"\x00"
        with pytest.raises(PushRejected):
            reg.receive_push("app", "v0", recipe, payload,
                             claimed_root=cl.indexes["app"].root)

    def test_incomplete_push_rejected(self):
        """A recipe referencing chunks neither pushed nor stored must be
        rejected — committing it would create an unreconstructable version."""
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        cl.commit("app", "v0", _rand(50_000, seed=8))
        recipe = cl.store.recipes["app:v0"]
        payload = {fp: cl.store.chunks.get(fp) for fp in recipe.fps}
        del payload[recipe.fps[len(recipe.fps) // 2]]
        with pytest.raises(PushRejected):
            reg.receive_push("app", "v0", recipe, payload,
                             claimed_root=cl.indexes["app"].root)
        assert reg.tags("app") == []

    def test_rejected_push_leaves_node_store_untouched(self):
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        cl.commit("app", "v0", _rand(50_000, seed=9))
        recipe = cl.store.recipes["app:v0"]
        payload = {fp: cl.store.chunks.get(fp) for fp in recipe.fps}
        with pytest.raises(PushRejected):
            reg.receive_push("app", "v0", recipe, payload,
                             claimed_root=b"\x00" * 16)
        lin = reg.lineages.get("app")
        assert lin is None or len(lin.node_store) == 0

    def test_unreferenced_chunk_push_rejected(self):
        """Pushed chunks the recipe never references must be refused —
        otherwise verified pushes could still bloat the store with
        unreachable data."""
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        cl.commit("app", "v0", _rand(50_000, seed=15))
        recipe = cl.store.recipes["app:v0"]
        payload = {fp: cl.store.chunks.get(fp) for fp in recipe.fps}
        junk = _rand(999, seed=16)
        payload[hashing.chunk_fingerprint(junk)] = junk
        with pytest.raises(PushRejected):
            reg.receive_push("app", "v0", recipe, payload,
                             claimed_root=cl.indexes["app"].root)
        assert reg.store.chunks.n_chunks() == 0

    def test_push_non_head_tag(self):
        """Pushing a tag that is no longer the lineage head must rebuild
        that tag's index from its recipe, not diff/claim the head's tree."""
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        versions = _versions(3, seed=10)
        for i, v in enumerate(versions):
            cl.commit("app", f"v{i}", v)     # commit all, push none
        for i in (0, 2, 1):                   # push out of order
            cl.push(reg, "app", f"v{i}")
        for i, v in enumerate(versions):
            fresh = Client(cdc_params=PARAMS)
            fresh.pull(reg, "app", f"v{i}")
            assert fresh.materialize("app", f"v{i}") == v

    def test_honest_push_accepted(self):
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        data = _rand(50_000, seed=4)
        cl.commit("app", "v0", data)
        stats = cl.push(reg, "app", "v0")   # Client.push claims its root
        assert stats.chunks_moved == stats.chunks_total
        fresh = Client(cdc_params=PARAMS)
        fresh.pull(reg, "app", "v0")
        assert fresh.materialize("app", "v0") == data

    def test_client_with_custom_cdmt_params_can_push(self):
        """Root verification must use the params the client built with —
        the claim travels with its params (in-process and on the wire)."""
        data = _rand(50_000, seed=11)
        reg = Registry()                            # default CDMTParams
        cl = Client(cdc_params=PARAMS, cdmt_params=P)   # window=4
        cl.commit("app", "v0", data)
        cl.push(reg, "app", "v0")                   # must not PushRejected
        srv = RegistryServer(Registry())
        cl2 = Client(cdc_params=PARAMS, cdmt_params=P)
        cl2.commit("app", "v0", data)
        DeltaSession(cl2, srv).push("app", "v0")    # wire path too
        fresh = Client(cdc_params=PARAMS)
        DeltaSession(fresh, srv).pull("app", "v0")
        assert fresh.materialize("app", "v0") == data


# -------------------------------------------------------------------- swarm

class TestSwarm:
    def test_second_client_pulls_mostly_from_peer(self):
        srv, versions = _loaded_server(n_versions=3, seed=21)
        tracker = SwarmTracker()
        a = SwarmNode("a", cdc_params=PARAMS)
        sa = swarm_pull(a, srv, tracker, "app", "v2")
        assert sa.chunks_from_peers == 0          # nobody to ask yet
        assert a.client.materialize("app", "v2") == versions[2]

        b = SwarmNode("b", cdc_params=PARAMS)
        sb = swarm_pull(b, srv, tracker, "app", "v2")
        assert b.client.materialize("app", "v2") == versions[2]
        # satellite acceptance: ≥50% of chunks arrive from the peer
        assert sb.chunks_from_peers >= 0.5 * sb.chunks_moved
        assert sb.peer_offload_fraction >= 0.5

    def test_partial_peer_falls_back_to_registry(self):
        srv, versions = _loaded_server(n_versions=4, seed=22)
        tracker = SwarmTracker()
        a = SwarmNode("a", cdc_params=PARAMS)
        swarm_pull(a, srv, tracker, "app", "v0")  # peer only has v0
        b = SwarmNode("b", cdc_params=PARAMS)
        sb = swarm_pull(b, srv, tracker, "app", "v3")
        assert b.client.materialize("app", "v3") == versions[3]
        assert sb.registry_chunk_bytes > 0        # v3-only chunks from registry
        assert sb.chunks_moved == sb.chunks_total

    def test_swarm_reduces_registry_egress(self):
        srv, _ = _loaded_server(n_versions=2, seed=23)
        base = srv.snapshot().egress_bytes
        tracker = SwarmTracker()
        first = SwarmNode("n0", cdc_params=PARAMS)
        swarm_pull(first, srv, tracker, "app", "v1")
        egress_first = srv.snapshot().egress_bytes - base
        later = srv.snapshot().egress_bytes
        for i in range(1, 4):
            swarm_pull(SwarmNode(f"n{i}", cdc_params=PARAMS), srv, tracker,
                       "app", "v1")
        per_later = (srv.snapshot().egress_bytes - later) / 3
        # followers cost the registry a small fraction of the first pull
        assert per_later < 0.3 * egress_first
