"""AdamW, schedules, gradient compression, and the data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, TokenPipeline
from repro.optim import (AdamWConfig, adamw_init, adamw_update, apply_updates,
                         clip_by_global_norm, cosine_schedule, global_norm)
from repro.optim.compression import (CompressionConfig, compress_tree,
                                     init_error_state, wire_bytes_compressed,
                                     wire_bytes_dense)


class TestAdamW:
    def test_first_step_is_lr_sized(self):
        cfg = AdamWConfig(lr=1e-2, weight_decay=0.0)
        params = {"w": jnp.ones((4,))}
        st = adamw_init(params, cfg)
        g = {"w": jnp.full((4,), 0.5)}
        upd, st = adamw_update(g, st, params, cfg)
        # bias-corrected first step ≈ -lr * sign(g)
        np.testing.assert_allclose(np.asarray(upd["w"]), -1e-2, rtol=1e-3)

    def test_converges_on_quadratic(self):
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
        params = {"w": jnp.asarray([3.0, -2.0])}
        st = adamw_init(params, cfg)
        for _ in range(300):
            g = {"w": 2 * params["w"]}
            upd, st = adamw_update(g, st, params, cfg)
            params = apply_updates(params, upd)
        assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2

    def test_weight_decay_decoupled(self):
        cfg = AdamWConfig(lr=1e-2, weight_decay=0.5)
        params = {"w": jnp.asarray([1.0])}
        st = adamw_init(params, cfg)
        upd, _ = adamw_update({"w": jnp.asarray([0.0])}, st, params, cfg)
        np.testing.assert_allclose(np.asarray(upd["w"]), [-1e-2 * 0.5])

    def test_state_dtype(self):
        cfg = AdamWConfig(state_dtype=jnp.bfloat16)
        st = adamw_init({"w": jnp.ones((3,))}, cfg)
        assert st["m"]["w"].dtype == jnp.bfloat16

    def test_clip(self):
        g = {"a": jnp.full((100,), 1.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert abs(float(norm) - 10.0) < 1e-5
        assert abs(float(global_norm(clipped)) - 1.0) < 1e-4


def test_cosine_schedule():
    f = cosine_schedule(1e-3, warmup_steps=10, total_steps=100)
    assert float(f(0)) == 0.0
    assert abs(float(f(10)) - 1e-3) < 1e-9
    assert float(f(100)) == pytest.approx(1e-4, rel=1e-3)
    assert float(f(55)) < float(f(10))


class TestCompression:
    def test_topk_keeps_largest(self):
        cfg = CompressionConfig(density=0.1, min_size=1)
        g = {"w": jnp.arange(100, dtype=jnp.float32)}
        err = init_error_state(g)
        sent, new_err = compress_tree(g, err, cfg)
        nz = np.flatnonzero(np.asarray(sent["w"]))
        assert set(nz) == set(range(90, 100))

    def test_error_feedback_preserves_mass(self):
        """sent + residual == g + old_residual (no gradient is ever lost)."""
        cfg = CompressionConfig(density=0.05, min_size=1)
        rng = jax.random.PRNGKey(0)
        g = {"w": jax.random.normal(rng, (500,))}
        err = init_error_state(g)
        sent, err2 = compress_tree(g, err, cfg)
        np.testing.assert_allclose(
            np.asarray(sent["w"] + err2["w"]), np.asarray(g["w"]), atol=1e-6)

    def test_residual_reinjected_next_step(self):
        cfg = CompressionConfig(density=0.01, min_size=1)
        g = {"w": jnp.arange(1, 1001, dtype=jnp.float32) / 1000.0}
        err = init_error_state(g)
        _, err = compress_tree(g, err, cfg)
        sent2, _ = compress_tree(g, err, cfg)
        # accumulated residual makes previously-dropped entries win top-k
        assert float(jnp.max(sent2["w"])) >= 1.9

    def test_wire_model(self):
        cfg = CompressionConfig(density=0.01, min_size=1024)
        g = {"w": jnp.zeros((100_000,), jnp.float32)}
        dense = wire_bytes_dense(g)
        comp = wire_bytes_compressed(g, cfg)
        assert comp < 0.05 * dense


class TestDataPipeline:
    CFG = DataConfig(vocab=1000, seq_len=64, global_batch=8, n_hosts=4, seed=7)

    def test_deterministic(self):
        p1, p2 = TokenPipeline(self.CFG), TokenPipeline(self.CFG)
        b1, b2 = p1.batch_for(5, 2), p2.batch_for(5, 2)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_hosts_disjoint_and_cover(self):
        pipe = TokenPipeline(self.CFG)
        rows = [pipe.shard_rows(0, h) for h in range(4)]
        flat = sorted(r for rs in rows for r in rs)
        assert flat == list(range(8))

    def test_steps_differ(self):
        pipe = TokenPipeline(self.CFG)
        a, b = pipe.batch_for(0, 0), pipe.batch_for(1, 0)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_targets_shifted(self):
        pipe = TokenPipeline(self.CFG)
        b = pipe.batch_for(0, 0)
        assert b["tokens"].shape == (2, 64)
        # target[i] is the next token of the same virtual row

    def test_reassignment_regenerates_same_rows(self):
        """Straggler mitigation: the replacement host generates exactly the
        rows the straggler would have."""
        pipe = TokenPipeline(self.CFG)
        orig = pipe.batch_for(3, 1)
        rows = pipe.shard_rows(3, 0, reassignment={1: 0})
        covered = pipe.batch_for(3, 0, rows=rows)
        # host 0 now covers its own rows + host 1's rows
        assert len(rows) == 4
        np.testing.assert_array_equal(covered["tokens"][2:], orig["tokens"])
