"""Dedup store, registry, and the chunk-granular push/pull protocol."""

import json

import numpy as np
import pytest

from repro.core import cdc, hashing
from repro.core.errors import DeliveryError
from repro.core.pushpull import Client, merkle_pull_chunk_bytes, naive_pull_bytes
from repro.core.registry import Registry
from repro.core.store import DedupStore, Recipe

PARAMS = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)


def _rand(n, seed=0):
    return np.random.default_rng(seed).integers(0, 256, size=n,
                                                dtype=np.uint8).tobytes()


def _versions(n_versions=5, size=150_000, seed=0):
    """Synthetic version chain: each version edits ~2% of the previous."""
    rng = np.random.default_rng(seed)
    data = bytearray(_rand(size, seed))
    out = [bytes(data)]
    for _ in range(n_versions - 1):
        for _ in range(3):
            pos = rng.integers(0, len(data) - 100)
            data[pos:pos + 64] = rng.bytes(64)
        ins = rng.integers(0, len(data))
        data[ins:ins] = rng.bytes(rng.integers(1, 256))   # chunk-shift source
        out.append(bytes(data))
    return out


class TestDedupStore:
    def test_ingest_restore_roundtrip(self):
        st = DedupStore(cdc_params=PARAMS)
        data = _rand(120_000)
        st.ingest("a", data)
        assert st.restore("a") == data

    def test_dedup_across_versions(self):
        st = DedupStore(cdc_params=PARAMS)
        versions = _versions()
        for i, v in enumerate(versions):
            st.ingest(f"v{i}", v)
        assert st.dedup_ratio() > 3.0             # ~5 similar versions
        for i, v in enumerate(versions):
            assert st.restore(f"v{i}") == v

    def test_disk_persistence(self, tmp_path):
        st = DedupStore(str(tmp_path / "store"), cdc_params=PARAMS)
        data = _rand(60_000, seed=2)
        st.ingest("a", data)
        recipe = st.recipes["a"]
        # reopen: chunk log + index reload from disk
        st2 = DedupStore(str(tmp_path / "store"), cdc_params=PARAMS)
        st2.recipes["a"] = Recipe.from_json(recipe.to_json())
        assert st2.restore("a") == data


class TestIngestVerification:
    def _chunks(self, n=4, seed=20):
        rng = np.random.default_rng(seed)
        payloads = [rng.bytes(64) for _ in range(n)]
        fps = [hashing.chunk_fingerprint(p) for p in payloads]
        return fps, dict(zip(fps, payloads)), [64] * n

    def test_bad_payload_rejected_before_any_mutation(self):
        st = DedupStore(cdc_params=PARAMS)
        fps, chunks, sizes = self._chunks()
        chunks[fps[1]] = chunks[fps[1]][:-1] + b"\x00"     # tampered
        with pytest.raises(DeliveryError):
            st.ingest_chunks("a", fps, chunks, sizes)
        assert "a" not in st.recipes            # nothing half-committed
        assert st.chunks.n_chunks() == 0

    def test_missing_chunk_rejected_with_clear_error(self):
        """Previously a bad pull only surfaced later as an opaque KeyError
        in restore(); now ingest itself names the missing fingerprint."""
        st = DedupStore(cdc_params=PARAMS)
        fps, chunks, sizes = self._chunks()
        del chunks[fps[2]]
        with pytest.raises(DeliveryError, match=fps[2].hex()[:12]):
            st.ingest_chunks("a", fps, chunks, sizes)
        assert "a" not in st.recipes

    def test_size_mismatch_rejected(self):
        st = DedupStore(cdc_params=PARAMS)
        fps, chunks, sizes = self._chunks()
        with pytest.raises(DeliveryError):
            st.ingest_chunks("a", fps, chunks, sizes[:-1])

    def test_already_stored_chunks_need_not_be_provided(self):
        st = DedupStore(cdc_params=PARAMS)
        fps, chunks, sizes = self._chunks()
        st.chunks.put(fps[0], chunks[fps[0]])
        partial = {fp: chunks[fp] for fp in fps[1:]}
        st.ingest_chunks("a", fps, partial, sizes)
        assert st.restore("a") == b"".join(chunks[fp] for fp in fps)


class TestServeErrors:
    def test_serve_chunks_unknown_fp_is_clean_error(self):
        reg = Registry()
        ghost = hashing.chunk_fingerprint(b"never pushed")
        with pytest.raises(DeliveryError, match=ghost.hex()[:12]):
            reg.serve_chunks([ghost])

    def test_unknown_lineage_and_tag_are_clean_errors(self):
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        cl.commit("app", "v0", _rand(30_000, seed=21))
        cl.push(reg, "app", "v0")
        with pytest.raises(DeliveryError):
            reg.index_for_tag("nope", "v0")
        with pytest.raises(DeliveryError):
            reg.index_for_tag("app", "nope")
        with pytest.raises(DeliveryError):
            reg.recipe_for("app", "nope")
        # the failed lookups must not have created phantom lineages
        assert set(reg.lineages) == {"app"}


class TestRecipeValidation:
    def test_roundtrip_ok(self):
        r = Recipe("a", [hashing.chunk_fingerprint(b"x")], [1])
        r2 = Recipe.from_json(r.to_json())
        assert r2.fps == r.fps and r2.sizes == r.sizes

    def test_length_mismatch_rejected(self):
        r = Recipe("a", [hashing.chunk_fingerprint(b"x")], [1])
        d = json.loads(r.to_json())
        d["sizes"] = [1, 2]
        with pytest.raises(ValueError):
            Recipe.from_json(json.dumps(d))

    def test_bad_digest_size_rejected(self):
        d = {"name": "a", "fps": ["abcd"], "sizes": [1]}
        with pytest.raises(ValueError):
            Recipe.from_json(json.dumps(d))

    def test_negative_size_rejected(self):
        d = {"name": "a", "fps": [hashing.chunk_fingerprint(b"x").hex()],
             "sizes": [-5]}
        with pytest.raises(ValueError):
            Recipe.from_json(json.dumps(d))


class TestPushPull:
    def test_push_new_then_incremental(self):
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        versions = _versions(seed=3)
        cl.commit("app", "v0", versions[0])
        s0 = cl.push(reg, "app", "v0")
        assert s0.chunks_moved == s0.chunks_total  # new image: all chunks
        cl.commit("app", "v1", versions[1])
        s1 = cl.push(reg, "app", "v1")
        assert s1.chunk_bytes < 0.2 * s1.raw_bytes  # only the edits move
        assert s1.savings_vs_raw > 0.7

    def test_pull_roundtrip_and_incremental(self):
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        versions = _versions(seed=4)
        for i, v in enumerate(versions):
            cl.commit("app", f"v{i}", v)
            cl.push(reg, "app", f"v{i}")
        fresh = Client(cdc_params=PARAMS)
        p0 = fresh.pull(reg, "app", "v0")
        assert fresh.materialize("app", "v0") == versions[0]
        assert p0.chunks_moved == p0.chunks_total
        p_last = fresh.pull(reg, "app", f"v{len(versions)-1}")
        assert fresh.materialize("app", f"v{len(versions)-1}") == versions[-1]
        # upgrading v0 -> v4 moves ≪ the full artifact (Table II)
        assert p_last.chunk_bytes < 0.5 * p_last.raw_bytes

    def test_registry_serves_all_versions(self):
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        versions = _versions(3, seed=5)
        for i, v in enumerate(versions):
            cl.commit("app", f"v{i}", v)
            cl.push(reg, "app", f"v{i}")
        assert reg.tags("app") == ["v0", "v1", "v2"]
        for i, v in enumerate(versions):
            c = Client(cdc_params=PARAMS)
            c.pull(reg, "app", f"v{i}")
            assert c.materialize("app", f"v{i}") == v

    def test_cross_lineage_global_dedup(self):
        """Chunks shared across lineages aren't re-fetched (client store
        check is chunk-granular, not per-lineage)."""
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        base = _rand(100_000, seed=6)
        cl.commit("a", "v0", base)
        cl.push(reg, "a", "v0")
        cl.commit("b", "v0", base + _rand(10_000, seed=7))
        sb = cl.push(reg, "b", "v0")
        fresh = Client(cdc_params=PARAMS)
        fresh.pull(reg, "a", "v0")
        pb = fresh.pull(reg, "b", "v0")
        assert pb.chunk_bytes < 0.4 * pb.raw_bytes

    def test_cdmt_beats_naive_by_over_40pct(self):
        """The paper's headline: without the index, chunk exchange costs
        >40% more network."""
        reg, cl = Registry(), Client(cdc_params=PARAMS)
        versions = _versions(8, seed=8)
        for i, v in enumerate(versions):
            cl.commit("app", f"v{i}", v)
            cl.push(reg, "app", f"v{i}")
        upgr = Client(cdc_params=PARAMS)
        upgr.pull(reg, "app", "v0")
        naive_total = 0
        cdmt_total = 0
        for i in range(1, len(versions)):
            stats = upgr.pull(reg, "app", f"v{i}")
            cdmt_total += stats.total_wire_bytes
            naive_total += stats.raw_bytes
        assert naive_total > 1.4 * cdmt_total
