"""Hypothesis property tests (CDC, CDMT, checkpoint serializer, wire format).

Collected only when ``hypothesis`` is installed — the module-level
``importorskip`` keeps tier-1 runs green on minimal environments while CI
with dev extras still gets full property coverage.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import cdc, hashing  # noqa: E402
from repro.core.cdmt import CDMT, CDMTParams, compare  # noqa: E402
from repro.core.store import Recipe  # noqa: E402
from repro.delivery import wire  # noqa: E402

PARAMS = cdc.CDCParams(mask_bits=10, min_size=128, max_size=8192)
P = CDMTParams(window=4, rule_bits=2)


def _rand(n, seed=0):
    return np.random.default_rng(seed).integers(0, 256, size=n,
                                                dtype=np.uint8).tobytes()


def _fps(n, seed=0):
    rng = np.random.default_rng(seed)
    return [hashing.chunk_fingerprint(rng.bytes(32)) for _ in range(n)]


# ------------------------------------------------------------------- CDC

@settings(max_examples=25, deadline=None)
@given(data=st.binary(min_size=0, max_size=30_000))
def test_property_reconstruction(data):
    assert b"".join(cdc.chunk_bytes(data, PARAMS)) == data


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 20_000), seed=st.integers(0, 100),
       cut=st.integers(0, 20_000), ins=st.binary(min_size=1, max_size=64))
def test_property_edit_locality(n, seed, cut, ins):
    data = _rand(n, seed)
    cut = min(cut, n)
    edited = data[:cut] + ins + data[cut:]
    chunks_a = {bytes(c) for c in cdc.chunk_bytes(data, PARAMS)}
    chunks_b = list(cdc.chunk_bytes(edited, PARAMS))
    shared = sum(1 for c in chunks_b if bytes(c) in chunks_a)
    # at most a bounded number of chunks around the edit can change
    assert len(chunks_b) - shared <= 3 + (len(ins) + 2 * PARAMS.max_size) // PARAMS.min_size


# ------------------------------------------------------------------ CDMT

@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 400), seed=st.integers(0, 50))
def test_property_build_covers_all_leaves(n, seed):
    fps = _fps(n, seed)
    t = CDMT.build(fps, P)
    missing, _ = compare(None, t)
    assert missing == set(fps)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 300), seed=st.integers(0, 50),
       k=st.integers(0, 7))
def test_property_compare_finds_all_new(n, seed, k):
    fps = _fps(n, seed)
    new = _fps(k, seed + 1000)
    pos = n // 2
    edited = fps[:pos] + new + fps[pos:]
    a, b = CDMT.build(fps, P), CDMT.build(edited, P)
    missing, _ = compare(a, b)
    # Alg. 2 must never MISS a chunk the client lacks (superset is fine —
    # extra chunks only cost bandwidth, missing ones break reconstruction)
    assert set(new) <= missing | set(fps)


# ------------------------------------------------------- checkpoint serializer

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), groups=st.integers(1, 5),
       n_leaves=st.integers(1, 6), byte_plane=st.booleans())
def test_property_serializer_roundtrip(seed, groups, n_leaves, byte_plane):
    """Any dict pytree of numeric arrays roundtrips exactly through any
    group count and either layout."""
    from repro.checkpoint import deserialize_tree, serialize_tree, tree_manifest
    rng = np.random.default_rng(seed)
    dtypes = [np.float32, np.int32, np.float16, np.uint8, np.int64]
    tree = {}
    for i in range(n_leaves):
        shape = tuple(rng.integers(1, 8, size=rng.integers(0, 3)))
        dt = dtypes[rng.integers(len(dtypes))]
        tree[f"leaf{i}"] = (rng.standard_normal(shape) * 100).astype(dt) \
            if np.issubdtype(dt, np.floating) else \
            rng.integers(0, 100, size=shape).astype(dt)
    streams = serialize_tree(tree, groups, byte_plane=byte_plane)
    manifest = tree_manifest(tree)
    if byte_plane:
        manifest["__layout__"] = "byte_plane"
    back = deserialize_tree(streams, manifest, tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]), tree[k])


# ----------------------------------------------------------------- wire format

@settings(max_examples=25, deadline=None)
@given(n=st.integers(0, 300), seed=st.integers(0, 50))
def test_property_index_roundtrip(n, seed):
    fps = _fps(n, seed)
    t = CDMT.build(fps, P)
    back = wire.decode_index(wire.encode_index(t))
    assert back.root == t.root
    assert back.levels == t.levels
    assert set(back.nodes) == set(t.nodes)


@settings(max_examples=25, deadline=None)
@given(sizes=st.lists(st.integers(0, 5000), max_size=40),
       seed=st.integers(0, 50), name=st.text(max_size=30))
def test_property_recipe_roundtrip(sizes, seed, name):
    rng = np.random.default_rng(seed)
    fps = [hashing.chunk_fingerprint(rng.bytes(16)) for _ in sizes]
    r = Recipe(name=name, fps=fps, sizes=list(sizes))
    back = wire.decode_recipe(wire.encode_recipe(r))
    assert back.name == r.name and back.fps == r.fps and back.sizes == r.sizes


@settings(max_examples=25, deadline=None)
@given(blobs=st.lists(st.binary(min_size=0, max_size=2000), max_size=20))
def test_property_chunk_batch_roundtrip(blobs):
    chunks = {hashing.chunk_fingerprint(b): b for b in blobs}
    back = wire.decode_chunk_batch(wire.encode_chunk_batch(chunks))
    assert back == chunks


@settings(max_examples=50, deadline=None)
@given(n=st.integers(0, 2**64 - 1))
def test_property_uvarint_roundtrip(n):
    v, off = wire.decode_uvarint(wire.encode_uvarint(n))
    assert v == n and off == len(wire.encode_uvarint(n))


@settings(max_examples=30, deadline=None)
@given(blobs=st.lists(st.binary(min_size=1, max_size=500), min_size=1,
                      max_size=8),
       cut_frac=st.floats(0.0, 0.999))
def test_property_truncated_batch_always_raises(blobs, cut_frac):
    chunks = {hashing.chunk_fingerprint(b): b for b in blobs}
    frame = wire.encode_chunk_batch(chunks)
    cut = int(len(frame) * cut_frac)
    with pytest.raises(wire.WireError):
        wire.decode_chunk_batch(frame[:cut])


# -------------------------------------------------------- replication log

from repro.core.errors import JournalError  # noqa: E402
from repro.core.journal import ReplicationLog  # noqa: E402


@settings(max_examples=40, deadline=None)
@given(ops=st.lists(st.one_of(
    st.tuples(st.just("append"), st.binary(max_size=48)),
    st.tuples(st.just("ack"), st.sampled_from(["a", "b", "c"]),
              st.floats(0.0, 1.0)),
), max_size=60))
def test_property_trim_never_loses_unacked_records(ops):
    """The primary's trim discipline: after any interleaving of appends
    and replica acks (trim to ``min(replica_offsets)``), every record at
    or past the lowest acked offset is still servable byte-identically,
    and the base never overtakes the slowest replica."""
    log = ReplicationLog()
    shadow = []                       # every record ever appended, by offset
    acked = {}                        # replica -> monotonic acked offset
    for op in ops:
        if op[0] == "append":
            off = log.append(1, op[1])
            assert off == len(shadow)          # offsets dense, never reissued
            shadow.append(wire.encode_record(1, op[1]))
        else:
            _, replica, frac = op
            # a replica's ack is an offset it really synced to: at or past
            # the base (ships below the base are refused — it would have
            # bootstrapped at the head instead), monotonic per replica
            base, head = log.base, log.head()
            acked[replica] = max(acked.get(replica, 0),
                                 base + int(frac * (head - base)))
            log.trim_to(min(acked.values()))
        assert log.base <= log.head()
        lo = min(acked.values()) if acked else 0
        assert log.base <= lo or not acked     # slowest replica pins the log
        start = max(lo, log.base)
        assert log.records_from(start) == shadow[start:]
        assert log.records_from(log.head()) == []   # caught up == empty


@settings(max_examples=40, deadline=None)
@given(ops=st.lists(st.one_of(
    st.tuples(st.just("append"), st.binary(max_size=32)),
    st.tuples(st.just("trim"), st.integers(0, 200)),
), max_size=60))
def test_property_offsets_monotonic_never_reissued(ops):
    """Heads and bases only advance — trims included, even a bootstrap
    trim past the current head — so no offset is ever assigned twice."""
    log = ReplicationLog()
    last_off = -1
    for op in ops:
        head_before, base_before = log.head(), log.base
        if op[0] == "append":
            off = log.append(2, op[1])
            assert off == head_before          # the next offset, exactly
            assert off > last_off              # strictly increasing forever
            last_off = off
            assert log.head() == head_before + 1
        else:
            dropped = log.trim_to(op[1])
            assert log.base == max(base_before, op[1])
            assert log.head() == max(head_before, op[1])
            assert dropped == min(op[1], head_before) - base_before \
                if op[1] > base_before else dropped == 0
        assert log.head() >= head_before and log.base >= base_before


@settings(max_examples=40, deadline=None)
@given(n=st.integers(0, 30), t=st.integers(0, 40), probe=st.integers(0, 60))
def test_property_records_from_contract(n, t, probe):
    """Reads below the trimmed base demand a full resync, reads past the
    head are a divergence, and everything in between is an exact
    byte-identical slice."""
    log = ReplicationLog()
    raws = []
    for i in range(n):
        payload = bytes([i])
        log.append(3, payload)
        raws.append(wire.encode_record(3, payload))
    log.trim_to(t)
    base, head = log.base, log.head()
    if probe < base:
        with pytest.raises(JournalError, match="behind the log base"):
            log.records_from(probe)
    elif probe > head:
        with pytest.raises(JournalError, match="diverged"):
            log.records_from(probe)
    else:
        assert log.records_from(probe) == raws[probe:]
        assert log.records_from(probe, limit=1) == raws[probe:probe + 1]
