"""Dedup checkpoint manager: the paper's push/pull as the framework's
checkpoint transport.

Each save:
  1. serializes the train state into ``n_groups`` byte streams (one per
     shard-group / paper "layer"),
  2. commits each stream to the local client store (CDC chunk + local CDMT),
  3. pushes to the registry — Algorithm 2 against the registry's previous
     version means only *changed* chunks move (paper push case 2).

Each restore pulls the version (only chunks missing locally move — a
restarted host that kept its disk pulls almost nothing; a fresh host pulls
everything once and then increments).

Async mode snapshots device arrays to host, then pushes on a background
thread so the train loop only blocks for the device→host copy.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import cdc
from repro.core.cdmt import CDMTParams, DEFAULT_PARAMS
from repro.core.pushpull import Client, WireStats
from repro.core.registry import Registry
from repro.checkpoint import serializer


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    lineage: str = "run0"
    n_groups: int = 4               # shard groups (paper: layers)
    every_steps: int = 50
    async_push: bool = False
    keep_last: int = 0              # 0 = keep all (registry is deduped anyway)
    # dtype-aware byte-plane layout: measured (bench_checkpoint_delivery) to
    # help only marginally for f32 AdamW streams and to FRAGMENT small
    # leaves (plane runs are itemsize× shorter than flat runs) — opt-in.
    byte_plane: bool = False
    cdc_params: cdc.CDCParams = cdc.DEFAULT_PARAMS
    cdmt_params: CDMTParams = DEFAULT_PARAMS


@dataclasses.dataclass
class CheckpointInfo:
    step: int
    tag: str
    wire: List[WireStats]
    raw_bytes: int
    wall_s: float

    @property
    def total_wire_bytes(self) -> int:
        return sum(w.total_wire_bytes for w in self.wire)

    @property
    def savings_vs_raw(self) -> float:
        return 1.0 - self.total_wire_bytes / self.raw_bytes if self.raw_bytes else 0.0


class DedupCheckpointManager:
    """Client-side checkpoint save/restore over a (possibly remote) registry."""

    def __init__(self, registry: Registry, cfg: CheckpointConfig,
                 directory: Optional[str] = None):
        self.registry = registry
        self.cfg = cfg
        self.client = Client(cdc_params=cfg.cdc_params,
                             cdmt_params=cfg.cdmt_params, directory=directory)
        self.manifests: Dict[str, Dict] = {}      # tag -> manifest
        self.history: List[CheckpointInfo] = []
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save

    def _group_lineage(self, g: int) -> str:
        return f"{self.cfg.lineage}/g{g}"

    def save(self, state, step: int, block: bool = True) -> CheckpointInfo:
        """Checkpoint ``state`` (any pytree) at ``step``."""
        t0 = time.time()
        host_state = jax.tree.map(np.asarray, state)   # device→host snapshot
        if self.cfg.async_push and not block:
            self.wait()                                # one in flight at a time
            self._thread = threading.Thread(
                target=self._push, args=(host_state, step, t0), daemon=True)
            self._thread.start()
            return CheckpointInfo(step=step, tag=self._tag(step), wire=[],
                                  raw_bytes=0, wall_s=time.time() - t0)
        return self._push(host_state, step, t0)

    def _tag(self, step: int) -> str:
        return f"step{step:08d}"

    def _push(self, host_state, step: int, t0: float) -> CheckpointInfo:
        tag = self._tag(step)
        streams = serializer.serialize_tree(host_state, self.cfg.n_groups,
                                            byte_plane=self.cfg.byte_plane)
        manifest = serializer.tree_manifest(host_state)
        if self.cfg.byte_plane:
            manifest["__layout__"] = "byte_plane"
        self.manifests[tag] = manifest
        wire: List[WireStats] = []
        raw = 0
        for g, stream in enumerate(streams):
            lin = self._group_lineage(g)
            self.client.commit(lin, tag, stream)
            wire.append(self.client.push(self.registry, lin, tag))
            raw += len(stream)
        self.registry.put_metadata(self.cfg.lineage, tag,
                                   serializer.manifest_json(manifest))
        info = CheckpointInfo(step=step, tag=tag, wire=wire, raw_bytes=raw,
                              wall_s=time.time() - t0)
        self.history.append(info)
        return info

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore

    def latest_step(self) -> Optional[int]:
        tags = self.registry.tags(self._group_lineage(0))
        if not tags:
            return None
        return max(int(t[4:]) for t in tags)

    def restore(self, treedef_like, step: Optional[int] = None
                ) -> Tuple[Any, int, List[WireStats]]:
        """Pull + rebuild state.  ``treedef_like``: same-structure pytree
        (e.g. abstract state) for unflattening."""
        if step is None:
            step = self.latest_step()
            assert step is not None, "no checkpoint in registry"
        tag = self._tag(step)
        wire: List[WireStats] = []
        streams: List[bytes] = []
        for g in range(self.cfg.n_groups):
            lin = self._group_lineage(g)
            wire.append(self.client.pull(self.registry, lin, tag))
            streams.append(self.client.materialize(lin, tag))
        manifest = self.manifests.get(tag)
        if manifest is None:
            manifest = json.loads(
                self.registry.get_metadata(self.cfg.lineage, tag).decode())
        state = serializer.deserialize_tree(streams, manifest, treedef_like)
        return state, step, wire

    # ------------------------------------------------------------ accounting

    def wire_summary(self) -> Dict[str, float]:
        total = sum(i.total_wire_bytes for i in self.history)
        raw = sum(i.raw_bytes for i in self.history)
        return {
            "checkpoints": len(self.history),
            "wire_bytes": total,
            "raw_bytes": raw,
            "savings": 1.0 - total / raw if raw else 0.0,
        }
