"""CDMT-deduplicated checkpointing (the paper's technique, framework-native)."""
from repro.checkpoint.serializer import (serialize_tree, deserialize_tree,
                                         tree_manifest)
from repro.checkpoint.manager import (CheckpointConfig, DedupCheckpointManager,
                                      CheckpointInfo)
