"""Pytree ↔ byte-stream serialization with a *stable, dedup-friendly* layout.

Layout invariants that make consecutive checkpoints CDC-dedup well
(DESIGN.md §2):

* leaves are emitted in sorted key-path order — insertion of a new leaf
  shifts the *stream*, but CDC chunking absorbs byte shifts by design (that
  is the paper's point);
* each leaf is raw little-endian array bytes, no compression (compression
  would destroy cross-version chunk identity — the paper stores chunks
  uncompressed for exactly this reason, Sec. I);
* the manifest (shapes/dtypes/offsets) is a separate small JSON artifact, so
  a byte-identical weight region dedups even when metadata changes.

``shard_group`` splits the leaf list round-robin by size into G independent
streams ("layers" in the paper's sense): each training host pushes its own
group in parallel, and the registry dedups across groups and versions.

**Byte-plane layout (beyond-paper optimization).**  Consecutive *training*
checkpoints defeat flat-byte dedup: an AdamW step perturbs the low mantissa
bits of nearly every float, so nearly every 4-byte group differs and CDC
finds nothing.  But the SIGN/EXPONENT byte and the high-mantissa byte of
most floats are unchanged by a ~1e-3 relative update.  ``byte_plane=True``
transposes each leaf's bytes so that plane k of every float is contiguous
(all byte-3s, then all byte-2s, …): the stable high planes become long
byte-identical runs that CDC dedups across versions, while the churning low
planes are isolated.  Same bytes, same size — just an order the paper's
index can exploit.  Measured in benchmarks/bench_checkpoint_delivery.py.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _key_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        else:
            parts.append(str(p))
    return "/".join(parts)


def flatten_named(tree) -> List[Tuple[str, np.ndarray]]:
    """(sorted-key-path, host ndarray) pairs for every leaf."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    items = [(_key_str(path), np.asarray(leaf)) for path, leaf in flat]
    items.sort(key=lambda kv: kv[0])
    return items


def tree_manifest(tree) -> Dict[str, Any]:
    """Shapes/dtypes manifest (JSON-serializable)."""
    return {
        name: {"shape": list(arr.shape), "dtype": arr.dtype.name}
        for name, arr in flatten_named(tree)
    }


def _to_planes(arr: np.ndarray) -> bytes:
    """Byte-plane transpose: all byte-(k) of each element contiguous."""
    flat = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
    itemsize = arr.dtype.itemsize
    if itemsize == 1 or arr.size == 0:
        return flat.tobytes()
    return flat.reshape(-1, itemsize).T.copy().tobytes()


def _from_planes(raw: bytes, dtype: np.dtype, count: int) -> np.ndarray:
    if dtype.itemsize == 1 or count == 0:
        return np.frombuffer(raw, dtype=dtype, count=count)
    planes = np.frombuffer(raw, dtype=np.uint8).reshape(dtype.itemsize, count)
    return planes.T.copy().reshape(-1).view(dtype)


def serialize_tree(tree, n_groups: int = 1, byte_plane: bool = False
                   ) -> List[bytes]:
    """Serialize a pytree into ``n_groups`` independent byte streams.

    Group assignment is deterministic (leaf index round-robin weighted by
    nothing — stable across versions as long as the tree structure is
    stable; new leaves join groups at the end, shifting only their group).
    """
    items = flatten_named(tree)
    groups: List[List[bytes]] = [[] for _ in range(n_groups)]
    for i, (name, arr) in enumerate(items):
        buf = _to_planes(arr) if byte_plane else arr.tobytes(order="C")
        groups[i % n_groups].append(buf)
    return [b"".join(g) for g in groups]


def deserialize_tree(streams: List[bytes], manifest: Dict[str, Any],
                     treedef_like, byte_plane: bool = False) -> Any:
    """Rebuild a pytree from group streams + manifest.

    ``treedef_like`` is any pytree with the same structure (e.g. the
    abstract param tree) used to unflatten.
    """
    names = sorted(k for k in manifest.keys() if not k.startswith("__"))
    byte_plane = byte_plane or manifest.get("__layout__") == "byte_plane"
    n_groups = len(streams)
    offsets = [0] * n_groups
    by_name: Dict[str, np.ndarray] = {}
    for i, name in enumerate(names):
        g = i % n_groups
        meta = manifest[name]
        dtype = np.dtype(meta["dtype"])
        count = int(np.prod(meta["shape"])) if meta["shape"] else 1
        nbytes = count * dtype.itemsize
        raw = streams[g][offsets[g]:offsets[g] + nbytes]
        offsets[g] += nbytes
        if byte_plane:
            by_name[name] = _from_planes(raw, dtype, count).reshape(meta["shape"])
        else:
            by_name[name] = np.frombuffer(raw, dtype=dtype).reshape(meta["shape"])

    flat = jax.tree_util.tree_flatten_with_path(treedef_like)
    leaves = []
    for path, _ in flat[0]:
        leaves.append(by_name[_key_str(path)])
    # tree_flatten_with_path returns leaves in treedef order — but our
    # by_name lookup is by path, so ordering is already correct.
    return jax.tree_util.tree_unflatten(flat[1], leaves)


def manifest_json(manifest: Dict[str, Any]) -> bytes:
    return json.dumps(manifest, sort_keys=True).encode()
