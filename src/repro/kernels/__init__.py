"""Pallas TPU kernels for the perf-critical hot spots.

``gear_cdc``   — CDC boundary scan (the paper's hashing hot loop, Fig. 10).
``chunk_fp``   — parallel polynomial page fingerprints (device-side dedup).
``flash_attention`` — blockwise fused attention (LM prefill hot spot).

``ops`` holds the jit'd dispatch wrappers; ``ref`` the pure-jnp oracles.
EXAMPLE.md documents the kernel/ops/ref convention.
"""

from . import ops, ref
