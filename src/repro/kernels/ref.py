"""Pure-jnp oracles for every Pallas kernel.

These are the semantics contracts: tests sweep shapes/dtypes and
``assert_allclose`` each kernel (run with ``interpret=True`` on CPU) against
the functions here.  They are also the CPU/debug execution path selected by
``repro.kernels.ops`` when no TPU is present.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cdc import GEAR_WINDOW, gear_table

# ---------------------------------------------------------------------------
# Gear rolling hash (CDC boundary scan)
# ---------------------------------------------------------------------------


@jax.jit
def gear_hash_ref(data: jax.Array) -> jax.Array:
    """Rolling gear hash per byte position.

    ``h_i = sum_{j=0}^{31} 2^j * G[b_{i-j}]  (mod 2^32)`` — the unrolled form
    of ``h_i = 2*h_{i-1} + G[b_i]`` (the gear register forgets after 32
    shifts).  All arithmetic in int32: XLA int32 wraparound IS mod 2^32.
    Input: uint8 (n,). Output: uint32 (n,).
    """
    table = jnp.asarray(gear_table().view(np.int32))
    g = table[data.astype(jnp.int32)]                      # (n,) int32 gather
    n = data.shape[0]
    h = jnp.zeros((n,), dtype=jnp.int32)
    valid = jnp.arange(n)
    for j in range(GEAR_WINDOW):
        shifted = jnp.roll(g, j)
        shifted = jnp.where(valid >= j, shifted, 0)        # zero wrapped prefix
        h = h + (shifted << j)
    return jax.lax.bitcast_convert_type(h, jnp.uint32)


def boundary_mask_ref(data: jax.Array, mask_bits: int) -> jax.Array:
    """Candidate-boundary mask: hash low ``mask_bits`` bits all zero."""
    h = gear_hash_ref(data)
    return (h & jnp.uint32((1 << mask_bits) - 1)) == 0


# ---------------------------------------------------------------------------
# Parallel polynomial chunk fingerprint
# ---------------------------------------------------------------------------

FP_MULTIPLIER = np.int64(0x01000193)  # FNV prime, used as polynomial base


def fp_weights(page_size: int) -> np.ndarray:
    """w_i = p^(page_size-1-i) mod 2^32 as int32 (two's complement)."""
    w = np.zeros(page_size, dtype=np.uint64)
    acc = np.uint64(1)
    m = np.uint64(0xFFFFFFFF)
    with np.errstate(over="ignore"):
        for i in range(page_size - 1, -1, -1):
            w[i] = acc
            acc = (acc * np.uint64(FP_MULTIPLIER)) & m
    return w.astype(np.uint32).view(np.int32)


def page_fingerprint_ref(pages: jax.Array) -> jax.Array:
    """64-ish-bit fingerprints of fixed-size pages.

    Input: uint8 (n_pages, page_size). Output: int32 (n_pages, 2) — two
    independent polynomial fingerprints (base p and p^2) evaluated mod 2^32.
    XLA int32 arithmetic wraps (two's complement) — exactly mod 2^32.
    """
    n_pages, page_size = pages.shape
    w1 = jnp.asarray(fp_weights(page_size))                       # (S,)
    w2 = jnp.asarray(_squared_weights(page_size))
    b = pages.astype(jnp.int32)
    fp1 = jnp.sum(b * w1[None, :], axis=1, dtype=jnp.int32)
    fp2 = jnp.sum(b * w2[None, :], axis=1, dtype=jnp.int32)
    return jnp.stack([fp1, fp2], axis=-1)


@functools.lru_cache(maxsize=None)
def _squared_weights(page_size: int) -> np.ndarray:
    w = np.zeros(page_size, dtype=np.uint64)
    acc = np.uint64(1)
    m = np.uint64(0xFFFFFFFF)
    p2 = (np.uint64(FP_MULTIPLIER) * np.uint64(FP_MULTIPLIER)) & m
    with np.errstate(over="ignore"):
        for i in range(page_size - 1, -1, -1):
            w[i] = acc
            acc = (acc * p2) & m
    return w.astype(np.uint32).view(np.int32)


# ---------------------------------------------------------------------------
# Attention (flash-attention oracle)
# ---------------------------------------------------------------------------


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
            scale: float | None = None) -> jax.Array:
    """Plain softmax attention.  q: (B,H,S,D), k/v: (B,H,S,D) (kv heads
    already repeated to H).  fp32 accumulation."""
    b, h, s, d = q.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(k.shape[2])[None, :]
        logits = jnp.where(qi >= ki, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
