"""Pallas TPU kernel: content-defined-chunking boundary scan (gear hash).

The paper's CDC hot loop (Sec. III-A, VI-D) is a byte-serial rolling hash —
hostile to a TPU.  Two adaptations (DESIGN.md §4) make it TPU-native:

1. **Table lookup → one-hot matmul.**  ``G[byte]`` over a 256-entry table is
   a gather (slow on TPU).  Instead each byte becomes a one-hot row of a
   ``(sub, 256)`` matrix and the lookup is a ``(sub,256) @ (256,2)``
   matmul on the MXU.  The uint32 gear values are split into two exact
   16-bit halves so fp32 MXU accumulation is exact (one-hot rows select a
   single entry; |half| < 2^16 < 2^24).

2. **Serial recurrence → bounded convolution.**  ``h_i = 2 h_{i-1} + g_i``
   (mod 2^32) has bounded memory: after 32 doublings a term leaves the
   register, so ``h_i = Σ_{j<32} 2^j g_{i-j}`` — a 32-tap convolution,
   computed with static shifted adds on the VPU (int32 wraparound = mod
   2^32).  Cross-block dependence is only a 31-byte halo, passed as a
   second blocked operand, so grid steps are fully independent.

Grid: 1-D over byte-stream tiles of ``BLOCK`` (16 KiB).  VMEM per step:
in/out tiles ~80 KiB + one (SUB=2048, 256) f32 one-hot scratch of 2 MiB —
well inside the ~16 MiB/core budget; sub-tiling keeps the one-hot from
scaling with BLOCK.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.cdc import GEAR_WINDOW, gear_table

BLOCK = 16384          # bytes per grid step
SUB = 2048             # one-hot sub-tile rows (VMEM: (SUB,256) f32 = 2 MiB)
HALO = GEAR_WINDOW     # 32 trailing bytes of the previous block


def _gear_table_halves() -> jax.Array:
    """(256, 2) f32: [hi16, lo16] of each gear entry — exact in fp32."""
    g = gear_table()
    hi = (g >> 16).astype(np.float32)
    lo = (g & 0xFFFF).astype(np.float32)
    return jnp.stack([jnp.asarray(hi), jnp.asarray(lo)], axis=1)


def _gear_cdc_kernel(bytes_ref, halo_ref, table_ref, hash_ref):
    """One grid step: rolling gear hash of BLOCK bytes (uint32 bits in int32)."""
    data = jnp.concatenate([halo_ref[...], bytes_ref[...]], axis=0)
    n = BLOCK + HALO
    table = table_ref[...]                                    # (256, 2) f32
    data_i32 = data.astype(jnp.int32)

    # --- 1. gear lookup via one-hot matmul (MXU), per sub-tile -------------
    def lookup(sub):                                          # (m,) int32
        onehot = (sub[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (sub.shape[0], 256), 1)).astype(jnp.float32)
        halves = jnp.dot(onehot, table,
                         preferred_element_type=jnp.float32)  # (m, 2)
        hi = halves[:, 0].astype(jnp.int32)
        lo = halves[:, 1].astype(jnp.int32)
        return (hi << 16) + lo                                # exact uint32 bits

    g_parts = [lookup(data_i32[s0:min(s0 + SUB, n)])          # static unroll
               for s0 in range(0, n, SUB)]
    g = jnp.concatenate(g_parts, axis=0)                      # (BLOCK+HALO,)

    # Block 0 has no predecessor: its halo is padding, not stream bytes, so
    # its gear contributions must be zero (ref semantics: h_i sums only
    # over existing positions i-j >= 0).
    first = pl.program_id(0) == 0
    idx = jax.lax.broadcasted_iota(jnp.int32, (BLOCK + HALO,), 0)
    g = jnp.where(jnp.logical_and(first, idx < HALO), 0, g)

    # --- 2. 32-tap convolution with weights 2^j (VPU shifted adds) ---------
    h = jnp.zeros((BLOCK,), dtype=jnp.int32)
    for j in range(GEAR_WINDOW):
        # output position i (block coords) reads g[HALO + i - j]
        h = h + (g[HALO - j: HALO - j + BLOCK] << j)
    hash_ref[...] = h


@functools.partial(jax.jit, static_argnames=("interpret",))
def gear_hash_pallas(data: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Rolling gear hash of a uint8 stream via the Pallas kernel.

    ``data`` length must be a multiple of BLOCK (ops.py pads).  Returns
    uint32 hashes, bit-identical to ``ref.gear_hash_ref``.
    """
    n = data.shape[0]
    assert n % BLOCK == 0, "pad to BLOCK first (see ops.gear_boundary_mask)"
    n_blocks = n // BLOCK
    blocks = data.reshape(n_blocks, BLOCK)
    # halo operand: the 32 bytes preceding each block (zeros for block 0)
    halo_rows = jnp.concatenate(
        [jnp.zeros((1, HALO), jnp.uint8), blocks[:-1, -HALO:]], axis=0)

    out = pl.pallas_call(
        _gear_cdc_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((HALO,), lambda i: (i,)),
            pl.BlockSpec((256, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(blocks.reshape(-1), halo_rows.reshape(-1), table := _gear_table_halves())
    return jax.lax.bitcast_convert_type(out, jnp.uint32)
