"""jit'd public wrappers around the Pallas kernels.

Dispatch policy: Pallas (compiled) on TPU backends, Pallas ``interpret=True``
or the pure-jnp reference on CPU — selectable with ``impl=``.  All wrappers
handle padding/reshaping so callers never see tile-size constraints.
"""

from __future__ import annotations

import functools
from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cdc
from . import ref
from .chunk_fp import PAGE_TILE, page_fingerprint_pallas
from .flash_attention import Q_TILE, flash_attention_pallas
from .gear_cdc import BLOCK, gear_hash_pallas

Impl = Literal["auto", "pallas", "interpret", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: Impl) -> str:
    if impl != "auto":
        return impl
    return "pallas" if _on_tpu() else "ref"


# ---------------------------------------------------------------------------
# CDC boundary scan
# ---------------------------------------------------------------------------


def gear_hash(data: jax.Array, impl: Impl = "auto") -> jax.Array:
    """Rolling gear hash (uint32) per byte of a uint8 stream."""
    mode = _resolve(impl)
    if mode == "ref":
        return ref.gear_hash_ref(data)
    n = data.shape[0]
    pad = (-n) % BLOCK
    padded = jnp.pad(data, (0, pad))
    out = gear_hash_pallas(padded, interpret=(mode == "interpret"))
    return out[:n]


def gear_boundary_mask(data: jax.Array, mask_bits: int,
                       impl: Impl = "auto") -> jax.Array:
    """Candidate chunk boundaries: low ``mask_bits`` of the rolling hash zero."""
    h = gear_hash(data, impl=impl)
    return (h & jnp.uint32((1 << mask_bits) - 1)) == 0


def chunk_boundaries_accelerated(data: bytes, params: cdc.CDCParams,
                                 impl: Impl = "auto") -> list:
    """Full CDC: device boundary scan + host min/max pass (DESIGN.md §4)."""
    arr = jnp.asarray(np.frombuffer(data, dtype=np.uint8))
    mask = np.asarray(gear_boundary_mask(arr, params.mask_bits, impl=impl))
    return cdc.boundaries_from_mask(mask, params)


# ---------------------------------------------------------------------------
# Page fingerprints
# ---------------------------------------------------------------------------


def page_fingerprints(pages: jax.Array, impl: Impl = "auto") -> jax.Array:
    """(n_pages, page_size) uint8 → (n_pages, 2) int32 fingerprint pairs."""
    mode = _resolve(impl)
    if mode == "ref":
        return ref.page_fingerprint_ref(pages)
    n = pages.shape[0]
    pad = (-n) % PAGE_TILE
    padded = jnp.pad(pages, ((0, pad), (0, 0)))
    out = page_fingerprint_pallas(padded, interpret=(mode == "interpret"))
    return out[:n]


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: Optional[float] = None,
                    impl: Impl = "auto") -> jax.Array:
    """Fused attention over (B, H, S, D) with (B, KVH, S, D) k/v (GQA ok).

    Repeats kv heads to match q heads, flattens (B,H) for the kernel, pads S
    to the 128 tile.  fp32 accumulation; returns q.dtype.
    """
    mode = _resolve(impl)
    b, h, s, d = q.shape
    kvh = k.shape[1]
    if kvh != h:
        assert h % kvh == 0
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if mode == "ref":
        return ref.mha_ref(q, k, v, causal=causal, scale=scale)

    skv = k.shape[2]
    pad_q = (-s) % Q_TILE
    pad_kv = (-skv) % Q_TILE
    qf = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0))).reshape(b * h, s + pad_q, d)
    kf = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0))).reshape(b * h, skv + pad_kv, d)
    vf = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0))).reshape(b * h, skv + pad_kv, d)
    out = flash_attention_pallas(qf, kf, vf, causal=causal, scale=scale,
                                 interpret=(mode == "interpret"))
    return out[:, :s, :].reshape(b, h, s, d)
