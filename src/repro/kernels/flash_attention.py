"""Pallas TPU kernel: blockwise fused (flash) attention for prefill.

The 32k-token prefill shapes make attention the compute hot spot of the LM
substrate.  Standard flash decomposition: for each query tile, stream key/
value tiles through VMEM keeping a running (max, sum, weighted-V) in fp32 —
O(S) memory instead of O(S²), MXU-aligned (128×128) tiles.

Grid: (batch·heads, q_tiles, kv_tiles) with the kv axis innermost ("arbitrary"
semantics — accumulator carried in VMEM scratch across kv steps).  Causal
masking skips fully-masked kv tiles via a predicated early-out on the whole
tile (Mosaic turns uniform predicates into cheap scalar branches).

GQA is handled by the ops.py wrapper (q heads grouped per kv head before the
call), so the kernel sees matched head counts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Q_TILE = 128
KV_TILE = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, kv_tiles: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: kv tile strictly after q tile contributes nothing
    run = jnp.logical_or(not causal,
                         ki * KV_TILE <= qi * Q_TILE + (Q_TILE - 1))

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                     # (Q_TILE, D)
        k = k_ref[0].astype(jnp.float32)                     # (KV_TILE, D)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * Q_TILE + jax.lax.broadcasted_iota(
                jnp.int32, (Q_TILE, KV_TILE), 0)
            k_pos = ki * KV_TILE + jax.lax.broadcasted_iota(
                jnp.int32, (Q_TILE, KV_TILE), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_scr[...]                                  # (Q_TILE, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                               # (Q_TILE, KV_TILE)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == kv_tiles - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / l_scr[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, scale: float | None = None,
                           interpret: bool = True) -> jax.Array:
    """Fused attention.  q/k/v: (BH, S, D) with S % 128 == 0, matched heads.

    Returns (BH, S, D) in q.dtype; fp32 accumulation inside.
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    assert sq % Q_TILE == 0 and skv % KV_TILE == 0
    scale = float(scale if scale is not None else 1.0 / np.sqrt(d))
    q_tiles, kv_tiles = sq // Q_TILE, skv // KV_TILE

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               kv_tiles=kv_tiles)
    return pl.pallas_call(
        kernel,
        grid=(bh, q_tiles, kv_tiles),
        in_specs=[
            pl.BlockSpec((1, Q_TILE, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, KV_TILE, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, KV_TILE, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q_TILE, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Q_TILE, 1), jnp.float32),   # running max m
            pltpu.VMEM((Q_TILE, 1), jnp.float32),   # running sum l
            pltpu.VMEM((Q_TILE, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
