"""Pallas TPU kernel: parallel polynomial page fingerprints.

Fast-path dedup fingerprint for device-resident checkpoint shards
(DESIGN.md §4): every fixed-size page gets a pair of 32-bit polynomial
fingerprints ``fp_k = Σ_i b_i · p_k^(S-1-i)  (mod 2^32)`` with two
independent bases.  Pages whose 64-bit fp pair matches a stored page are
*candidate* duplicates — the host confirms with blake2b before dropping any
byte, so the kernel only needs to be collision-*rare*, not collision-free.

Mapping to TPU: the weighted sum is elementwise-multiply + row reduction on
the VPU in int32 (XLA int32 wraps ⇒ arithmetic is exactly mod 2^32 — no
fp rounding concerns, unlike an MXU matmul formulation).  Grid is 1-D over
page tiles; weights are a broadcast operand resident in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .ref import fp_weights, _squared_weights

PAGE_TILE = 256        # pages per grid step


def _chunk_fp_kernel(pages_ref, w_ref, fp_ref):
    pages = pages_ref[...].astype(jnp.int32)          # (PAGE_TILE, S)
    w = w_ref[...]                                    # (S, 2) int32
    fp1 = jnp.sum(pages * w[None, :, 0], axis=1, dtype=jnp.int32)
    fp2 = jnp.sum(pages * w[None, :, 1], axis=1, dtype=jnp.int32)
    fp_ref[...] = jnp.stack([fp1, fp2], axis=-1)      # (PAGE_TILE, 2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_fingerprint_pallas(pages: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Fingerprint (n_pages, page_size) uint8 pages → (n_pages, 2) int32.

    ``n_pages`` must be a multiple of PAGE_TILE (ops.py pads with zero pages
    and truncates).  Bit-identical to ``ref.page_fingerprint_ref``.
    """
    n_pages, page_size = pages.shape
    assert n_pages % PAGE_TILE == 0, "pad pages to PAGE_TILE (see ops.py)"
    w = jnp.stack([jnp.asarray(fp_weights(page_size)),
                   jnp.asarray(_squared_weights(page_size))], axis=1)

    return pl.pallas_call(
        _chunk_fp_kernel,
        grid=(n_pages // PAGE_TILE,),
        in_specs=[
            pl.BlockSpec((PAGE_TILE, page_size), lambda i: (i, 0)),
            pl.BlockSpec((page_size, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((PAGE_TILE, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pages, 2), jnp.int32),
        interpret=interpret,
    )(pages, w)
