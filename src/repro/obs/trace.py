"""Lightweight span tracing for the delivery path.

A :class:`Tracer` produces trees of timed :class:`Span`\\ s through one
entry point::

    with tracer.span("pull", lineage="app", tag="v3") as sp:
        with tracer.span("plan_pull"):      # nests under "pull"
            ...
        sp.annotate(chunks=42)

Parentage is implicit per thread (a thread-local stack), with an explicit
``parent=`` escape hatch for work fanned out to a pool: the submitting
thread captures its current span and each worker opens children under it —
the resulting tree crosses threads but stays one pull.

Completed **root** spans land in a bounded ring buffer (old pulls fall off,
memory stays flat); :meth:`Tracer.take` drains them for inspection or for
``tools/trace_dump.py``.  Spans serialize to plain dicts
(:meth:`Span.to_dict`) so a recorded trace survives a JSON round-trip.

Cost model: tracers are **disabled by default**.  A disabled tracer's
``span()`` returns one shared no-op context manager — no allocation, no
clock read, no lock — which is what keeps "tracing off" indistinguishable
from "tracing not wired in" (``tests/test_obs.py`` measures it).
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, List, Optional

__all__ = ["Span", "Tracer", "NULL_TRACER"]


class Span:
    """One timed operation; children are spans it (transitively) caused."""

    __slots__ = ("name", "attrs", "t0", "t1", "children")

    def __init__(self, name: str, attrs: Optional[Dict] = None):
        self.name = name
        self.attrs: Dict = attrs or {}
        self.t0 = 0.0
        self.t1 = 0.0
        self.children: List["Span"] = []

    @property
    def duration(self) -> float:
        return max(0.0, self.t1 - self.t0)

    def annotate(self, **attrs) -> None:
        """Attach attributes mid-span (chunk counts, byte totals, ...)."""
        self.attrs.update(attrs)

    def to_dict(self) -> dict:
        return {"name": self.name, "attrs": dict(self.attrs),
                "duration": self.duration,
                "children": [c.to_dict() for c in self.children]}

    @classmethod
    def from_dict(cls, obj: dict) -> "Span":
        sp = cls(obj["name"], dict(obj.get("attrs", {})))
        sp.t0, sp.t1 = 0.0, float(obj.get("duration", 0.0))
        sp.children = [cls.from_dict(c) for c in obj.get("children", ())]
        return sp

    def walk(self):
        """Yield ``(depth, span)`` depth-first."""
        stack = [(0, self)]
        while stack:
            depth, sp = stack.pop()
            yield depth, sp
            stack.extend((depth + 1, c) for c in reversed(sp.children))


class _NullSpanContext:
    """Shared do-nothing span + context manager for disabled tracers."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpanContext":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def annotate(self, **attrs) -> None:
        pass


_NULL_SPAN = _NullSpanContext()


class _SpanContext:
    """Context manager for one live span: clocks it, maintains the thread's
    span stack, attaches to the parent (or the ring buffer for roots)."""

    __slots__ = ("_tracer", "_span", "_parent")

    def __init__(self, tracer: "Tracer", span: Span, parent: Optional[Span]):
        self._tracer = tracer
        self._span = span
        self._parent = parent

    def __enter__(self) -> Span:
        tracer = self._tracer
        stack = tracer._stack()
        if self._parent is None and stack:
            self._parent = stack[-1]
        stack.append(self._span)
        self._span.t0 = time.perf_counter()
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        span = self._span
        span.t1 = time.perf_counter()
        if exc_type is not None:
            span.attrs.setdefault("error", exc_type.__name__)
        tracer = self._tracer
        stack = tracer._stack()
        if stack and stack[-1] is span:
            stack.pop()
        if self._parent is not None:
            with tracer._lock:      # parents may collect from many threads
                self._parent.children.append(span)
        else:
            with tracer._lock:
                tracer._roots.append(span)
        return False


class Tracer:
    """Span factory + bounded recorder.  Disabled (free) until asked."""

    def __init__(self, enabled: bool = False, capacity: int = 256):
        self.enabled = enabled  # guarded-by: external(benign bool flip; readers only ever see on/off)
        self._lock = threading.Lock()
        self._roots: deque = deque(maxlen=max(1, capacity))  # guarded-by: _lock
        self._local = threading.local()

    def _stack(self) -> List[Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    # ------------------------------------------------------------- control

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._roots.clear()

    # -------------------------------------------------------------- spans

    def span(self, name: str, parent: Optional[Span] = None, **attrs):
        """Open a span; use as ``with tracer.span("op") as sp``.

        ``parent=`` overrides the thread-local nesting — pass the submitting
        thread's span when the work runs on a pool thread.
        """
        if not self.enabled:
            return _NULL_SPAN
        return _SpanContext(self, Span(name, attrs), parent)

    def current(self) -> Optional[Span]:
        """This thread's innermost open span (None outside any span or when
        disabled) — capture it before handing work to another thread."""
        if not self.enabled:
            return None
        stack = self._stack()
        return stack[-1] if stack else None

    # ----------------------------------------------------------- recorder

    def roots(self) -> List[Span]:
        """Completed root spans currently held (oldest first), kept."""
        with self._lock:
            return list(self._roots)

    def take(self) -> List[Span]:
        """Drain and return the recorded root spans."""
        with self._lock:
            out = list(self._roots)
            self._roots.clear()
        return out


NULL_TRACER = Tracer(enabled=False)
