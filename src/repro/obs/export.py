"""Exposition: render a :class:`~repro.obs.metrics.MetricsSnapshot` as
Prometheus text or JSON, parse the text form back, and validate scrapes.

The Prometheus text format is the ops-facing surface (`# HELP`/`# TYPE`
lines, one sample per series, histograms exploded into ``_bucket``/``_sum``
/``_count`` with cumulative ``le`` labels).  JSON is the wire surface: the
``Op.METRICS`` scrape ships :meth:`MetricsSnapshot.to_json` bytes, and the
decoded snapshot answers the same queries as an in-process one.

:func:`parse_prometheus_text` implements just enough of the exposition
grammar to round-trip what :func:`to_prometheus_text` emits — CI uses it
to prove a live scrape parses and that counters are monotonic between two
scrapes (:func:`check_monotonic`).
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

from .metrics import MetricsSnapshot

__all__ = ["to_prometheus_text", "parse_prometheus_text",
           "check_monotonic"]


def _fmt_value(v: float) -> str:
    if isinstance(v, float) and math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v) == int(v):
        return str(int(v))
    return repr(float(v))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\"", "\\\"").replace("\n", "\\n")


def _label_str(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(str(v))}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def to_prometheus_text(snapshot: MetricsSnapshot) -> str:
    """Render a snapshot in the Prometheus text exposition format."""
    lines: List[str] = []
    for fam in snapshot.families:
        name, kind = fam["name"], fam["kind"]
        if fam.get("help"):
            lines.append(f"# HELP {name} {fam['help']}")
        lines.append(f"# TYPE {name} {kind}")
        for entry in fam["series"]:
            labels = entry["labels"]
            if kind != "histogram":
                lines.append(f"{name}{_label_str(labels)} "
                             f"{_fmt_value(entry['value'])}")
                continue
            cum = 0
            edges = list(fam["buckets"]) + [math.inf]
            for edge, n in zip(edges, entry["counts"]):
                cum += n
                le = dict(labels)
                le["le"] = _fmt_value(edge)
                lines.append(f"{name}_bucket{_label_str(le)} {cum}")
            lines.append(f"{name}_sum{_label_str(labels)} "
                         f"{_fmt_value(entry['sum'])}")
            lines.append(f"{name}_count{_label_str(labels)} "
                         f"{entry['count']}")
    return "\n".join(lines) + "\n" if lines else ""


# ------------------------------------------------------------------ parsing

Sample = Tuple[str, Tuple[Tuple[str, str], ...]]     # (name, sorted labels)


def _parse_labels(text: str) -> Dict[str, str]:
    labels: Dict[str, str] = {}
    i = 0
    while i < len(text):
        eq = text.index("=", i)
        key = text[i:eq].strip().lstrip(",").strip()
        if text[eq + 1] != '"':
            raise ValueError(f"unquoted label value near {text[eq:]!r}")
        j = eq + 2
        out = []
        while text[j] != '"':
            if text[j] == "\\":
                nxt = text[j + 1]
                out.append({"n": "\n", "\\": "\\", '"': '"'}.get(nxt, nxt))
                j += 2
            else:
                out.append(text[j])
                j += 1
        labels[key] = "".join(out)
        i = j + 1
    return labels


def parse_prometheus_text(text: str) -> Dict[Sample, float]:
    """Parse exposition text into ``{(name, labels): value}``.

    Raises :class:`ValueError` on lines that don't scan — the CI smoke
    treats any exception as "the scrape does not parse".
    """
    samples: Dict[Sample, float] = {}
    types: Dict[str, str] = {}
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 3 and parts[1] == "TYPE":
                types[parts[2]] = parts[3] if len(parts) > 3 else ""
            continue
        if "{" in line:
            name = line[:line.index("{")]
            rest = line[line.index("{") + 1:]
            close = rest.rindex("}")
            labels = _parse_labels(rest[:close])
            value_text = rest[close + 1:].strip()
        else:
            name, value_text = line.split(None, 1)
            labels = {}
        value = float(value_text.replace("+Inf", "inf"))
        key = (name, tuple(sorted(labels.items())))
        if key in samples:
            raise ValueError(f"line {lineno}: duplicate sample {key}")
        samples[key] = value
    if not samples:
        raise ValueError("no samples found")
    return samples


def check_monotonic(before: MetricsSnapshot,
                    after: MetricsSnapshot) -> List[str]:
    """Counter series (and histogram cumulative counts) must never move
    backwards between two scrapes of the same server.  Returns a list of
    violation descriptions — empty means the pair is consistent."""
    bad: List[str] = []
    for fam in before.families:
        name = fam["name"]
        for entry in fam["series"]:
            labels = entry["labels"]
            if fam["kind"] == "counter":
                now = after.value(name, labels, default=-1)
                if now < entry["value"]:
                    bad.append(f"counter {name}{labels} went "
                               f"{entry['value']} -> {now}")
            elif fam["kind"] == "histogram":
                now_h = after.histogram(name, labels)
                if now_h is None or now_h.count < entry["count"]:
                    bad.append(f"histogram {name}{labels} count shrank")
    return bad
