"""Thread-safe metrics primitives — counters, gauges, latency histograms.

Dependency-free (stdlib only) by design: the delivery stack instruments
itself with these, and anything that can parse JSON or Prometheus text can
read them.  The model follows the Prometheus client-library shape without
importing it:

  * a :class:`MetricsRegistry` owns metric *families* (one per metric name);
  * a family with label names vends *children* via :meth:`~_Family.labels`
    (one child per label-value tuple); a family with no labels acts as its
    own single child;
  * reads happen through :meth:`MetricsRegistry.snapshot` — an immutable,
    mergeable, JSON-round-trippable view taken under the registry lock, so
    a scrape never observes a half-updated histogram.

Hot-path cost model: children are meant to be **pre-bound** at construction
time (``self._m_hits = reg.counter("cache_hits_total").labels()``), so an
increment is one lock acquire + one integer add.  A registry constructed
with ``enabled=False`` (or the shared :data:`NULL_REGISTRY`) vends no-op
singletons instead: an increment is then a single no-op method call, which
is what makes "metrics disabled" measurably free.

Histograms use fixed bucket upper bounds (Prometheus ``le`` semantics:
bucket *i* counts observations ``<= edges[i]``, plus one overflow bucket).
Quantiles are estimated from the cumulative bucket counts by linear
interpolation inside the containing bucket — the same estimate
``histogram_quantile`` would compute from the exposition.
"""

from __future__ import annotations

import bisect
import json
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "MetricsRegistry", "MetricsSnapshot", "HistogramView", "NULL_REGISTRY",
    "LATENCY_BUCKETS", "SIZE_BUCKETS",
]

# seconds — spans 100µs in-process calls to multi-second bulk transfers
LATENCY_BUCKETS: Tuple[float, ...] = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

# bytes — chunk payloads and frames, 256 B .. 64 MiB
SIZE_BUCKETS: Tuple[float, ...] = (
    256, 1024, 4096, 16384, 65536, 262144, 1 << 20, 4 << 20,
    16 << 20, 64 << 20)


def _label_values(labelnames: Sequence[str], args: Sequence[str],
                  kwargs: Dict[str, str]) -> Tuple[str, ...]:
    if kwargs:
        if args:
            raise ValueError("pass label values positionally or by name, "
                             "not both")
        try:
            return tuple(str(kwargs[n]) for n in labelnames)
        except KeyError as e:
            raise ValueError(f"missing label {e.args[0]!r}; "
                             f"expected {list(labelnames)}") from None
    if len(args) != len(labelnames):
        raise ValueError(f"expected {len(labelnames)} label value(s) "
                         f"{list(labelnames)}, got {len(args)}")
    return tuple(str(a) for a in args)


# ------------------------------------------------------------------ children

class _Counter:
    """Monotonic counter child.  ``inc`` only accepts non-negative deltas."""

    __slots__ = ("_lock", "_value")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self._value = 0

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += n

    def value(self) -> float:
        with self._lock:
            return self._value


class _Gauge:
    """Settable gauge child (current level: bytes resident, lag, in-flight)."""

    __slots__ = ("_lock", "_value")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self._value = 0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1) -> None:
        with self._lock:
            self._value -= n

    def value(self) -> float:
        with self._lock:
            return self._value


class _Histogram:
    """Fixed-bucket histogram child: counts per ``le`` bucket + sum + count."""

    __slots__ = ("_lock", "_edges", "_counts", "_sum", "_count")

    def __init__(self, lock: threading.Lock, edges: Tuple[float, ...]):
        self._lock = lock
        self._edges = edges
        self._counts = [0] * (len(edges) + 1)     # last bucket = +Inf
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        idx = bisect.bisect_left(self._edges, v)
        with self._lock:
            self._counts[idx] += 1
            self._sum += v
            self._count += 1

    def value(self) -> "HistogramView":
        with self._lock:
            return HistogramView(self._edges, tuple(self._counts),
                                 self._sum, self._count)


class _NullMetric:
    """The child every disabled registry vends: all writes are no-ops, all
    reads are zero.  One shared instance serves every family and label set,
    so a disabled hot path pays exactly one no-op method call."""

    __slots__ = ()

    def labels(self, *a, **kw) -> "_NullMetric":
        return self

    def inc(self, n: float = 1) -> None:
        pass

    def dec(self, n: float = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def value(self) -> float:
        return 0


_NULL_METRIC = _NullMetric()


# ------------------------------------------------------------------ families

_KINDS = ("counter", "gauge", "histogram")


class _Family:
    """One named metric; vends per-label children (itself when label-free)."""

    def __init__(self, kind: str, name: str, help_: str,
                 labelnames: Tuple[str, ...], lock: threading.Lock,
                 buckets: Tuple[float, ...] = ()):
        self.kind = kind
        self.name = name
        self.help = help_
        self.labelnames = labelnames
        self.buckets = buckets
        self._lock = lock
        self._children: Dict[Tuple[str, ...], object] = {}

    def _make_child(self):
        if self.kind == "counter":
            return _Counter(self._lock)
        if self.kind == "gauge":
            return _Gauge(self._lock)
        return _Histogram(self._lock, self.buckets)

    def labels(self, *args: str, **kwargs: str):
        key = _label_values(self.labelnames, args, kwargs)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._make_child()
        return child

    # label-free convenience: the family is its own single child
    def inc(self, n: float = 1) -> None:
        self.labels().inc(n)

    def dec(self, n: float = 1) -> None:
        self.labels().dec(n)

    def set(self, v: float) -> None:
        self.labels().set(v)

    def observe(self, v: float) -> None:
        self.labels().observe(v)

    def value(self):
        return self.labels().value()


# ------------------------------------------------------------------ registry

class MetricsRegistry:
    """A process-local set of metric families, snapshot-consistent.

    Components each own (or are handed) a registry, so independent servers
    in one process never share counters; a deployment that wants one scrape
    endpoint hands the same registry to everything, or merges snapshots.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._families: Dict[str, _Family] = {}  # guarded-by: _lock

    # -------------------------------------------------------- registration

    def _family(self, kind: str, name: str, help_: str,
                labelnames: Sequence[str],
                buckets: Tuple[float, ...] = ()):
        if not self.enabled:
            return _NULL_METRIC
        labelnames = tuple(labelnames)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = _Family(
                    kind, name, help_, labelnames, self._lock, buckets)
                return fam
        if fam.kind != kind or fam.labelnames != labelnames:
            raise ValueError(
                f"metric {name!r} re-registered as {kind}{labelnames} "
                f"(was {fam.kind}{fam.labelnames})")
        return fam

    def counter(self, name: str, help_: str = "",
                labelnames: Sequence[str] = ()):
        return self._family("counter", name, help_, labelnames)

    def gauge(self, name: str, help_: str = "",
              labelnames: Sequence[str] = ()):
        return self._family("gauge", name, help_, labelnames)

    def histogram(self, name: str, help_: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS):
        edges = tuple(sorted(float(b) for b in buckets))
        if not edges:
            raise ValueError("histogram needs at least one bucket edge")
        return self._family("histogram", name, help_, labelnames, edges)

    # -------------------------------------------------------------- reading

    def snapshot(self) -> "MetricsSnapshot":
        """A consistent point-in-time copy of every series (one lock hold)."""
        fams: List[dict] = []
        with self._lock:
            for fam in self._families.values():
                series = []
                for key, child in fam._children.items():
                    entry = {"labels": dict(zip(fam.labelnames, key))}
                    if fam.kind == "histogram":
                        entry["counts"] = list(child._counts)
                        entry["sum"] = child._sum
                        entry["count"] = child._count
                    else:
                        entry["value"] = child._value
                    series.append(entry)
                fams.append({"kind": fam.kind, "name": fam.name,
                             "help": fam.help,
                             "labelnames": list(fam.labelnames),
                             "buckets": list(fam.buckets),
                             "series": series})
        return MetricsSnapshot(fams)


NULL_REGISTRY = MetricsRegistry(enabled=False)


# ------------------------------------------------------------------ snapshot

class HistogramView:
    """Immutable histogram state: bucket counts, sum, count, quantiles."""

    __slots__ = ("edges", "counts", "sum", "count")

    def __init__(self, edges: Sequence[float], counts: Sequence[int],
                 sum_: float, count: int):
        self.edges = tuple(edges)
        self.counts = tuple(counts)
        self.sum = sum_
        self.count = count

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Linear-interpolation estimate of the ``q``-quantile (0..1).

        Observations in the overflow bucket clamp to the last finite edge
        (there is no upper bound to interpolate toward) — same convention
        as Prometheus ``histogram_quantile``.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError("quantile must be in [0, 1]")
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        lo = 0.0
        for edge, n in zip(self.edges, self.counts):
            if cum + n >= target and n > 0:
                frac = (target - cum) / n
                return lo + (edge - lo) * min(1.0, max(0.0, frac))
            cum += n
            lo = edge
        return self.edges[-1]       # landed in the +Inf overflow bucket

    def merge(self, other: "HistogramView") -> "HistogramView":
        if self.edges != other.edges:
            raise ValueError("cannot merge histograms with different buckets")
        return HistogramView(self.edges,
                             [a + b for a, b in zip(self.counts,
                                                    other.counts)],
                             self.sum + other.sum, self.count + other.count)


def _series_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted(labels.items()))


class MetricsSnapshot:
    """Immutable view of a registry: mergeable, JSON-round-trippable.

    ``families`` is a list of plain dicts (the JSON shape), so a snapshot
    decoded from an :data:`~repro.delivery.wire.Op.METRICS` scrape is
    indistinguishable from one taken in-process.
    """

    def __init__(self, families: Optional[List[dict]] = None):
        self.families: List[dict] = families if families is not None else []

    # ------------------------------------------------------------ accessors

    def family(self, name: str) -> Optional[dict]:
        for fam in self.families:
            if fam["name"] == name:
                return fam
        return None

    def names(self) -> List[str]:
        return [fam["name"] for fam in self.families]

    def _series(self, name: str, labels: Optional[Dict[str, str]]):
        fam = self.family(name)
        if fam is None:
            return None, None
        want = _series_key({k: str(v) for k, v in (labels or {}).items()})
        for entry in fam["series"]:
            if _series_key(entry["labels"]) == want:
                return fam, entry
        return fam, None

    def value(self, name: str, labels: Optional[Dict[str, str]] = None,
              default: float = 0) -> float:
        """Counter/gauge series value (``default`` when never incremented)."""
        fam, entry = self._series(name, labels)
        if entry is None:
            return default
        if fam["kind"] == "histogram":
            raise ValueError(f"{name} is a histogram — use .histogram()")
        return entry["value"]

    def histogram(self, name: str,
                  labels: Optional[Dict[str, str]] = None
                  ) -> Optional[HistogramView]:
        fam, entry = self._series(name, labels)
        if entry is None:
            return None
        if fam["kind"] != "histogram":
            raise ValueError(f"{name} is a {fam['kind']}, not a histogram")
        return HistogramView(fam["buckets"], entry["counts"],
                             entry["sum"], entry["count"])

    def sum_values(self, name: str, **fixed: str) -> float:
        """Sum a family's series values over every series matching the
        given label subset (e.g. all ``op`` values for one ``transport``)."""
        fam = self.family(name)
        if fam is None:
            return 0
        total = 0
        for entry in fam["series"]:
            if all(entry["labels"].get(k) == str(v)
                   for k, v in fixed.items()):
                total += entry["value"]
        return total

    # ---------------------------------------------------------------- merge

    def merge(self, other: "MetricsSnapshot") -> "MetricsSnapshot":
        """Combine two snapshots (e.g. several workers' registries) into
        one: counter and histogram series sum; gauge series sum too —
        levels like resident bytes or in-flight requests aggregate across
        shards (per-instance gauges should carry a distinguishing label)."""
        out: List[dict] = [json.loads(json.dumps(f)) for f in self.families]
        by_name = {f["name"]: f for f in out}
        for fam in other.families:
            mine = by_name.get(fam["name"])
            if mine is None:
                out.append(json.loads(json.dumps(fam)))
                continue
            if mine["kind"] != fam["kind"] or \
                    mine["buckets"] != fam["buckets"]:
                raise ValueError(f"cannot merge incompatible metric "
                                 f"{fam['name']!r}")
            index = {_series_key(e["labels"]): e for e in mine["series"]}
            for entry in fam["series"]:
                got = index.get(_series_key(entry["labels"]))
                if got is None:
                    mine["series"].append(json.loads(json.dumps(entry)))
                elif mine["kind"] == "histogram":
                    got["counts"] = [a + b for a, b in zip(got["counts"],
                                                           entry["counts"])]
                    got["sum"] += entry["sum"]
                    got["count"] += entry["count"]
                else:
                    got["value"] += entry["value"]
        return MetricsSnapshot(out)

    # ----------------------------------------------------------------- JSON

    def to_json_obj(self) -> dict:
        return {"v": 1, "families": self.families}

    def to_json(self) -> str:
        return json.dumps(self.to_json_obj(), sort_keys=True)

    @classmethod
    def from_json_obj(cls, obj: dict) -> "MetricsSnapshot":
        if not isinstance(obj, dict) or obj.get("v") != 1:
            raise ValueError("not a metrics snapshot (missing v=1)")
        return cls(obj["families"])

    @classmethod
    def from_json(cls, text: str) -> "MetricsSnapshot":
        return cls.from_json_obj(json.loads(text))
