"""Dependency-free observability: metrics, span tracing, exposition.

This package deliberately imports nothing from the rest of the repo (and
nothing beyond the stdlib): the delivery stack depends on ``repro.obs``,
never the reverse.  See ``docs/OBSERVABILITY.md`` for the metric catalog
and usage patterns.
"""

from .metrics import (LATENCY_BUCKETS, SIZE_BUCKETS, HistogramView,
                      MetricsRegistry, MetricsSnapshot, NULL_REGISTRY)
from .trace import NULL_TRACER, Span, Tracer
from .export import (check_monotonic, parse_prometheus_text,
                     to_prometheus_text)

__all__ = [
    "MetricsRegistry", "MetricsSnapshot", "HistogramView", "NULL_REGISTRY",
    "LATENCY_BUCKETS", "SIZE_BUCKETS",
    "Tracer", "Span", "NULL_TRACER",
    "to_prometheus_text", "parse_prometheus_text", "check_monotonic",
]
