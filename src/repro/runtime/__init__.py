"""Training runtime: train step, trainer loop, fault tolerance."""
from repro.runtime.train_step import (TrainConfig, TrainState, make_train_step,
                                      init_train_state, abstract_train_state)
from repro.runtime.trainer import Trainer, SimulatedFailure
