"""The fault-tolerant training driver.

Responsibilities:
  * jit the train step (with shardings when a mesh is active),
  * drive the data pipeline (host-sharded, straggler-aware),
  * periodic CDMT-dedup checkpoints (sync or async),
  * crash recovery: on (re)start, restore the latest registry version and
    resume — the data pipeline is stateless so step k reproduces exactly;
  * failure injection for tests (``fail_at_step``).

On a real cluster each process runs one Trainer with
``jax.distributed.initialize``; here host parallelism is simulated
faithfully at the protocol level (per-host clients, per-host data shards)
while the device math runs on the local mesh.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointConfig, DedupCheckpointManager
from repro.core.registry import Registry
from repro.data import DataConfig, TokenPipeline
from repro.models.api import Model
from repro.runtime.train_step import (TrainConfig, TrainState,
                                      abstract_train_state, init_train_state,
                                      make_train_step, reshape_batch_for_accum)
from repro.runtime.straggler import StragglerConfig, StragglerTracker


class SimulatedFailure(RuntimeError):
    """Injected failure for fault-tolerance tests."""


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt: CheckpointConfig = dataclasses.field(default_factory=CheckpointConfig)
    train: TrainConfig = dataclasses.field(default_factory=TrainConfig)
    straggler: StragglerConfig = dataclasses.field(default_factory=StragglerConfig)
    fail_at_step: Optional[int] = None      # failure injection (tests)


class Trainer:
    def __init__(self, model: Model, data_cfg: DataConfig,
                 cfg: TrainerConfig, registry: Optional[Registry] = None,
                 host: int = 0):
        self.model = model
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.host = host
        self.pipeline = TokenPipeline(data_cfg)
        self.registry = registry if registry is not None else Registry()
        self.ckpt = DedupCheckpointManager(self.registry, cfg.ckpt)
        self.tracker = StragglerTracker(data_cfg.n_hosts, cfg.straggler)
        self.reassignment: Dict[int, int] = {}
        self.metrics_log: List[Dict[str, float]] = []
        self._step_fn = None

    # ------------------------------------------------------------------ setup

    def _train_step(self):
        if self._step_fn is None:
            step = make_train_step(self.model, self.cfg.train)
            self._step_fn = jax.jit(step, donate_argnums=(0,))
        return self._step_fn

    def init_or_restore(self, seed: int = 0) -> TrainState:
        """Fresh init, or resume from the latest registry checkpoint."""
        latest = self.ckpt.latest_step()
        if latest is None:
            return init_train_state(self.model, jax.random.PRNGKey(seed),
                                    self.cfg.train)
        abstract = abstract_train_state(self.model, self.cfg.train)
        state_np, step, _ = self.ckpt.restore(abstract, latest)
        state = jax.tree.map(jnp.asarray, state_np)
        return TrainState(*state)

    # ------------------------------------------------------------------ train

    def _host_batch(self, step: int) -> Dict[str, np.ndarray]:
        rows = self.pipeline.shard_rows(step, self.host, self.reassignment)
        return self.pipeline.batch_for(step, self.host, rows=rows)

    def run(self, state: Optional[TrainState] = None,
            on_step: Optional[Callable[[int, Dict[str, float]], None]] = None
            ) -> TrainState:
        if state is None:
            state = self.init_or_restore()
        step_fn = self._train_step()
        tc = self.cfg.train
        start = int(state.step)
        for step in range(start, self.cfg.total_steps):
            if self.cfg.fail_at_step is not None and step == self.cfg.fail_at_step:
                raise SimulatedFailure(f"injected failure at step {step}")
            t0 = time.time()
            batch = self._host_batch(step)
            batch = reshape_batch_for_accum(batch, tc.n_micro)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = step_fn(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step_s"] = time.time() - t0
            self.metrics_log.append(metrics)
            if on_step:
                on_step(step, metrics)
            if (step + 1) % self.cfg.ckpt.every_steps == 0:
                self.ckpt.save(jax.tree.map(np.asarray, state), step + 1,
                               block=not self.cfg.ckpt.async_push)
        self.ckpt.wait()
        return state

    # --------------------------------------------------------- straggler hook

    def observe_host_times(self, host_times: List[float]) -> Dict[int, int]:
        """Feed per-host step times (from the cluster control plane); returns
        the active data-shard reassignment map."""
        self.tracker.record_step(host_times)
        self.reassignment = self.tracker.reassignment()
        return self.reassignment
