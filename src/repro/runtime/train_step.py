"""The jitted train step: microbatch grad accumulation + AdamW.

One ``train_step(state, batch)`` where ``batch`` arrays carry a leading
``(n_micro, micro_batch, ...)`` layout.  Grad accumulation is a ``lax.scan``
over microbatches:

* activation memory is bounded by ONE microbatch (with per-block remat this
  is what fits 32k-token training shapes in HBM);
* under FSDP sharding XLA hoists the parameter all-gathers that are
  loop-invariant — or re-gathers per microbatch when HBM pressure demands —
  and the gradient reduce-scatter overlaps the next microbatch's compute
  (the compute/comm-overlap trick, DESIGN.md §5).

The optimizer update is sharded identically to the parameters (ZeRO-3
style): m/v PartitionSpecs reuse the param rules.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.optim import (AdamWConfig, adamw_init, adamw_update, apply_updates,
                         clip_by_global_norm, cosine_schedule)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    n_micro: int = 1                  # grad-accumulation microbatches
    adamw: AdamWConfig = AdamWConfig()
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_compression: float = 1.0     # <1 = top-k density (optim/compression)
    accum_dtype: Any = jnp.float32    # grad-accumulator dtype (bf16 halves
                                      # the largest temp at 200B+ scale)
    # Cast f32 masters → bf16 once (sharding-annotated) hoping FSDP gathers
    # move half-width tensors.  REFUTED on XLA:CPU SPMD (EXPERIMENTS §Perf):
    # the partitioner still gathers f32 and converts after, and the bf16
    # copy costs ~1GB of temps — keep off; revisit with explicit shard_map
    # FSDP or on real TPU toolchains.
    cast_params_once: bool = False


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


def init_train_state(model: Model, key: jax.Array, tc: TrainConfig) -> TrainState:
    params = model.init_params(key)
    opt = adamw_init(params, tc.adamw)
    return TrainState(params=params, opt=opt, step=jnp.zeros((), jnp.int32))


def abstract_train_state(model: Model, tc: TrainConfig) -> TrainState:
    """ShapeDtypeStruct train state (dry-run / restore unflattening)."""
    params = model.abstract_params()
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, tc.adamw.state_dtype)
    opt = {"m": jax.tree.map(sds, params), "v": jax.tree.map(sds, params),
           "count": jax.ShapeDtypeStruct((), jnp.int32)}
    return TrainState(params=params, opt=opt,
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def reshape_batch_for_accum(batch: Dict[str, Any], n_micro: int) -> Dict[str, Any]:
    """(B, ...) → (n_micro, B/n_micro, ...)."""
    def r(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])
    return {k: r(v) for k, v in batch.items()}


def make_train_step(model: Model, tc: TrainConfig):
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``batch`` arrays: leading (n_micro, micro_batch).  The function is pure
    and jit/pjit-able; all sharding comes from in/out shardings plus the
    logical-axis constraints inside the model.
    """
    cfg = model.cfg
    schedule = cosine_schedule(tc.adamw.lr, tc.warmup_steps, tc.total_steps)

    def cast_sharded(params):
        """f32 masters → compute dtype once, re-annotated with their param
        shardings so downstream FSDP gathers move the HALF-width tensors."""
        from repro.models import spec as S
        from repro.parallel import sharding as sh
        leaves_p, tdef = jax.tree.flatten(params)
        leaves_s = jax.tree.leaves(model.specs, is_leaf=S.is_spec)
        out = []
        for p, s in zip(leaves_p, leaves_s):
            if p.dtype == jnp.float32 and p.ndim >= 2:
                out.append(sh.constrain_axes(p.astype(cfg.compute_dtype),
                                             s.axes))
            else:
                out.append(p)
        return jax.tree.unflatten(tdef, out)

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def train_step(state: TrainState, batch: Dict[str, Any]):
        masters = state.params
        params = cast_sharded(masters) if tc.cast_params_once else masters

        def micro(carry, mb):
            loss_sum, grad_sum = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            grad_sum = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                    grad_sum, grads)
            return (loss_sum + loss, grad_sum), None

        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, tc.accum_dtype), masters)
        (loss_sum, grad_sum), _ = jax.lax.scan(
            micro, (jnp.float32(0.0), zero_grads), batch)
        grads = jax.tree.map(lambda g: g / tc.n_micro, grad_sum)

        grads, gnorm = clip_by_global_norm(grads, tc.adamw.grad_clip)
        lr = schedule(state.step)
        # updates apply to the f32 MASTERS (mixed-precision discipline)
        updates, new_opt = adamw_update(grads, state.opt, masters, tc.adamw,
                                        lr=lr)
        new_params = apply_updates(masters, updates)
        metrics = {
            "loss": loss_sum / tc.n_micro,
            "grad_norm": gnorm,
            "lr": lr,
        }
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
