"""Straggler detection and mitigation.

Two mechanisms, both enabled by *stateless* substrate layers:

1. **Data-shard reassignment** — per-step host wall times are tracked with an
   EWMA; a host slower than ``threshold ×`` the median is marked a straggler
   and its data-shard rows are reassigned to the fastest host.  Because the
   data pipeline is a pure function of (step, row), the fast host regenerates
   the straggler's rows locally — zero data movement (data/pipeline.py).

2. **Chunk-granular peer fetch** — on restore, a slow-to-fetch host's client
   may fetch missing chunks from *peer* clients instead of the registry
   (BitTorrent-style), chunk-granular thanks to the CDMT index: peers serve
   any chunk whose fingerprint they hold, regardless of which version it
   came from.  (``peer_fetch`` below; used by runtime/fault_tolerance.)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.pushpull import Client


@dataclasses.dataclass
class StragglerConfig:
    threshold: float = 1.8        # × median EWMA step time
    ewma: float = 0.7
    min_history: int = 3


class StragglerTracker:
    """EWMA step-time tracker → reassignment map for the data pipeline."""

    def __init__(self, n_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.n_hosts = n_hosts
        self.times = np.zeros(n_hosts)
        self.count = 0

    def record_step(self, host_times: Sequence[float]) -> None:
        t = np.asarray(host_times, dtype=float)
        if self.count == 0:
            self.times = t
        else:
            self.times = self.cfg.ewma * self.times + (1 - self.cfg.ewma) * t
        self.count += 1

    def stragglers(self) -> List[int]:
        if self.count < self.cfg.min_history:
            return []
        med = float(np.median(self.times))
        return [i for i, t in enumerate(self.times)
                if t > self.cfg.threshold * med]

    def reassignment(self) -> Dict[int, int]:
        """straggler host → replacement host (fastest non-straggler)."""
        slow = set(self.stragglers())
        if not slow:
            return {}
        fast_order = [h for h in np.argsort(self.times) if h not in slow]
        if not fast_order:
            return {}
        out: Dict[int, int] = {}
        for i, h in enumerate(sorted(slow)):
            out[h] = int(fast_order[i % len(fast_order)])
        return out


def peer_fetch(client: Client, peers: Sequence[Client],
               fps: Sequence[bytes]) -> Dict[bytes, List[int]]:
    """Fetch missing chunks from peer chunk stores; returns fp → serving
    peer indices (for accounting).  Falls through silently for chunks no
    peer holds — the caller then hits the registry for the remainder."""
    served: Dict[bytes, List[int]] = {}
    for fp in fps:
        if client.store.chunks.has(fp):
            continue
        for pi, peer in enumerate(peers):
            if peer.store.chunks.has(fp):
                client.store.chunks.put(fp, peer.store.chunks.get(fp))
                served.setdefault(fp, []).append(pi)
                break
    return served
