"""Error-taxonomy analyzer: only typed errors may cross the API boundary.

The delivery stack's contract (``docs/ARCHITECTURE.md``, ``core/errors.py``)
is that public entry points raise only the typed taxonomy —
``DeliveryError``, ``PushRejected``, ``WireError``, ``JournalError``, and
``ValueError`` for caller bugs — never a bare ``KeyError`` / ``OSError`` /
``IndexError`` / ``struct.error``.  This analyzer proves the half of that
contract that is visible in our own source:

  * every **raise site** of a banned type (including a bare ``raise``
    inside a handler that caught one) must carry a
    ``# raises-ok: <reason>`` pragma — an internal raising helper is fine
    (``ChunkStore.get`` keeps its mapping-protocol ``KeyError``), but the
    reason is mandatory prose;
  * a method marked ``# api-boundary`` (trailing comment on its ``def``
    line, mirroring ``# requires-lock:``) must not let a banned type
    **escape** — neither from its own raise sites nor transitively through
    resolvable calls (``self.method()``, ``self.attr.helper()`` via
    ``__init__`` bindings, local aliases).  The pragma on a raise site
    does NOT remove the type from the helper's escape summary: boundary
    callers must still wrap it.  A ``# raises-ok:`` pragma on a *call*
    line allowlists deliberate propagation at that site (absence-signal
    idioms a caller catches).

Escapes through the standard library (``dict[...]``, ``socket``,
``struct.unpack``) are invisible to an AST raise analysis; those paths are
covered by the error-path regression tests (``tests/test_error_contract.py``)
— this lint keeps our *own* raise sites honest and is deliberately
silent on calls it cannot resolve (duck-typed transports), which is why
every concrete implementation of a protocol method carries its own
``# api-boundary`` marker.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .report import Finding

__all__ = ["BANNED", "analyze_files", "check_file", "new_stats"]

# the types that must never cross an api boundary, with the superclasses a
# handler may name to catch them
_SUPERS: Dict[str, Tuple[str, ...]] = {
    "KeyError": ("LookupError",),
    "IndexError": ("LookupError",),
    "OSError": (),
    "IOError": ("OSError",),
    "ConnectionError": ("OSError",),
    "ConnectionResetError": ("ConnectionError", "OSError"),
    "ConnectionRefusedError": ("ConnectionError", "OSError"),
    "ConnectionAbortedError": ("ConnectionError", "OSError"),
    "BrokenPipeError": ("ConnectionError", "OSError"),
    "TimeoutError": ("OSError",),
    "FileNotFoundError": ("OSError",),
    "PermissionError": ("OSError",),
    "InterruptedError": ("OSError",),
    "struct.error": (),
}
BANNED: FrozenSet[str] = frozenset(_SUPERS)

_RAISES_OK_RE = re.compile(r"#\s*raises-ok:\s*(.+?)\s*$")
_BOUNDARY_RE = re.compile(r"#\s*api-boundary\b")

_Origin = Tuple[str, int]          # (path, line) of the originating raise


def new_stats() -> Dict[str, int]:
    return {"files": 0, "classes": 0, "functions": 0, "raise_sites": 0,
            "banned_raises": 0, "boundaries": 0, "pragmas": 0,
            "calls_resolved": 0}


def _catchers(banned: str) -> Set[str]:
    return {banned, *_SUPERS[banned], "Exception", "BaseException"}


def _type_name(node: Optional[ast.expr]) -> Optional[str]:
    """The exception type named by a raise/handler expression."""
    if node is None:
        return None
    if isinstance(node, ast.Call):
        return _type_name(node.func)
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _type_name(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return None


def _handler_types(handler: ast.ExceptHandler) -> List[str]:
    t = handler.type
    if t is None:
        return ["BaseException"]               # bare except
    if isinstance(t, ast.Tuple):
        return [n for n in (_type_name(e) for e in t.elts) if n]
    name = _type_name(t)
    return [name] if name else []


def _banned_name(name: Optional[str]) -> Optional[str]:
    """Canonical banned type for a raise/handler name, or None."""
    if name is None:
        return None
    if name in BANNED:
        return name
    tail = name.rsplit(".", 1)[-1]
    if tail in BANNED and tail != "error":     # struct.error stays dotted
        return tail
    return None


def _ann_class(node) -> Optional[str]:
    """Class name from an annotation node (`Registry`, `Optional[Registry]`,
    string annotations)."""
    if node is None:
        return None
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value.split("[")[0].split(".")[-1].strip('"\' ')
    if isinstance(node, ast.Subscript):
        return _ann_class(node.slice)
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


class _ClassInfo:
    def __init__(self, name: str, path: str):
        self.name = name
        self.path = path
        self.methods: Dict[str, ast.FunctionDef] = {}
        self.bindings: Dict[str, str] = {}     # self.attr -> class name
        self.boundaries: Set[str] = set()      # method names marked


class _Module:
    def __init__(self, path: str, source: str):
        self.path = path
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.classes: Dict[str, _ClassInfo] = {}
        self.functions: Dict[str, ast.FunctionDef] = {}

    def line(self, n: int) -> str:
        return self.lines[n - 1] if 0 < n <= len(self.lines) else ""


def _collect_bindings(cls: _ClassInfo, init: ast.FunctionDef) -> None:
    ann: Dict[str, str] = {}
    args = init.args
    for a in list(args.posonlyargs) + list(args.args) + list(
            args.kwonlyargs):
        c = _ann_class(a.annotation)
        if c:
            ann[a.arg] = c
    for node in ast.walk(init):
        if isinstance(node, ast.AnnAssign) and isinstance(
                node.target, ast.Attribute) and isinstance(
                node.target.value, ast.Name) and \
                node.target.value.id == "self":
            c = _ann_class(node.annotation)
            if c:
                cls.bindings[node.target.attr] = c
        elif isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if not (isinstance(tgt, ast.Attribute) and isinstance(
                    tgt.value, ast.Name) and tgt.value.id == "self"):
                continue
            v = node.value
            if isinstance(v, ast.Call) and isinstance(v.func, ast.Name):
                cls.bindings[tgt.attr] = v.func.id
            elif isinstance(v, ast.Name) and v.id in ann:
                cls.bindings[tgt.attr] = ann[v.id]


def _has_marker(mod: _Module, node: ast.FunctionDef, regex) -> bool:
    end = node.body[0].lineno if node.body else node.lineno + 1
    for ln in range(max(1, node.lineno - 1), end):
        if regex.search(mod.line(ln)):
            return True
    return False


class _Analysis:
    """Cross-file escape analysis: per-function summaries of the banned
    types that can escape, with memoization and a recursion guard."""

    def __init__(self, modules: List[_Module], stats: Dict[str, int]):
        self.modules = modules
        self.stats = stats
        self.findings: List[Finding] = []
        self.class_table: Dict[str, Tuple[_Module, _ClassInfo]] = {}
        self.func_table: Dict[Tuple[str, str], ast.FunctionDef] = {}
        self._summaries: Dict[Tuple[str, str, str],
                              Dict[str, _Origin]] = {}
        self._in_progress: Set[Tuple[str, str, str]] = set()
        self._reported_raises: Set[Tuple[str, int]] = set()
        for mod in modules:
            for cname, cls in mod.classes.items():
                self.class_table.setdefault(cname, (mod, cls))
            for fname, fn in mod.functions.items():
                self.func_table[(mod.path, fname)] = fn

    # ------------------------------------------------------------ summaries

    def summary(self, mod: _Module, cls: Optional[_ClassInfo],
                fn: ast.FunctionDef) -> Dict[str, _Origin]:
        key = (mod.path, cls.name if cls else "", fn.name)
        if key in self._summaries:
            return self._summaries[key]
        if key in self._in_progress:
            return {}                          # recursion: fixpoint at empty
        self._in_progress.add(key)
        out = _FunctionWalker(self, mod, cls).walk(fn)
        self._in_progress.discard(key)
        self._summaries[key] = out
        return out

    def summary_of(self, cname: str, method: str) -> Dict[str, _Origin]:
        entry = self.class_table.get(cname)
        if entry is None:
            return {}
        mod, cls = entry
        fn = cls.methods.get(method)
        if fn is None:
            return {}
        return self.summary(mod, cls, fn)

    # -------------------------------------------------------------- driving

    def run(self) -> None:
        for mod in self.modules:
            for cls in mod.classes.values():
                self.stats["classes"] += 1
                for mname, fn in cls.methods.items():
                    self.stats["functions"] += 1
                    escapes = self.summary(mod, cls, fn)
                    if mname in cls.boundaries:
                        self.stats["boundaries"] += 1
                        for banned, (opath, oline) in sorted(
                                escapes.items()):
                            self.findings.append(Finding(
                                "err-contract", mod.path, fn.lineno,
                                f"api-boundary method "
                                f"'{cls.name}.{mname}' can leak {banned} "
                                f"(raised at {opath}:{oline}) — wrap it "
                                f"in the typed taxonomy"))
            for fn in mod.functions.values():
                self.stats["functions"] += 1
                self.summary(mod, None, fn)

    def report_raise(self, path: str, line: int, banned: str,
                     has_pragma: bool) -> None:
        self.stats["banned_raises"] += 1
        if has_pragma:
            self.stats["pragmas"] += 1
            return
        if (path, line) in self._reported_raises:
            return
        self._reported_raises.add((path, line))
        self.findings.append(Finding(
            "err-contract", path, line,
            f"raise of banned type {banned} without a "
            f"'# raises-ok: <reason>' pragma — public paths must use the "
            f"typed taxonomy (DeliveryError/PushRejected/WireError/"
            f"JournalError/ValueError)"))


class _FunctionWalker:
    """Walk one function body, tracking enclosing-try suppression and the
    local alias environment; returns the escape summary."""

    def __init__(self, analysis: _Analysis, mod: _Module,
                 cls: Optional[_ClassInfo]):
        self.a = analysis
        self.mod = mod
        self.cls = cls
        self.env: Dict[str, str] = {}          # local var -> class name
        self.escapes: Dict[str, _Origin] = {}

    def walk(self, fn: ast.FunctionDef) -> Dict[str, _Origin]:
        for a in list(fn.args.posonlyargs) + list(fn.args.args) + list(
                fn.args.kwonlyargs):
            c = _ann_class(a.annotation)
            if c:
                self.env[a.arg] = c
        for stmt in fn.body:
            self._visit(stmt, caught=frozenset(), handler_types=())
        return self.escapes

    # ------------------------------------------------------------- helpers

    def _pragma(self, line: int) -> bool:
        return bool(_RAISES_OK_RE.search(self.mod.line(line)))

    def _suppressed(self, banned: str, caught: FrozenSet[str]) -> bool:
        return bool(_catchers(banned) & caught)

    def _escape(self, banned: str, origin: _Origin,
                caught: FrozenSet[str]) -> None:
        if self._suppressed(banned, caught):
            return
        self.escapes.setdefault(banned, origin)

    def _resolve_obj(self, node: ast.expr) -> Optional[str]:
        """Class of the object expression `self`, `self.attr`, local var,
        or chains thereof (`self.store.chunks`)."""
        if isinstance(node, ast.Name):
            if node.id == "self":
                return self.cls.name if self.cls else None
            return self.env.get(node.id)
        if isinstance(node, ast.Attribute):
            base = self._resolve_obj(node.value)
            if base is None:
                return None
            entry = self.a.class_table.get(base)
            if entry is None:
                return None
            return entry[1].bindings.get(node.attr)
        return None

    def _callee_summary(self, call: ast.Call) -> Dict[str, _Origin]:
        f = call.func
        if isinstance(f, ast.Attribute):
            cname = self._resolve_obj(f.value)
            if cname is None:
                return {}
            self.a.stats["calls_resolved"] += 1
            return self.a.summary_of(cname, f.attr)
        if isinstance(f, ast.Name):
            fn = self.a.func_table.get((self.mod.path, f.id))
            if fn is not None:
                self.a.stats["calls_resolved"] += 1
                return self.a.summary(self.mod, None, fn)
            if f.id in self.a.class_table:     # constructor call
                self.a.stats["calls_resolved"] += 1
                return self.a.summary_of(f.id, "__init__")
        return {}

    # -------------------------------------------------------------- visits

    def _visit(self, node: ast.stmt, caught: FrozenSet[str],
               handler_types: Tuple[str, ...]) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return      # nested callables run later; analyzed on their own
        if isinstance(node, ast.Raise):
            self._visit_raise(node, caught, handler_types)
            for child in ast.iter_child_nodes(node):
                self._scan_calls(child, caught)
            return
        if isinstance(node, ast.Try):
            body_caught = caught | {
                t for h in node.handlers for t in _handler_types(h)}
            for stmt in node.body:
                self._visit(stmt, body_caught, handler_types)
            for h in node.handlers:
                h_types = tuple(_handler_types(h))
                for stmt in h.body:
                    self._visit(stmt, caught, h_types)
            for stmt in node.orelse + node.finalbody:
                self._visit(stmt, caught, handler_types)
            return
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            c = self._resolve_obj(node.value) if isinstance(
                node.value, (ast.Name, ast.Attribute)) else None
            if c is None and isinstance(node.value, ast.Call) and \
                    isinstance(node.value.func, ast.Name) and \
                    node.value.func.id in self.a.class_table:
                c = node.value.func.id
            if c is not None:
                self.env[node.targets[0].id] = c
        # recurse into nested statements; scan only this statement's own
        # expressions for calls (a call inside a nested try must see that
        # try's handlers, which the recursion provides)
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.stmt):
                self._visit(child, caught, handler_types)
            else:
                self._scan_calls(child, caught)

    def _visit_raise(self, node: ast.Raise, caught: FrozenSet[str],
                     handler_types: Tuple[str, ...]) -> None:
        self.a.stats["raise_sites"] += 1
        if node.exc is None:
            # bare raise: re-raises whatever the enclosing handler caught
            for h in handler_types:
                banned = _banned_name(h)
                if banned is None and h in ("Exception", "BaseException",
                                            "LookupError"):
                    continue    # too wide to judge; tests cover these
                if banned is None:
                    continue
                has_pragma = self._pragma(node.lineno)
                self.a.report_raise(self.mod.path, node.lineno, banned,
                                    has_pragma)
                self._escape(banned, (self.mod.path, node.lineno), caught)
            return
        banned = _banned_name(_type_name(node.exc))
        if banned is None:
            return
        has_pragma = self._pragma(node.lineno)
        self.a.report_raise(self.mod.path, node.lineno, banned, has_pragma)
        self._escape(banned, (self.mod.path, node.lineno), caught)

    def _scan_calls(self, node: ast.AST, caught: FrozenSet[str]) -> None:
        for child in ast.walk(node):
            if isinstance(child, ast.Lambda):
                continue                       # body runs later, elsewhere
            if not isinstance(child, ast.Call):
                continue
            summary = self._callee_summary(child)
            if not summary:
                continue
            if self._pragma(child.lineno):
                self.a.stats["pragmas"] += 1
                continue        # deliberate propagation, reason on the line
            for banned, origin in summary.items():
                self._escape(banned, origin, caught)


def _build_module(path: str, source: str) -> _Module:
    mod = _Module(path, source)
    for node in mod.tree.body:
        if isinstance(node, ast.ClassDef):
            cls = _ClassInfo(node.name, path)
            for item in node.body:
                if isinstance(item, ast.FunctionDef):
                    cls.methods[item.name] = item
                    if _has_marker(mod, item, _BOUNDARY_RE):
                        cls.boundaries.add(item.name)
            init = cls.methods.get("__init__")
            if init is not None:
                _collect_bindings(cls, init)
            mod.classes[node.name] = cls
        elif isinstance(node, ast.FunctionDef):
            mod.functions[node.name] = node
    return mod


def analyze_files(paths: Sequence[str], *,
                  overrides: Optional[Dict[str, str]] = None,
                  stats: Optional[Dict[str, int]] = None
                  ) -> Tuple[List[Finding], Dict[str, int]]:
    """Run the raise/escape analysis over ``paths``.  ``overrides`` maps a
    path to replacement source (tests strip pragmas without touching
    disk)."""
    if stats is None:
        stats = new_stats()
    modules: List[_Module] = []
    for path in paths:
        if overrides and path in overrides:
            source = overrides[path]
        else:
            with open(path, "r", encoding="utf-8") as f:
                source = f.read()
        stats["files"] += 1
        modules.append(_build_module(path, source))
    analysis = _Analysis(modules, stats)
    analysis.run()
    findings = sorted(analysis.findings,
                      key=lambda f: (f.path, f.line, f.message))
    return findings, stats


def check_file(path: str, source: Optional[str] = None) -> List[Finding]:
    """Single-file convenience (doc examples, fixtures)."""
    overrides = {path: source} if source is not None else None
    findings, _ = analyze_files([path], overrides=overrides)
    return findings
