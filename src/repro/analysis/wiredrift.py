"""Wire-spec drift checker: ``repro.delivery.wire`` vs
``docs/WIRE_PROTOCOL.md``, checked in both directions.

The doc's §2/§3/§5 tables are *normative*: every ``FrameType``/``Op``/
``ErrorCode`` member must appear with the matching numeric value, and
every documented row must exist in the enums — so a PR 6-style addition
(``Op.METRICS``, ``FrameType.METRICS``) can never land undocumented, and
a documented frame can never silently lose its implementation.

Beyond the tables:

- every ``FrameType`` must have a registered round-trip exemplar in
  ``EXEMPLARS`` (encode → decode → equality, plus the frame-header type
  byte).  Adding a frame type without registering an exemplar is itself
  a finding — the drift gate grows with the protocol by construction.
- the §8 exact-sizing identities are spot-verified by executing them
  against generated frames (``uvarint_len``, ``recipe_wire_bytes``,
  ``chunk_batch_frame_lens``, envelope sizes, ...).
- the magic strings the doc quotes (``"CW"``, ``"CQ"``, ``"CR"``,
  ``"CL"``) must match the module constants.
"""

from __future__ import annotations

import re
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import hashing
from repro.core.cdmt import CDMT, CDMTParams
from repro.core.registry import PushReceipt
from repro.core.store import Recipe
from repro.delivery import wire

from .report import Finding

# Doc section heading (substring match) -> enum it documents.
_TABLES: List[Tuple[str, str]] = [
    ("Frames", "FrameType"),
    ("Request envelopes", "Op"),
    ("Error codes", "ErrorCode"),
]

_ROW_RE = re.compile(r"^\|\s*(\d+)\s*\|\s*`(\w+)`\s*\|")
_HEADING_RE = re.compile(r"^##+\s+(.*)$")


def parse_doc_tables(doc_text: str) -> Dict[str, Dict[int, Tuple[str, int]]]:
    """Extract ``{enum name: {value: (NAME, doc line)}}`` from the doc."""
    tables: Dict[str, Dict[int, Tuple[str, int]]] = {
        enum: {} for _, enum in _TABLES}
    current: Optional[str] = None
    for lineno, line in enumerate(doc_text.splitlines(), start=1):
        m = _HEADING_RE.match(line)
        if m:
            current = None
            for key, enum in _TABLES:
                if key in m.group(1):
                    current = enum
            continue
        if current is None:
            continue
        m = _ROW_RE.match(line)
        if m:
            tables[current][int(m.group(1))] = (m.group(2), lineno)
    return tables


# ------------------------------------------------------- frame exemplars

def _fps(n: int) -> List[bytes]:
    return [bytes([i + 1]) * hashing.DIGEST_SIZE for i in range(n)]


def _index_pair() -> Tuple[bytes, Callable[[bytes], bool]]:
    t = CDMT.build(_fps(8), CDMTParams(window=2, rule_bits=1, max_fanout=4))
    buf = wire.encode_index(t)

    def ok(b: bytes) -> bool:
        back = wire.decode_index(b)
        return back.root == t.root and back.levels == t.levels
    return buf, ok


def _recipe_pair() -> Tuple[bytes, Callable[[bytes], bool]]:
    r = Recipe("layer0", _fps(3), [10, 200, 70000])
    buf = wire.encode_recipe(r)

    def ok(b: bytes) -> bool:
        back = wire.decode_recipe(b)
        return (back.name, back.fps, back.sizes) == (r.name, r.fps, r.sizes)
    return buf, ok


def _chunk_batch_pair() -> Tuple[bytes, Callable[[bytes], bool]]:
    chunks = {hashing.chunk_fingerprint(d): d
              for d in (b"alpha", b"beta" * 40, b"")}
    buf = wire.encode_chunk_batch(chunks)
    return buf, lambda b: wire.decode_chunk_batch(b) == chunks


def _fp_list_pair(enc: Callable, dec: Callable
                  ) -> Tuple[bytes, Callable[[bytes], bool]]:
    fps = _fps(4)
    return enc(fps), lambda b: dec(b) == fps


def _push_hdr_pair() -> Tuple[bytes, Callable[[bytes], bool]]:
    h = wire.PushHeader("lin", "v1@3", root=_fps(1)[0], parent_version=2,
                        params=CDMTParams(window=2, rule_bits=1,
                                          max_fanout=4))
    buf = wire.encode_push_header(h)

    def ok(b: bytes) -> bool:
        back = wire.decode_push_header(b)
        return (back.lineage, back.tag, back.root, back.parent_version,
                back.params) == (h.lineage, h.tag, h.root,
                                 h.parent_version, h.params)
    return buf, ok


def _tags_pair() -> Tuple[bytes, Callable[[bytes], bool]]:
    buf = wire.encode_tags_request("lin")
    return buf, lambda b: wire.decode_tags_request(b) == "lin"


def _tag_list_pair() -> Tuple[bytes, Callable[[bytes], bool]]:
    tags = ["v1", "v2", "v10"]
    buf = wire.encode_tag_list(tags)
    return buf, lambda b: wire.decode_tag_list(b) == tags


def _error_pair() -> Tuple[bytes, Callable[[bytes], bool]]:
    buf = wire.encode_error(wire.ErrorCode.WIRE, "boom")
    return buf, lambda b: wire.decode_error(b) == (wire.ErrorCode.WIRE,
                                                   "boom")


def _receipt_pair() -> Tuple[bytes, Callable[[bytes], bool]]:
    r = PushReceipt(lineage="lin", tag="v1", version=3, chunks_received=7,
                    bytes_received=4096, index_bytes=512, root=_fps(1)[0],
                    nodes_created=5, nodes_hashed=9, hash_calls=21,
                    deduplicated=False)
    buf = wire.encode_receipt(r)

    def ok(b: bytes) -> bool:
        back = wire.decode_receipt(b)
        return back == r
    return buf, ok


def _info_pair() -> Tuple[bytes, Callable[[bytes], bool]]:
    buf = wire.encode_info(64)
    return buf, lambda b: wire.decode_info(b) == 64


def _ship_pair() -> Tuple[bytes, Callable[[bytes], bool]]:
    buf = wire.encode_ship("replica-1", 3, 17, 100)
    return buf, lambda b: wire.decode_ship(b) == ("replica-1", 3, 17, 100)


def _record_pair() -> Tuple[bytes, Callable[[bytes], bool]]:
    raw = wire.encode_record(1, b"journal payload")
    buf = wire.encode_record_frame(raw)

    def ok(b: bytes) -> bool:
        rtype, payload, verbatim = wire.decode_record_frame(b)
        return rtype == 1 and payload == b"journal payload" \
            and verbatim == raw
    return buf, ok


def _repl_ack_pair() -> Tuple[bytes, Callable[[bytes], bool]]:
    buf = wire.encode_repl_ack("replica-1", 2, 9)
    return buf, lambda b: wire.decode_repl_ack(b) == ("replica-1", 2, 9)


def _metrics_pair() -> Tuple[bytes, Callable[[bytes], bool]]:
    doc = b'{"v": 1, "families": []}'
    buf = wire.encode_metrics(doc)
    return buf, lambda b: wire.decode_metrics(b) == doc


def _snapshot_pair() -> Tuple[bytes, Callable[[bytes], bool]]:
    buf = wire.encode_snapshot("replica-1", 2, 41)
    return buf, lambda b: wire.decode_snapshot(b) == ("replica-1", 2, 41)


# FrameType -> exemplar factory returning (encoded frame, decode check).
EXEMPLARS: Dict[wire.FrameType, Callable[
        [], Tuple[bytes, Callable[[bytes], bool]]]] = {
    wire.FrameType.INDEX: _index_pair,
    wire.FrameType.RECIPE: _recipe_pair,
    wire.FrameType.CHUNK_BATCH: _chunk_batch_pair,
    wire.FrameType.WANT:
        lambda: _fp_list_pair(wire.encode_want, wire.decode_want),
    wire.FrameType.PUSH_HDR: _push_hdr_pair,
    wire.FrameType.HAS:
        lambda: _fp_list_pair(wire.encode_has, wire.decode_has),
    wire.FrameType.MISSING:
        lambda: _fp_list_pair(wire.encode_missing, wire.decode_missing),
    wire.FrameType.TAGS: _tags_pair,
    wire.FrameType.TAG_LIST: _tag_list_pair,
    wire.FrameType.ERROR: _error_pair,
    wire.FrameType.RECEIPT: _receipt_pair,
    wire.FrameType.INFO: _info_pair,
    wire.FrameType.SHIP: _ship_pair,
    wire.FrameType.RECORD: _record_pair,
    wire.FrameType.REPL_ACK: _repl_ack_pair,
    wire.FrameType.METRICS: _metrics_pair,
    wire.FrameType.SNAPSHOT: _snapshot_pair,
}

_WIRE_PATH = "src/repro/delivery/wire.py"


def _wire_line(obj) -> int:
    try:
        return obj.__code__.co_firstlineno
    except AttributeError:
        return 1


def check_doc(doc_path: str, doc_text: Optional[str] = None
              ) -> Tuple[List[Finding], Dict[str, int]]:
    """Cross-check the doc tables against the wire enums, both ways."""
    if doc_text is None:
        with open(doc_path, "r", encoding="utf-8") as f:
            doc_text = f.read()
    findings: List[Finding] = []
    tables = parse_doc_tables(doc_text)
    stats = {"enums": 0, "enum_members": 0, "doc_rows": 0}
    for _, enum_name in _TABLES:
        enum = getattr(wire, enum_name)
        rows = tables[enum_name]
        stats["enums"] += 1
        stats["doc_rows"] += len(rows)
        for member in enum:
            stats["enum_members"] += 1
            row = rows.get(int(member))
            if row is None:
                findings.append(Finding(
                    "wire-drift", _WIRE_PATH, 1,
                    f"{enum_name}.{member.name} = {int(member)} has no "
                    f"row in the normative table of {doc_path}"))
            elif row[0] != member.name:
                findings.append(Finding(
                    "wire-drift", doc_path, row[1],
                    f"documented {enum_name} value {int(member)} is named "
                    f"`{row[0]}` but the enum member is {member.name}"))
        values = {int(m) for m in enum}
        for value, (name, lineno) in sorted(rows.items()):
            if value not in values:
                findings.append(Finding(
                    "wire-drift", doc_path, lineno,
                    f"documented {enum_name} row {value} `{name}` has no "
                    f"matching enum member in repro.delivery.wire"))
    for magic in (wire.MAGIC, wire.REQUEST_MAGIC, wire.RESPONSE_MAGIC,
                  wire.MUX_REQUEST_MAGIC, wire.MUX_RESPONSE_MAGIC,
                  wire.RECORD_MAGIC):
        token = f'`"{magic.decode()}"`'
        if token not in doc_text and f'"{magic.decode()}"' not in doc_text:
            findings.append(Finding(
                "wire-drift", doc_path, 1,
                f"magic {magic!r} from repro.delivery.wire is not quoted "
                f"anywhere in the doc"))
    return findings, stats


def check_codecs() -> Tuple[List[Finding], Dict[str, int]]:
    """Round-trip a representative frame per FrameType and verify the
    frame-header type byte; a FrameType without an exemplar is a finding."""
    findings: List[Finding] = []
    stats = {"frame_types": 0, "round_trips": 0}
    for ftype in wire.FrameType:
        stats["frame_types"] += 1
        factory = EXEMPLARS.get(ftype)
        if factory is None:
            findings.append(Finding(
                "wire-drift", _WIRE_PATH, 1,
                f"FrameType.{ftype.name} has no round-trip exemplar — "
                f"register one in repro.analysis.wiredrift.EXEMPLARS"))
            continue
        try:
            buf, ok = factory()
            got, _payload, off = wire.decode_frame(buf)
            if got is not ftype:
                raise wire.WireError(
                    f"frame encodes type {got.name}, not {ftype.name}")
            if off != len(buf):
                raise wire.WireError("trailing bytes after frame")
            if not ok(buf):
                raise wire.WireError("decode did not round-trip")
            stats["round_trips"] += 1
        except Exception as exc:  # findings, not crashes
            findings.append(Finding(
                "wire-drift", _WIRE_PATH, 1,
                f"FrameType.{ftype.name} exemplar failed: {exc}"))
    return findings, stats


def check_sizing() -> Tuple[List[Finding], Dict[str, int]]:
    """Execute the §8 exact-sizing identities against generated frames."""
    findings: List[Finding] = []
    checks = 0

    def expect(cond: bool, fn, what: str) -> None:
        nonlocal checks
        checks += 1
        if not cond:
            findings.append(Finding(
                "wire-drift", _WIRE_PATH, _wire_line(fn),
                f"sizing identity violated: {what}"))

    for n in (0, 1, 0x7F, 0x80, 300, 70000, 1 << 40):
        expect(wire.uvarint_len(n) == len(wire.encode_uvarint(n)),
               wire.uvarint_len, f"uvarint_len({n})")

    t = CDMT.build(_fps(8), CDMTParams(window=2, rule_bits=1, max_fanout=4))
    expect(wire.index_wire_bytes(t) == len(wire.encode_index(t)),
           wire.index_wire_bytes, "index_wire_bytes(t)")

    r = Recipe("layer0", _fps(5), [0, 1, 127, 128, 99999])
    expect(wire.recipe_wire_bytes(r) == len(wire.encode_recipe(r)),
           wire.recipe_wire_bytes, "recipe_wire_bytes(r)")

    datas = [b"x" * s for s in (0, 1, 100, 5000)]
    chunks = {hashing.chunk_fingerprint(d): d for d in datas}
    expect(wire.chunk_batch_wire_bytes(chunks)
           == len(wire.encode_chunk_batch(chunks)),
           wire.chunk_batch_wire_bytes, "chunk_batch_wire_bytes(chunks)")

    sizes = [len(d) for d in chunks.values()]
    for bc in (1, 3, 16):
        items = list(chunks.items())
        frames = [wire.encode_chunk_batch(dict(items[i:i + bc]))
                  for i in range(0, len(items), bc)]
        expect(wire.chunk_batch_frame_lens(sizes, bc)
               == [len(f) for f in frames],
               wire.chunk_batch_frame_lens,
               f"chunk_batch_frame_lens(sizes, {bc})")
        expect(wire.chunk_batches_wire_bytes(sizes, bc)
               == sum(len(f) for f in frames),
               wire.chunk_batches_wire_bytes,
               f"chunk_batches_wire_bytes(sizes, {bc})")

    body = [wire.encode_want(_fps(2))]
    req = wire.encode_request(wire.Op.WANT, "lin", "v1", body)
    expect(wire.request_envelope_bytes("lin", "v1",
                                       [len(f) for f in body]) == len(req),
           wire.request_envelope_bytes, "request_envelope_bytes(...)")

    resp_frames = [wire.encode_info(64), wire.encode_tag_list(["v1"])]
    resp = wire.encode_response(wire.STATUS_OK, resp_frames)
    expect(wire.response_envelope_bytes(
               [len(f) for f in resp_frames]) == len(resp),
           wire.response_envelope_bytes, "response_envelope_bytes(...)")

    # mux envelopes: identities must hold for any stream id (the id is
    # fixed-width by design — that is what keeps plan quotes exact)
    for sid in (0, 7, wire.MAX_STREAM_ID):
        mreq = wire.encode_mux_request(wire.Op.WANT, sid, "lin", "v1", body)
        expect(wire.mux_request_envelope_bytes(
                   "lin", "v1", [len(f) for f in body]) == len(mreq),
               wire.mux_request_envelope_bytes,
               f"mux_request_envelope_bytes(..., stream_id={sid})")
        measured = len(wire.encode_mux_response_header(
            sid, wire.STATUS_OK, len(resp_frames)))
        measured += sum(len(wire.encode_mux_response_frame(sid, f))
                        for f in resp_frames)
        expect(wire.mux_response_envelope_bytes(
                   [len(f) for f in resp_frames]) == measured,
               wire.mux_response_envelope_bytes,
               f"mux_response_envelope_bytes(..., stream_id={sid})")

    return findings, {"sizing_checks": checks}


def check_all(doc_path: str) -> Tuple[List[Finding], Dict[str, int]]:
    findings: List[Finding] = []
    stats: Dict[str, int] = {}
    for fs, st in (check_doc(doc_path), check_codecs(), check_sizing()):
        findings.extend(fs)
        stats.update(st)
    return findings, stats
