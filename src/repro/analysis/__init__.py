"""Repo-specific static analysis: the machine-checked half of our
concurrency, wire-protocol, layering, error-taxonomy, and durability
contracts.

Six analyzers, one CLI (``tools/analyze.py``), run in CI as a hard gate:

- :mod:`repro.analysis.guarded` — guarded-by lint.  Shared attributes are
  declared with trailing ``# guarded-by: _lock`` comments (or in the
  ``GUARDED_FIELDS`` registry); any access outside a ``with self._lock:``
  block is a finding.
- :mod:`repro.analysis.lockorder` — lock-order analyzer.  Extracts the
  lock-acquisition graph across ``core/`` + ``delivery/`` + ``obs/``,
  detects potential-deadlock cycles, and checks every discovered edge
  against the documented rank hierarchy (``LOCK_RANKS``), which is also
  emitted into ``docs/CONCURRENCY.md``.
- :mod:`repro.analysis.wiredrift` — wire-spec drift checker.  Cross-checks
  ``repro.delivery.wire`` (enums, codecs, sizing functions) against the
  normative tables in ``docs/WIRE_PROTOCOL.md`` in both directions.
- :mod:`repro.analysis.layers` — layer-import analyzer.  Parses the L0–L5
  table in ``docs/ARCHITECTURE.md``, builds the static-and-lazy import
  graph, and rejects upward edges not on the ``LAYER_EXCEPTIONS``
  allowlist (and allowlisted edges that are not lazy).  Emits the
  generated layer-map section of ARCHITECTURE.md.
- :mod:`repro.analysis.errcontract` — error-taxonomy analyzer.  Proves by
  AST raise/escape analysis that every ``# api-boundary`` method can only
  propagate the typed taxonomy (DeliveryError / PushRejected / WireError /
  JournalError / ValueError), never a bare KeyError / OSError /
  struct.error; ``# raises-ok: <reason>`` suppresses a deliberate site.
- :mod:`repro.analysis.durability` — crash-ordering lint.  Checks
  fsync-before-``os.replace`` plus directory fsync after, chunks-durable-
  before-commit-record, and journal-append-before-in-memory-mutation on
  the registry commit paths; ``# durability-ok: <reason>`` suppresses a
  reasoned exception.

:mod:`repro.analysis.runtime` holds the opt-in ``DebugLock`` runtime
companion used by the concurrency stress tests.  Pragma grammar reference:
``docs/CONTRACTS.md``.
"""

from .report import Finding

__all__ = ["Finding"]
