"""Repo-specific static analysis: the machine-checked half of our
concurrency and wire-protocol contracts.

Three analyzers, one CLI (``tools/analyze.py``), run in CI as a hard gate:

- :mod:`repro.analysis.guarded` — guarded-by lint.  Shared attributes are
  declared with trailing ``# guarded-by: _lock`` comments (or in the
  ``GUARDED_FIELDS`` registry); any access outside a ``with self._lock:``
  block is a finding.
- :mod:`repro.analysis.lockorder` — lock-order analyzer.  Extracts the
  lock-acquisition graph across ``core/`` + ``delivery/`` + ``obs/``,
  detects potential-deadlock cycles, and checks every discovered edge
  against the documented rank hierarchy (``LOCK_RANKS``), which is also
  emitted into ``docs/CONCURRENCY.md``.
- :mod:`repro.analysis.wiredrift` — wire-spec drift checker.  Cross-checks
  ``repro.delivery.wire`` (enums, codecs, sizing functions) against the
  normative tables in ``docs/WIRE_PROTOCOL.md`` in both directions.

:mod:`repro.analysis.runtime` holds the opt-in ``DebugLock`` runtime
companion used by the concurrency stress tests.
"""

from .report import Finding

__all__ = ["Finding"]
