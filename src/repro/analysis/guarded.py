"""Guarded-by lint: prove field accesses happen under their declared lock.

Declaration grammar (all machine-read from source comments):

- ``self.field = ... # guarded-by: _lock`` — every read or write of
  ``self.field`` in this class must be lexically inside a
  ``with self._lock:`` block (or in a method that declares it holds the
  lock, see below).  ``__init__`` is exempt: the object is not shared
  before its constructor returns.
- ``self.field = ... # guarded-by: external(<who serializes access>)`` —
  declared shared state whose synchronization lives outside the class
  (e.g. the ``Journal`` single-writer contract behind
  ``RegistryServer._registry_lock``).  Recorded for documentation and
  coverage stats, not enforced lexically.
- ``def helper(self): # requires-lock: _lock`` — the method body is
  analyzed as if the lock were held (caller-holds-lock contract, e.g.
  ``TieredChunkCache._admit``).
- a trailing ``# unguarded-ok: <reason>`` on an access line allowlists
  that single line (documented lock-free fast paths, e.g. reading
  ``SwarmNode.alive`` inside ``serve_want``).

Fields that cannot carry a trailing comment (``__slots__`` hot-path
classes, dynamically created attributes) are declared centrally in
``GUARDED_FIELDS`` keyed by ``(module stem, class name)``.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from .report import Finding

# Declarations for classes whose field definitions cannot carry a trailing
# comment.  The metrics children use __slots__ so their per-field state is
# declared here; they all share the owning MetricsRegistry's lock, passed
# in as the ``lock`` constructor argument and stored as ``self._lock``.
GUARDED_FIELDS: Dict[Tuple[str, str], Dict[str, str]] = {
    ("metrics", "_Counter"): {"_value": "_lock"},
    ("metrics", "_Gauge"): {"_value": "_lock"},
    ("metrics", "_Histogram"): {"_counts": "_lock", "_sum": "_lock",
                                "_count": "_lock"},
    ("metrics", "_Family"): {"_children": "_lock"},
}

_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*(.+?)\s*$")
_REQUIRES_RE = re.compile(r"#\s*requires-lock:\s*(?:self\.)?(\w+)")
_UNGUARDED_OK_RE = re.compile(r"#\s*unguarded-ok\b")
_EXTERNAL_RE = re.compile(r"^external\((.*)\)$", re.DOTALL)

EXTERNAL = "<external>"


class ClassDecls:
    """Declared guarded fields of one class."""

    def __init__(self, name: str) -> None:
        self.name = name
        self.guarded: Dict[str, str] = {}    # field -> lock attr
        self.external: Dict[str, str] = {}   # field -> who serializes


def _parse_lock_spec(spec: str) -> Tuple[str, str]:
    """Return ("lock", attr) or ("external", who)."""
    m = _EXTERNAL_RE.match(spec)
    if m:
        return EXTERNAL, m.group(1).strip()
    attr = spec.strip()
    if attr.startswith("self."):
        attr = attr[len("self."):]
    return "lock", attr


def _self_attr(node: ast.AST) -> Optional[str]:
    """Attribute name if node is ``self.<name>``, else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def collect_declarations(tree: ast.Module, lines: List[str],
                         module_stem: str) -> Dict[str, ClassDecls]:
    """Scan class bodies for ``self.x = ... # guarded-by:`` declarations."""
    out: Dict[str, ClassDecls] = {}
    for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
        decls = ClassDecls(cls.name)
        for node in ast.walk(cls):
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign):
                targets = [node.target]
            else:
                continue
            line = lines[node.lineno - 1]
            m = _GUARDED_RE.search(line)
            if not m:
                continue
            kind, detail = _parse_lock_spec(m.group(1))
            for tgt in targets:
                field = _self_attr(tgt)
                if field is None:
                    continue
                if kind == EXTERNAL:
                    decls.external[field] = detail
                else:
                    decls.guarded[field] = detail
        for field, spec in GUARDED_FIELDS.get((module_stem, cls.name),
                                              {}).items():
            kind, detail = _parse_lock_spec(spec)
            if kind == EXTERNAL:
                decls.external[field] = detail
            else:
                decls.guarded[field] = detail
        if decls.guarded or decls.external:
            out[cls.name] = decls
    return out


class _MethodChecker(ast.NodeVisitor):
    """Walk one method body tracking which ``self.<lock>`` locks are
    lexically held, flagging guarded-field accesses outside them."""

    def __init__(self, scan: "_ClassScan", method: str,
                 held: Set[str]) -> None:
        self.scan = scan
        self.method = method
        self.held = set(held)

    # -- lock tracking -------------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        acquired = []
        for item in node.items:
            attr = _self_attr(item.context_expr)
            if attr is not None and attr not in self.held:
                acquired.append(attr)
            # context managers that are calls (e.g. self._track(op)) are
            # not lock acquisitions; their arguments still get checked.
            self.visit(item.context_expr)
            if item.optional_vars is not None:
                self.visit(item.optional_vars)
        self.held.update(acquired)
        for stmt in node.body:
            self.visit(stmt)
        self.held.difference_update(acquired)

    # -- nested callables run later (thread targets, callbacks): they
    # -- cannot assume the enclosing lock is still held.
    def _visit_nested(self, node: ast.AST) -> None:
        checker = _MethodChecker(self.scan, self.method, set())
        for child in ast.iter_child_nodes(node):
            checker.visit(child)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_nested(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_nested(node)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self._visit_nested(node)

    # -- accesses ------------------------------------------------------
    def visit_Attribute(self, node: ast.Attribute) -> None:
        field = _self_attr(node)
        if field is not None:
            self.scan.check_access(field, node, self.method, self.held)
        self.generic_visit(node)


class _ClassScan:
    def __init__(self, path: str, lines: List[str], decls: ClassDecls,
                 stats: Dict[str, int]) -> None:
        self.path = path
        self.lines = lines
        self.decls = decls
        self.stats = stats
        self.findings: List[Finding] = []

    def check_access(self, field: str, node: ast.AST, method: str,
                     held: Set[str]) -> None:
        lock = self.decls.guarded.get(field)
        if field in self.decls.external:
            self.stats["accesses_checked"] += 1
            return
        if lock is None:
            return
        self.stats["accesses_checked"] += 1
        if lock in held:
            return
        if _UNGUARDED_OK_RE.search(self.lines[node.lineno - 1]):
            return
        self.findings.append(Finding(
            "guarded-by", self.path, node.lineno,
            f"{self.decls.name}.{field} (guarded by '{lock}') accessed "
            f"outside 'with self.{lock}:' in {method}()"))

    def run(self, cls: ast.ClassDef) -> None:
        for node in cls.body:
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if node.name == "__init__":
                continue
            held: Set[str] = set()
            for lineno in range(max(1, node.lineno - 1),
                                node.body[0].lineno):
                m = _REQUIRES_RE.search(self.lines[lineno - 1])
                if m:
                    held.add(m.group(1))
            checker = _MethodChecker(self, node.name, held)
            for stmt in node.body:
                checker.visit(stmt)


def check_file(path: str, source: Optional[str] = None,
               stats: Optional[Dict[str, int]] = None) -> List[Finding]:
    if source is None:
        with open(path, "r", encoding="utf-8") as f:
            source = f.read()
    if stats is None:
        stats = new_stats()
    lines = source.splitlines()
    tree = ast.parse(source, filename=path)
    module_stem = path.rsplit("/", 1)[-1].removesuffix(".py")
    decls = collect_declarations(tree, lines, module_stem)
    findings: List[Finding] = []
    stats["files"] += 1
    for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
        if cls.name not in decls:
            continue
        stats["classes"] += 1
        stats["guarded_fields"] += len(decls[cls.name].guarded)
        stats["external_fields"] += len(decls[cls.name].external)
        scan = _ClassScan(path, lines, decls[cls.name], stats)
        scan.run(cls)
        findings.extend(scan.findings)
    return findings


def check_files(paths: List[str]) -> Tuple[List[Finding], Dict[str, int]]:
    stats = new_stats()
    findings: List[Finding] = []
    for path in paths:
        findings.extend(check_file(path, stats=stats))
    return findings, stats


def new_stats() -> Dict[str, int]:
    return {"files": 0, "classes": 0, "guarded_fields": 0,
            "external_fields": 0, "accesses_checked": 0}
