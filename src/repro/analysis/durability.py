"""Crash-ordering lint: the write-ordering disciplines behind every
durability claim in ``core/store.py`` / ``core/journal.py`` /
``core/registry.py``, machine-checked.

Three checks, each over the statement order *within* one function (the
disciplines are deliberately written straight-line so an AST line-order
check is exact, not heuristic):

1. **fsync-before-replace** — every function calling ``os.replace`` must
   fsync the temp content first (an ``os.fsync`` call on an earlier line)
   and fsync the target's parent directory afterwards (the
   ``os.open(dir, os.O_RDONLY)`` + ``os.fsync`` idiom, or a call to a
   ``fsync_dir`` helper).  Without the first, the rename can commit a
   hole; without the second, the rename itself may not survive a crash.
2. **chunks-before-record** — on the declared :data:`COMMIT_PATHS`
   (``Registry.receive_push`` / ``apply_replicated``), the first
   ``...chunks.sync()`` call must precede the first journal
   ``append_raw``/``append`` — a journaled version whose payloads are not
   yet durable would violate "a journaled version's payloads are always
   servable".
3. **append-before-mutate** — on the declared :data:`JOURNALED_PATHS`,
   the first journal append must precede the first in-memory state
   mutation (assignment through ``self``) and the first call to a
   declared state-applying helper (:data:`MUTATORS`) — an acked change
   must be durable before it is observable.

``# durability-ok: <reason>`` on the offending line suppresses a finding
with mandatory prose (recovery-only paths whose inputs were fsynced
before the crash, etc.).
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .report import Finding

__all__ = ["COMMIT_PATHS", "JOURNALED_PATHS", "MUTATORS", "check_file",
           "check_files", "new_stats"]

# (class, method) pairs that commit pushed payloads: chunk durability must
# precede the commit record
COMMIT_PATHS: Set[Tuple[str, str]] = {
    ("Registry", "receive_push"),
    ("Registry", "apply_replicated"),
    ("Registry", "bootstrap_from_snapshot"),
}

# (class, method) pairs whose in-memory mutations must follow the journal
# append that makes them durable
JOURNALED_PATHS: Set[Tuple[str, str]] = {
    ("Registry", "receive_push"),
    ("Registry", "apply_replicated"),
    ("Registry", "put_metadata"),
    ("Registry", "bootstrap_from_snapshot"),
}

# self-methods that apply replayed state in bulk — calling one counts as an
# in-memory mutation for check 3
MUTATORS: Set[str] = {"_apply"}

_DURABILITY_OK_RE = re.compile(r"#\s*durability-ok:\s*(.+?)\s*$")


def new_stats() -> Dict[str, int]:
    return {"files": 0, "functions": 0, "replace_sites": 0,
            "commit_paths": 0, "journaled_paths": 0, "pragmas": 0}


def _call_name(node: ast.Call) -> str:
    """Dotted name of a call target: ``os.replace``, ``self.chunks.sync``,
    ``f.flush`` …"""
    parts: List[str] = []
    f = node.func
    while isinstance(f, ast.Attribute):
        parts.append(f.attr)
        f = f.value
    if isinstance(f, ast.Name):
        parts.append(f.id)
    return ".".join(reversed(parts))


def _calls(fn: ast.FunctionDef) -> List[Tuple[str, ast.Call]]:
    out = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            out.append((_call_name(node), node))
    return out


def _is_dir_open(call: ast.Call) -> bool:
    """``os.open(<dir>, os.O_RDONLY)`` — the POSIX directory-fsync idiom."""
    if _call_name(call) != "os.open":
        return False
    for arg in call.args:
        if isinstance(arg, ast.Attribute) and arg.attr == "O_RDONLY":
            return True
    return False


class _FileCheck:
    def __init__(self, path: str, source: str, stats: Dict[str, int]):
        self.path = path
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.stats = stats
        self.findings: List[Finding] = []

    def _pragma(self, line: int) -> bool:
        text = self.lines[line - 1] if 0 < line <= len(self.lines) else ""
        if _DURABILITY_OK_RE.search(text):
            self.stats["pragmas"] += 1
            return True
        return False

    # ------------------------------------------------- check 1: os.replace

    def check_replace(self, owner: Optional[str],
                      fn: ast.FunctionDef) -> None:
        calls = _calls(fn)
        replaces = [c for name, c in calls if name == "os.replace"]
        if not replaces:
            return
        fsync_lines = [c.lineno for name, c in calls if name == "os.fsync"]
        # a dir-fsync is the fsync_dir helper, or an os.open(dir, O_RDONLY)
        # immediately followed by an os.fsync (a lone O_RDONLY open is just
        # a read fd)
        dir_fsync_lines = [c.lineno for name, c in calls
                           if name.rsplit(".", 1)[-1] == "fsync_dir"
                           or (_is_dir_open(c) and any(
                               c.lineno <= ln <= c.lineno + 3
                               for ln in fsync_lines))]
        where = f"{owner}.{fn.name}" if owner else fn.name
        for rep in replaces:
            self.stats["replace_sites"] += 1
            pragma = self._pragma(rep.lineno)
            if not any(ln < rep.lineno for ln in fsync_lines) and \
                    not pragma:
                self.findings.append(Finding(
                    "durability", self.path, rep.lineno,
                    f"os.replace in {where} without a preceding "
                    f"os.fsync — the renamed content may not be "
                    f"durable at the moment it becomes visible"))
            if not any(ln > rep.lineno for ln in dir_fsync_lines) and \
                    not pragma:
                self.findings.append(Finding(
                    "durability", self.path, rep.lineno,
                    f"os.replace in {where}: the target's parent "
                    f"directory is never fsynced afterwards — the "
                    f"rename itself may not survive a crash "
                    f"(fsync_dir / os.open(dir, os.O_RDONLY) + "
                    f"os.fsync)"))

    # --------------------------------------- check 2: chunks before record

    def check_commit_order(self, owner: str, fn: ast.FunctionDef) -> None:
        self.stats["commit_paths"] += 1
        calls = _calls(fn)
        sync_lines = [c.lineno for name, c in calls
                      if name.endswith("chunks.sync")]
        append_lines = [c.lineno for name, c in calls
                        if name.rsplit(".", 1)[-1] in ("append_raw",
                                                       "append")
                        and "journal" in name.lower()]
        if not append_lines:
            return                       # nothing journaled here: vacuous
        first_append = min(append_lines)
        if not sync_lines:
            if not self._pragma(first_append):
                self.findings.append(Finding(
                    "durability", self.path, first_append,
                    f"{owner}.{fn.name} journals a commit record but "
                    f"never calls chunks.sync() — referenced payloads "
                    f"must be durable before the record"))
        elif min(sync_lines) > first_append:
            if not self._pragma(first_append):
                self.findings.append(Finding(
                    "durability", self.path, first_append,
                    f"{owner}.{fn.name} appends the commit record at "
                    f"line {first_append} before chunks.sync() at line "
                    f"{min(sync_lines)} — chunks must be durable before "
                    f"the record that references them"))

    # -------------------------------------- check 3: append before mutate

    def check_journal_order(self, owner: str, fn: ast.FunctionDef) -> None:
        self.stats["journaled_paths"] += 1
        calls = _calls(fn)
        append_lines = [c.lineno for name, c in calls
                        if name.rsplit(".", 1)[-1] in ("append_raw",
                                                       "append")
                        and "journal" in name.lower()]
        if not append_lines:
            return
        first_append = min(append_lines)
        mutations: List[Tuple[int, str]] = []
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for tgt in targets:
                    if self._is_self_state(tgt):
                        mutations.append((node.lineno,
                                          "assignment through self"))
            elif isinstance(node, ast.Call):
                name = _call_name(node)
                if name.startswith("self.") and \
                        name.rsplit(".", 1)[-1] in MUTATORS:
                    mutations.append((node.lineno,
                                      f"state-applying call {name}()"))
        for line, what in sorted(mutations):
            if line >= first_append:
                break
            if self._pragma(line):
                continue
            self.findings.append(Finding(
                "durability", self.path, line,
                f"{owner}.{fn.name} mutates in-memory state "
                f"({what}) at line {line} before the journal append at "
                f"line {first_append} — an acked change must be durable "
                f"before it is observable"))

    @staticmethod
    def _is_self_state(tgt: ast.expr) -> bool:
        """``self.x = …`` / ``self.x[...] = …`` / ``self.a.b[...] = …``"""
        node = tgt
        while isinstance(node, (ast.Subscript, ast.Attribute)):
            node = node.value
        if not isinstance(node, ast.Name) or node.id != "self":
            return False
        # a plain `self._x = …` of a local/underscore counter is still a
        # mutation; the commit paths use pragmas where this is benign
        return isinstance(tgt, (ast.Subscript, ast.Attribute))


def check_file(path: str, source: Optional[str] = None,
               stats: Optional[Dict[str, int]] = None,
               commit_paths: Optional[Set[Tuple[str, str]]] = None,
               journaled_paths: Optional[Set[Tuple[str, str]]] = None
               ) -> List[Finding]:
    if source is None:
        with open(path, "r", encoding="utf-8") as f:
            source = f.read()
    if stats is None:
        stats = new_stats()
    if commit_paths is None:
        commit_paths = COMMIT_PATHS
    if journaled_paths is None:
        journaled_paths = JOURNALED_PATHS
    stats["files"] += 1
    fc = _FileCheck(path, source, stats)
    for node in fc.tree.body:
        if isinstance(node, ast.FunctionDef):
            stats["functions"] += 1
            fc.check_replace(None, node)
        elif isinstance(node, ast.ClassDef):
            for item in node.body:
                if not isinstance(item, ast.FunctionDef):
                    continue
                stats["functions"] += 1
                fc.check_replace(node.name, item)
                key = (node.name, item.name)
                if key in commit_paths:
                    fc.check_commit_order(node.name, item)
                if key in journaled_paths:
                    fc.check_journal_order(node.name, item)
    return fc.findings


def check_files(paths: Sequence[str], **kw
                ) -> Tuple[List[Finding], Dict[str, int]]:
    stats = kw.pop("stats", None) or new_stats()
    findings: List[Finding] = []
    for path in paths:
        findings.extend(check_file(path, stats=stats, **kw))
    return findings, stats
