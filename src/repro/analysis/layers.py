"""Layer-import analyzer: "dependencies point **down** only", machine-checked.

``docs/ARCHITECTURE.md`` declares the L0–L5 layer map as an ASCII box table;
this analyzer parses that table, builds the import graph over
``src/repro/{core,delivery,obs}/`` (distinguishing **module-level** imports
from **call-time** imports inside function bodies), and rejects:

  * any upward edge (target layer above source layer) that is not on the
    declared :data:`LAYER_EXCEPTIONS` allowlist;
  * any allowlisted upward edge performed at **module level** — the whole
    point of the exceptions is that ``import repro.core`` never recurses
    into the delivery package, so they must stay lazy;
  * any scanned module with no declared layer — new modules must be added
    to the table before ``--strict`` passes;
  * any scanned module importing ``repro.analysis`` (the gate must never
    become a runtime dependency of what it gates);
  * any ``repro.obs`` module importing the rest of the repo — obs is the
    dependency-free crosscutting layer every tier writes into.

Layer assignments are keyed by module *stem* (``store``, ``wire``, …),
which is how the doc table names them; stems are unique across the scanned
trees (``__init__`` package facades are exempt re-export surfaces).
Downward and same-layer edges are always allowed — layers group modules,
they do not order siblings.

`layers_markdown` renders the derived map + allowlist + discovered upward
edges deterministically; ``tools/analyze.py --write-docs`` splices it into
ARCHITECTURE.md and ``--strict`` fails on drift, exactly like the lock
hierarchy in CONCURRENCY.md.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, List, Optional, Sequence, Tuple

from .report import Finding

__all__ = ["LAYER_EXCEPTIONS", "LayerResult", "analyze_paths",
           "layers_markdown", "parse_layer_doc"]

ARCH_DOC = "docs/ARCHITECTURE.md"

# Declared upward (lower-layer → higher-layer) imports.  Every entry must
# be a *call-time* import in the source — an allowlisted edge performed at
# module level is still a finding.  Keys are (source stem, target stem).
LAYER_EXCEPTIONS: Dict[Tuple[str, str], str] = {
    ("journal", "wire"): (
        "journaled records reuse the delivery frame codec; lazy so "
        "`import repro.core` never recurses into the delivery package"),
    ("registry", "wire"): (
        "commit/metadata records are encoded with the same wire codec the "
        "journal ships (see core.journal's layering note)"),
    ("pushpull", "client"): (
        "legacy shim: `pushpull.Client` delegates to the unified "
        "`ImageClient`, constructed lazily per call"),
    ("pushpull", "transport"): (
        "legacy shim: each push/pull binds a `LocalTransport` to the "
        "target registry at call time"),
}

# Layer line in the ARCHITECTURE.md box table, e.g.
#   L3    │  server.py · cache.py · wire.py (+ delta.py, pushpull.py)
_LAYER_LINE_RE = re.compile(r"^\s*L(\d)\s*│(.*)$")
_MODULE_RE = re.compile(r"(\w+)\.py")


@dataclasses.dataclass
class LayerResult:
    findings: List[Finding]
    assignments: Dict[str, int]          # module stem -> layer
    exceptions: Dict[Tuple[str, str], str]
    edges: List[Tuple[str, str, bool, str, int]]  # (src, dst, lazy, path, ln)
    stats: Dict[str, int]


def parse_layer_doc(text: str) -> Dict[str, int]:
    """Module-stem → layer from the ASCII box table in ARCHITECTURE.md."""
    assignments: Dict[str, int] = {}
    for line in text.splitlines():
        m = _LAYER_LINE_RE.match(line)
        if not m:
            continue
        layer = int(m.group(1))
        for mod in _MODULE_RE.findall(m.group(2)):
            assignments[mod] = layer
    return assignments


def _load_doc_assignments(doc: str) -> Dict[str, int]:
    with open(doc, "r", encoding="utf-8") as f:
        return parse_layer_doc(f.read())


def _module_info(path: str) -> Tuple[str, str]:
    """(stem, package) for a scanned file — package is the containing
    directory name (``core`` / ``delivery`` / ``obs`` in the real tree)."""
    stem = os.path.splitext(os.path.basename(path))[0]
    package = os.path.basename(os.path.dirname(path))
    return stem, package


class _ImportCollector(ast.NodeVisitor):
    """Collect ``(target, lazy, line)`` triples; ``target`` is a dotted
    absolute name (``repro.delivery.wire``) or a package-relative one
    (``.wire`` resolved by the caller)."""

    def __init__(self, package: str):
        self.package = package
        self.imports: List[Tuple[str, bool, int]] = []
        self._depth = 0

    def _add(self, target: str, line: int) -> None:
        self.imports.append((target, self._depth > 0, line))

    def visit_FunctionDef(self, node):
        self._depth += 1
        self.generic_visit(node)
        self._depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef

    def visit_Import(self, node: ast.Import):
        for alias in node.names:
            self._add(alias.name, node.lineno)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.level > 0:
            # relative: `from . import wire` / `from .plan import SourceLeg`
            base = f"repro.{self.package}"
            if node.module:
                base += f".{node.module}"
            if node.module is not None:
                self._add(base, node.lineno)
            else:
                for alias in node.names:
                    self._add(f"{base}.{alias.name}", node.lineno)
        else:
            base = node.module or ""
            # each name may be a distinct module when importing from a
            # package facade (`from repro.core import cdc, cdmt`)
            for alias in node.names:
                self._add(f"{base}.{alias.name}", node.lineno)


def _resolve_target(dotted: str, known: Dict[str, str]
                    ) -> Optional[Tuple[str, str]]:
    """Resolve a dotted import target to ``(stem, package)``.

    ``known`` maps stem → package for every scanned module.  Non-``repro``
    targets (stdlib, third-party) resolve to None.  A target naming a
    package facade (``repro.core``) or a symbol imported *from* a facade
    (``repro.obs.MetricsRegistry``) resolves to the deepest component that
    is a known stem or package.
    """
    if not dotted.startswith("repro"):
        return None
    parts = dotted.split(".")
    # deepest known module stem wins: repro.delivery.wire -> wire
    for part in reversed(parts[1:]):
        if part in known:
            return part, known[part]
    # package facade: repro.core / repro.obs / repro.analysis...
    if len(parts) >= 2:
        return f"{parts[1]}.__init__", parts[1]
    return None


def analyze_paths(paths: Sequence[str], *, doc: str = ARCH_DOC,
                  assignments: Optional[Dict[str, int]] = None,
                  exceptions: Optional[Dict[Tuple[str, str], str]] = None
                  ) -> LayerResult:
    if assignments is None:
        assignments = _load_doc_assignments(doc)
    if exceptions is None:
        exceptions = LAYER_EXCEPTIONS
    findings: List[Finding] = []
    edges: List[Tuple[str, str, bool, str, int]] = []
    stats = {"files": 0, "modules": 0, "edges": 0, "lazy_edges": 0,
             "upward_edges": 0, "exceptions": len(exceptions)}

    modules: List[Tuple[str, str, str]] = []   # (path, stem, package)
    known: Dict[str, str] = {}                 # stem -> package
    for path in paths:
        stem, package = _module_info(path)
        modules.append((path, stem, package))
        if stem != "__init__":
            known[stem] = package
    for stem in assignments:
        # assignments may name modules outside `paths` (fixture runs
        # analyze a single file against the real layer map)
        known.setdefault(stem, "+")

    # package -> max member layer, for edges landing on a facade
    facade_layer: Dict[str, int] = {}
    for stem, package in known.items():
        if stem in assignments:
            facade_layer[package] = max(facade_layer.get(package, 0),
                                        assignments[stem])

    def layer_of(stem: str, package: str) -> Optional[int]:
        if package == "obs":
            return None                        # crosscutting: always below
        if stem.endswith("__init__"):
            return facade_layer.get(package)
        return assignments.get(stem)

    for path, stem, package in modules:
        stats["files"] += 1
        with open(path, "r", encoding="utf-8") as f:
            source = f.read()
        tree = ast.parse(source, filename=path)
        collector = _ImportCollector(package)
        collector.visit(tree)

        is_facade = stem == "__init__"
        is_obs = package == "obs"
        if not is_facade:
            stats["modules"] += 1
            if not is_obs and stem not in assignments:
                findings.append(Finding(
                    "layers", path, 1,
                    f"module '{stem}' has no declared layer — add it to "
                    f"the L0–L5 table in {doc}"))
                continue

        seen_sites = set()
        for dotted, lazy, line in collector.imports:
            if dotted.startswith("repro.analysis"):
                findings.append(Finding(
                    "layers", path, line,
                    f"'{stem}' imports the analysis package — the gate "
                    f"must never be a runtime dependency of gated code"))
                continue
            resolved = _resolve_target(dotted, known)
            if resolved is None:
                continue
            dst_stem, dst_package = resolved
            if is_obs:
                if dst_package != "obs":
                    findings.append(Finding(
                        "layers", path, line,
                        f"obs module '{stem}' imports '{dotted}' — obs is "
                        f"the dependency-free crosscutting layer and must "
                        f"import nothing from the rest of the repo"))
                continue
            if dst_package == "obs" or is_facade:
                continue                       # always allowed / facade
            if (dst_stem, line) in seen_sites:
                continue      # one edge per (module, site): multi-name froms
            seen_sites.add((dst_stem, line))
            edges.append((stem, dst_stem, lazy, path, line))
            stats["edges"] += 1
            if lazy:
                stats["lazy_edges"] += 1
            src_layer = layer_of(stem, package)
            dst_layer = layer_of(dst_stem, dst_package)
            if src_layer is None or dst_layer is None:
                continue                       # unknown already reported
            if dst_layer <= src_layer:
                continue                       # downward or lateral: fine
            stats["upward_edges"] += 1
            reason = exceptions.get((stem, dst_stem))
            if reason is None:
                findings.append(Finding(
                    "layers", path, line,
                    f"upward import: L{src_layer} '{stem}' imports "
                    f"L{dst_layer} '{dst_stem}' — dependencies point down "
                    f"only (declare a LAYER_EXCEPTIONS entry with a "
                    f"reason if this is deliberate, and keep it lazy)"))
            elif not lazy:
                findings.append(Finding(
                    "layers", path, line,
                    f"allowlisted upward import '{stem}' → '{dst_stem}' "
                    f"is performed at module level — the exception "
                    f"requires a lazy, call-time import"))
    return LayerResult(findings=findings, assignments=dict(assignments),
                       exceptions=dict(exceptions), edges=edges, stats=stats)


def layers_markdown(result: LayerResult) -> str:
    """Deterministic markdown for the generated ARCHITECTURE.md section."""
    by_layer: Dict[int, List[str]] = {}
    for stem, layer in result.assignments.items():
        by_layer.setdefault(layer, []).append(stem)
    lines = ["| layer | modules |", "|-------|---------|"]
    for layer in sorted(by_layer, reverse=True):
        mods = " · ".join(f"`{m}`" for m in sorted(by_layer[layer]))
        lines.append(f"| L{layer} | {mods} |")
    lines.append("")
    lines.append("Declared upward exceptions (each must stay a lazy, "
                 "call-time import — `repro.analysis.layers."
                 "LAYER_EXCEPTIONS`):")
    lines.append("")
    for (src, dst) in sorted(result.exceptions):
        lines.append(f"- `{src}` → `{dst}` — {result.exceptions[(src, dst)]}")
    lines.append("")
    lines.append("Discovered upward edges (site of the import; all lazy, "
                 "all allowlisted):")
    lines.append("")
    seen = set()
    upward = []
    for src, dst, lazy, path, line in result.edges:
        src_l = result.assignments.get(src)
        dst_l = result.assignments.get(dst)
        if src_l is None or dst_l is None or dst_l <= src_l:
            continue
        if (src, dst) in seen:
            continue                 # first site per edge keeps the doc tight
        seen.add((src, dst))
        upward.append(f"- `{src}` → `{dst}` — {path}:{line}")
    lines.extend(sorted(upward))
    return "\n".join(lines) + "\n"
