"""Opt-in runtime lock-order checking: ``DebugLock`` + object-graph
instrumentation.

The static analyzer (:mod:`repro.analysis.lockorder`) proves what it can
resolve; ``DebugLock`` closes the soundness gap at runtime.  The stress
tests build a real server stack, call :func:`instrument` on the root
objects to swap every ``threading.Lock``/``RLock`` they own for a ranked
``DebugLock``, hammer the stack from N threads, and assert that
``ViolationLog`` stayed empty — i.e. no thread ever acquired a lock whose
documented rank (``LOCK_RANKS``) was not strictly above everything it
already held.

Instrument *before* any traffic: swapping a lock attribute while another
thread holds the old lock instance would let two threads briefly use
different locks.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Iterable, List, Optional, Tuple

from .lockorder import ALIASES, LOCK_RANKS

_LOCK_TYPES = (type(threading.Lock()), type(threading.RLock()))
_RLOCK_TYPE = type(threading.RLock())


class LockOrderViolation(AssertionError):
    pass


class ViolationLog:
    """Thread-safe collector of runtime ordering violations.

    ``raise_immediately=True`` turns the first violation into a
    ``LockOrderViolation`` at the acquisition site (handy when debugging);
    the default collects, so a stress test can run to completion and
    assert ``log.violations == []`` at the end.
    """

    def __init__(self, raise_immediately: bool = False) -> None:
        self.raise_immediately = raise_immediately
        self.violations: List[str] = []
        self._lock = threading.Lock()  # plain lock: never instrumented

    def record(self, message: str) -> None:
        with self._lock:
            self.violations.append(message)
        if self.raise_immediately:
            raise LockOrderViolation(message)


_held = threading.local()


def _held_stack() -> List["DebugLock"]:
    stack = getattr(_held, "stack", None)
    if stack is None:
        stack = _held.stack = []
    return stack


class DebugLock:
    """Drop-in ``threading.Lock``/``RLock`` wrapper asserting rank order.

    Acquiring a lock whose rank is not strictly greater than every
    currently-held rank (reentrant re-acquisition of the same RLock
    excepted) records a violation.  Unranked locks are violations too —
    the hierarchy must stay total over the locks we actually take.
    """

    def __init__(self, name: str, rank: Optional[int],
                 inner: Any, log: ViolationLog) -> None:
        self.name = name
        self.rank = rank
        self.reentrant = isinstance(inner, _RLOCK_TYPE)
        self._inner = inner
        self._log = log

    # -- checks --------------------------------------------------------
    def _check_order(self) -> None:
        stack = _held_stack()
        if self.rank is None:
            self._log.record(
                f"lock '{self.name}' has no rank in LOCK_RANKS")
            return
        for held in stack:
            if held is self and self.reentrant:
                continue
            if held.rank is None or held.rank >= self.rank:
                self._log.record(
                    f"acquired '{self.name}' (rank {self.rank}) while "
                    f"holding '{held.name}' (rank {held.rank}) — order "
                    f"must be strictly increasing")
                return

    # -- lock protocol -------------------------------------------------
    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._check_order()
        got = self._inner.acquire(blocking, timeout)
        if got:
            _held_stack().append(self)
        return got

    def release(self) -> None:
        stack = _held_stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is self:
                del stack[i]
                break
        self._inner.release()

    def __enter__(self) -> "DebugLock":
        self.acquire()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.release()

    def locked(self) -> bool:
        return self._inner.locked()


def _attr_names(obj: Any) -> Iterable[str]:
    if hasattr(obj, "__dict__"):
        return list(vars(obj).keys())
    names = []
    for klass in type(obj).__mro__:
        names.extend(getattr(klass, "__slots__", ()))
    return names


def instrument(*roots: Any, log: ViolationLog,
               ranks: Optional[Dict[str, int]] = None) -> int:
    """Walk ``roots`` and replace every owned Lock/RLock with a DebugLock.

    Recurses through attributes of ``repro.*`` objects and through
    dict/list/tuple/set containers reached from them, so pre-bound metric
    children and registry families get wrapped too.  A lock instance
    shared between several holders (the metrics registry hands its lock
    to every child) gets exactly one wrapper: ranks are looked up under
    every alias name via ``lockorder.ALIASES``.  Returns the number of
    attribute sites rewritten.
    """
    ranks = LOCK_RANKS if ranks is None else ranks
    wrappers: Dict[int, DebugLock] = {}
    seen: set = set()
    count = 0

    def wrap(name: str, lock: Any) -> DebugLock:
        existing = wrappers.get(id(lock))
        if existing is not None:
            if existing.rank is None:
                existing.rank = ranks.get(ALIASES.get(name, name))
            return existing
        canonical = ALIASES.get(name, name)
        dbg = DebugLock(canonical, ranks.get(canonical), lock, log)
        wrappers[id(lock)] = dbg
        return dbg

    def visit(obj: Any) -> None:
        nonlocal count
        if id(obj) in seen:
            return
        seen.add(id(obj))
        if isinstance(obj, dict):
            for v in list(obj.values()):
                visit(v)
            return
        if isinstance(obj, (list, tuple, set, frozenset)):
            for v in list(obj):
                visit(v)
            return
        module = getattr(type(obj), "__module__", "") or ""
        if not module.startswith("repro."):
            return
        cls_name = type(obj).__name__
        for attr in _attr_names(obj):
            try:
                value = getattr(obj, attr)
            except AttributeError:
                continue
            if isinstance(value, _LOCK_TYPES):
                setattr(obj, attr, wrap(f"{cls_name}.{attr}", value))
                count += 1
            elif isinstance(value, DebugLock):
                continue
            else:
                visit(value)

    for root in roots:
        visit(root)
    return count
