"""Finding type shared by the analyzers.

Findings render as ``path:line: [analyzer] message`` — the same shape
compilers use, so terminals and CI annotations make them clickable.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Iterable, List


@dataclasses.dataclass(frozen=True)
class Finding:
    analyzer: str  # "guarded-by" | "lock-order" | "wire-drift"
    path: str      # repo-relative where possible
    line: int
    message: str

    def location(self) -> str:
        return f"{self.path}:{self.line}"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.analyzer}] {self.message}"


def relpath(path: str, root: str) -> str:
    """Repo-relative path for findings (falls back to the input)."""
    try:
        rel = os.path.relpath(path, root)
    except ValueError:
        return path
    return path if rel.startswith("..") else rel


def render(findings: Iterable[Finding]) -> List[str]:
    return [str(f) for f in findings]
