"""Lock-order analyzer: extract the lock-acquisition graph, prove it acyclic,
and check it against the documented rank hierarchy.

The analyzer walks every class in the scanned files and records an edge
``A -> B`` whenever lock ``B`` can be acquired while ``A`` is held — either
directly (``with self.a: ... with self.b:``) or through a resolvable call
chain (``with self._registry_lock: self.registry.receive_push(...)`` where
``receive_push`` acquires ``ReplicationLog._lock``).  Call resolution is
deliberately simple and static:

- ``self.method(...)`` — same-class summary;
- ``self.attr.method(...)`` / chains — via type bindings inferred from
  ``__init__`` (``self.x = ClassName(...)``, annotated parameters) plus
  ``MANUAL_BINDINGS``;
- local aliases (``log = self.registry.replication``) within a method;
- any call on a ``self._m_*`` attribute (the pre-bound metric-child
  convention) — counts as acquiring ``MetricsRegistry._lock``, since every
  metric child shares its registry's single lock (see ``ALIASES``).

Nested functions (thread targets) are analyzed with an *empty* held set:
they run later, on another thread.  Unresolvable calls contribute nothing —
a documented soundness gap, mitigated by the runtime ``DebugLock`` check in
the stress tests.

``LOCK_RANKS`` is the normative hierarchy: every discovered edge must go
strictly rank-increasing, every discovered lock must be ranked, and the
table is emitted into ``docs/CONCURRENCY.md`` (``tools/analyze.py
--write-docs``) so the documentation cannot drift from the code.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .report import Finding

# The documented lock hierarchy: acquisitions must go strictly rank-upward.
# Locks that never nest with each other may share a rank.
LOCK_RANKS: Dict[str, int] = {
    "RegistryServer._registry_lock": 10,
    "RegistryServer._stats_lock": 12,      # legacy; kept ranked for safety
    "RegistryServer._inflight_lock": 20,
    "SocketRegistryServer._conns_lock": 20,
    "SocketTransport._pool_lock": 20,
    "AsyncRegistryServer._lifecycle_lock": 20,
    "MuxSocketTransport._lock": 20,
    "_MuxConn._lock": 24,
    "_MuxConn._send_lock": 25,
    "JournalFollower._lifecycle_lock": 20,
    "SwarmTracker._lock": 20,
    "SwarmNode._lock": 22,
    "ReplicatedTransport._lock": 20,
    "ReplicationLog._lock": 30,
    "TieredChunkCache._lock": 30,
    "MetricsRegistry._lock": 40,
    "Tracer._lock": 45,
}

# Lock attributes that are aliases of another class's lock (the metric
# children are constructed with the owning registry's lock).
ALIASES: Dict[str, str] = {
    "_Counter._lock": "MetricsRegistry._lock",
    "_Gauge._lock": "MetricsRegistry._lock",
    "_Histogram._lock": "MetricsRegistry._lock",
    "_Family._lock": "MetricsRegistry._lock",
}

# Type bindings the simple inference cannot see (duck-typed parameters).
MANUAL_BINDINGS: Dict[Tuple[str, str], str] = {
    ("RegistryServer", "metrics"): "MetricsRegistry",
    ("Registry", "metrics"): "MetricsRegistry",
}

METRICS_NODE = "MetricsRegistry._lock"
_METRIC = "<metric-child>"
_METRIC_FACTORIES = {"counter", "gauge", "histogram", "labels"}


@dataclasses.dataclass
class _ClassInfo:
    name: str
    path: str
    lock_attrs: Dict[str, str] = dataclasses.field(default_factory=dict)
    # lock attr -> "Lock" | "RLock"
    bindings: Dict[str, str] = dataclasses.field(default_factory=dict)
    methods: Dict[str, ast.FunctionDef] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass
class LockOrderResult:
    findings: List[Finding]
    nodes: Dict[str, Tuple[str, int]]          # lock -> discovery site
    edges: Dict[Tuple[str, str], Tuple[str, int]]  # (a, b) -> first site
    lock_kinds: Dict[str, str]                 # lock -> "Lock" | "RLock"
    stats: Dict[str, int]


def _ann_class(node: Optional[ast.AST]) -> Optional[str]:
    """Extract a class name from an annotation (handles Optional[X], "X")."""
    if node is None:
        return None
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.Subscript):
        base = node.value
        if isinstance(base, ast.Name) and base.id == "Optional":
            return _ann_class(node.slice)
    return None


class _Analyzer:
    def __init__(self, ranks: Optional[Dict[str, int]],
                 check_ranks: bool) -> None:
        self.ranks = ranks or {}
        self.check_ranks = check_ranks
        self.classes: Dict[str, _ClassInfo] = {}
        self.nodes: Dict[str, Tuple[str, int]] = {}
        self.edges: Dict[Tuple[str, str], Tuple[str, int]] = {}
        self.lock_kinds: Dict[str, str] = {}
        self.findings: List[Finding] = []
        self._summaries: Dict[Tuple[str, str], Set[str]] = {}
        self._in_progress: Set[Tuple[str, str]] = set()
        self.stats = {"files": 0, "classes": 0, "locks": 0, "edges": 0}

    # ---------------- pass 1: collect classes ----------------
    def load(self, path: str, source: str) -> None:
        tree = ast.parse(source, filename=path)
        self.stats["files"] += 1
        for cls in [n for n in ast.walk(tree)
                    if isinstance(n, ast.ClassDef)]:
            info = _ClassInfo(cls.name, path)
            for node in cls.body:
                if isinstance(node, ast.FunctionDef):
                    info.methods[node.name] = node
            init = info.methods.get("__init__")
            if init is not None:
                self._collect_init(info, init)
            for key, target in MANUAL_BINDINGS.items():
                if key[0] == cls.name:
                    info.bindings[key[1]] = target
            self.classes[cls.name] = info
            self.stats["classes"] += 1

    def _collect_init(self, info: _ClassInfo, init: ast.FunctionDef) -> None:
        params: Dict[str, str] = {}
        for arg in init.args.args + init.args.kwonlyargs:
            cls = _ann_class(arg.annotation)
            if cls:
                params[arg.arg] = cls
        for node in ast.walk(init):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            tgt = node.targets[0]
            if not (isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self"):
                continue
            attr, value = tgt.attr, node.value
            kind = self._lock_ctor(value)
            if kind is not None:
                info.lock_attrs[attr] = kind
                node_name = self._canonical(f"{info.name}.{attr}")
                self.nodes.setdefault(node_name, (info.path, node.lineno))
                self.lock_kinds.setdefault(node_name, kind)
                continue
            bound = self._bind_value(value, params)
            if bound is not None:
                info.bindings.setdefault(attr, bound)

    @staticmethod
    def _lock_ctor(value: ast.AST) -> Optional[str]:
        if (isinstance(value, ast.Call)
                and isinstance(value.func, ast.Attribute)
                and isinstance(value.func.value, ast.Name)
                and value.func.value.id == "threading"
                and value.func.attr in ("Lock", "RLock")):
            return value.func.attr
        return None

    def _bind_value(self, value: ast.AST,
                    params: Dict[str, str]) -> Optional[str]:
        if isinstance(value, ast.BoolOp):
            for operand in value.values:
                bound = self._bind_value(operand, params)
                if bound is not None:
                    return bound
            return None
        if isinstance(value, ast.Call) and isinstance(value.func, ast.Name):
            return value.func.id
        if isinstance(value, ast.Name):
            return params.get(value.id)
        return None

    def _canonical(self, node_name: str) -> str:
        return ALIASES.get(node_name, node_name)

    # ---------------- pass 2: acquisition summaries ----------------
    def summarize_all(self) -> None:
        for cls in self.classes.values():
            for meth in cls.methods:
                self._acquired(cls.name, meth)

    def _acquired(self, cls_name: str, meth: str) -> Set[str]:
        key = (cls_name, meth)
        if key in self._summaries:
            return self._summaries[key]
        if key in self._in_progress:
            return set()  # recursion: fixpoint approximated by empty set
        cls = self.classes.get(cls_name)
        if cls is None or meth not in cls.methods:
            return set()
        self._in_progress.add(key)
        acquired: Set[str] = set()
        node = cls.methods[meth]
        env: Dict[str, str] = {}
        for stmt in node.body:
            self._walk(cls, stmt, set(), acquired, env)
        self._in_progress.discard(key)
        self._summaries[key] = acquired
        return acquired

    # -- graph recording
    def _acquire(self, cls: _ClassInfo, lock: str, held: Set[str],
                 acquired: Set[str], site: Tuple[str, int]) -> None:
        self.nodes.setdefault(lock, site)
        for h in held:
            if h == lock:
                continue
            self.edges.setdefault((h, lock), site)
        acquired.add(lock)

    def _call_summary(self, cls: _ClassInfo, target_cls: str, meth: str,
                      held: Set[str], acquired: Set[str],
                      site: Tuple[str, int]) -> None:
        for lock in self._acquired(target_cls, meth):
            self._acquire(cls, lock, held, acquired, site)

    # -- expression/statement walker
    def _walk(self, cls: _ClassInfo, node: ast.AST, held: Set[str],
              acquired: Set[str], env: Dict[str, str]) -> None:
        if isinstance(node, ast.With):
            newly: List[str] = []
            for item in node.items:
                ctx = item.context_expr
                lock = self._as_own_lock(cls, ctx)
                if lock is not None:
                    site = (cls.path, ctx.lineno)
                    self._acquire(cls, lock, held | set(newly),
                                  acquired, site)
                    if lock not in held:
                        newly.append(lock)
                else:
                    self._walk(cls, ctx, held, acquired, env)
            inner = held | set(newly)
            for stmt in node.body:
                self._walk(cls, stmt, inner, acquired, env)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            # Runs later, possibly on another thread: empty held set, and
            # its acquisitions do not become part of this method's summary.
            nested_acquired: Set[str] = set()
            body = node.body if isinstance(node.body, list) else [node.body]
            for stmt in body:
                self._walk(cls, stmt, set(), nested_acquired, dict(env))
            return
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            bound = self._resolve(cls, env, node.value)
            if bound is not None and bound != _METRIC:
                env[node.targets[0].id] = bound
            self._walk(cls, node.value, held, acquired, env)
            return
        if isinstance(node, ast.Call):
            self._resolve_call(cls, env, node, held, acquired)
            for child in ast.iter_child_nodes(node):
                self._walk(cls, child, held, acquired, env)
            return
        for child in ast.iter_child_nodes(node):
            self._walk(cls, child, held, acquired, env)

    def _as_own_lock(self, cls: _ClassInfo, expr: ast.AST) -> Optional[str]:
        if (isinstance(expr, ast.Attribute)
                and isinstance(expr.value, ast.Name)
                and expr.value.id == "self"
                and expr.attr in cls.lock_attrs):
            return self._canonical(f"{cls.name}.{expr.attr}")
        return None

    def _resolve_call(self, cls: _ClassInfo, env: Dict[str, str],
                      call: ast.Call, held: Set[str],
                      acquired: Set[str]) -> None:
        func = call.func
        if not isinstance(func, ast.Attribute):
            return
        site = (cls.path, call.lineno)
        base = self._resolve(cls, env, func.value)
        if base == _METRIC:
            self._acquire(cls, METRICS_NODE, held, acquired, site)
        elif base is not None and base in self.classes:
            if func.attr in self.classes[base].methods:
                self._call_summary(cls, base, func.attr, held, acquired,
                                   site)
            elif base == "MetricsRegistry" and \
                    func.attr in _METRIC_FACTORIES:
                self._acquire(cls, METRICS_NODE, held, acquired, site)

    def _resolve(self, cls: _ClassInfo, env: Dict[str, str],
                 expr: ast.AST) -> Optional[str]:
        """Resolve an expression to a class name or the metric marker."""
        if isinstance(expr, ast.Name):
            if expr.id == "self":
                return cls.name
            return env.get(expr.id)
        if isinstance(expr, ast.Attribute):
            base = self._resolve(cls, env, expr.value)
            if base == _METRIC:
                return _METRIC
            if base is None:
                return None
            if expr.attr.startswith("_m_"):
                return _METRIC
            info = self.classes.get(base)
            if info is not None:
                return info.bindings.get(expr.attr)
            return None
        if isinstance(expr, ast.Subscript):
            return self._resolve(cls, env, expr.value)
        if isinstance(expr, ast.Call):
            func = expr.func
            if isinstance(func, ast.Attribute) and \
                    func.attr in _METRIC_FACTORIES:
                base = self._resolve(cls, env, func.value)
                if base in (_METRIC, "MetricsRegistry"):
                    return _METRIC
            if isinstance(func, ast.Name) and func.id in self.classes:
                return func.id
            return None
        return None

    # ---------------- pass 3: checks ----------------
    def check(self) -> None:
        self.stats["locks"] = len(self.nodes)
        self.stats["edges"] = len(self.edges)
        for (a, b), (path, line) in sorted(self.edges.items()):
            if a == b:
                if self.lock_kinds.get(a) != "RLock":
                    self.findings.append(Finding(
                        "lock-order", path, line,
                        f"'{a}' re-acquired while already held and is not "
                        f"an RLock (self-deadlock)"))
                continue
            if not self.check_ranks:
                continue
            ra, rb = self.ranks.get(a), self.ranks.get(b)
            if ra is not None and rb is not None and ra >= rb:
                self.findings.append(Finding(
                    "lock-order", path, line,
                    f"acquisition '{a}' -> '{b}' contradicts the "
                    f"documented hierarchy (rank {ra} >= {rb}); see "
                    f"docs/CONCURRENCY.md"))
        if self.check_ranks:
            for node, (path, line) in sorted(self.nodes.items()):
                if node not in self.ranks:
                    self.findings.append(Finding(
                        "lock-order", path, line,
                        f"lock '{node}' is not ranked in "
                        f"repro.analysis.lockorder.LOCK_RANKS — rank it "
                        f"and regenerate docs/CONCURRENCY.md"))
        self._check_cycles()

    def _check_cycles(self) -> None:
        adj: Dict[str, List[str]] = {}
        for (a, b) in self.edges:
            if a != b:
                adj.setdefault(a, []).append(b)
        index: Dict[str, int] = {}
        low: Dict[str, int] = {}
        on_stack: Set[str] = set()
        stack: List[str] = []
        counter = [0]
        sccs: List[List[str]] = []

        def strongconnect(v: str) -> None:
            index[v] = low[v] = counter[0]
            counter[0] += 1
            stack.append(v)
            on_stack.add(v)
            for w in adj.get(v, ()):
                if w not in index:
                    strongconnect(w)
                    low[v] = min(low[v], low[w])
                elif w in on_stack:
                    low[v] = min(low[v], index[w])
            if low[v] == index[v]:
                scc = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    scc.append(w)
                    if w == v:
                        break
                sccs.append(scc)

        for v in list(adj) + [b for bs in adj.values() for b in bs]:
            if v not in index:
                strongconnect(v)
        for scc in sccs:
            if len(scc) < 2:
                continue
            cyc = sorted(scc)
            path, line = self.edges.get(
                (cyc[0], cyc[1]), next(iter(self.edges.values())))
            self.findings.append(Finding(
                "lock-order", path, line,
                "potential deadlock cycle: " + " -> ".join(
                    cyc + [cyc[0]])))


def analyze_files(paths: List[str], *,
                  ranks: Optional[Dict[str, int]] = None,
                  check_ranks: bool = True) -> LockOrderResult:
    """Run the lock-order analysis over ``paths``.

    ``ranks=None`` with ``check_ranks=True`` uses the repo's normative
    ``LOCK_RANKS``; pass ``check_ranks=False`` to only detect cycles (used
    for the broken-fixture self-test).
    """
    an = _Analyzer(LOCK_RANKS if ranks is None else ranks, check_ranks)
    for path in paths:
        with open(path, "r", encoding="utf-8") as f:
            an.load(path, f.read())
    an.summarize_all()
    an.check()
    return LockOrderResult(an.findings, an.nodes, an.edges,
                           an.lock_kinds, an.stats)


def hierarchy_markdown(result: LockOrderResult,
                       ranks: Optional[Dict[str, int]] = None) -> str:
    """Render the documented hierarchy + discovered edges as markdown.

    Deterministic, so ``tools/analyze.py --strict`` can diff it against the
    generated section of ``docs/CONCURRENCY.md``.
    """
    ranks = LOCK_RANKS if ranks is None else ranks
    out = ["| rank | lock | kind | acquires while held |",
           "|------|------|------|---------------------|"]
    succ: Dict[str, List[str]] = {}
    for (a, b) in result.edges:
        if a != b:
            succ.setdefault(a, []).append(b)
    for lock, rank in sorted(ranks.items(), key=lambda kv: (kv[1], kv[0])):
        kind = result.lock_kinds.get(lock, "Lock")
        nxt = ", ".join(f"`{b}`" for b in sorted(succ.get(lock, [])))
        out.append(f"| {rank} | `{lock}` | {kind} | {nxt or '—'} |")
    out.append("")
    out.append("Discovered acquisition edges (site of the inner "
               "acquisition):")
    out.append("")
    for (a, b), (path, line) in sorted(result.edges.items()):
        if a != b:
            out.append(f"- `{a}` → `{b}` — {path}:{line}")
    if not any(a != b for (a, b) in result.edges):
        out.append("- (none)")
    return "\n".join(out) + "\n"
