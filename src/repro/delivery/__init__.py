"""``repro.delivery`` — the measurable delivery stack on top of the CDMT core.

The core (``repro.core``) proves the paper's *algorithms*; this package turns
them into a delivery *system* whose byte counts are real:

  * :mod:`repro.delivery.wire`      — varint-framed binary wire format for
    CDMT indexes, recipes, chunk batches, want-lists, and presence queries
    (round-trip, self-verifying);
  * :mod:`repro.delivery.cache`     — tiered chunk cache (in-memory LRU over
    the disk/log ``ChunkStore``) with hit/miss/eviction/warm accounting;
  * :mod:`repro.delivery.server`    — concurrent registry frontend: many
    pullers, request coalescing, batched chunk responses, restart warm-up,
    exact egress/ingress meters;
  * :mod:`repro.delivery.transport` — the pluggable :class:`Transport`
    protocol with in-process (``LocalTransport``), framed (``WireTransport``)
    and peer-first (``SwarmTransport``) implementations;
  * :mod:`repro.delivery.plan`      — inspectable :class:`PullPlan` and the
    unified per-source :class:`TransferReport` accounting;
  * :mod:`repro.delivery.client`    — :class:`ImageClient`, the single
    client API (``plan_pull``/``execute``/``push``/``upgrade``) every legacy
    entry point now routes through;
  * :mod:`repro.delivery.net`       — real TCP: ``SocketRegistryServer``
    (threaded acceptor, enveloped requests, streamed WANT responses, ERROR
    frames) and ``SocketTransport`` (pooled connections, byte-exact socket
    accounting) — the row where reported bytes actually crossed a wire;
  * :mod:`repro.delivery.aio`       — the async data plane:
    ``AsyncRegistryServer`` (event-loop acceptor, O(cores) worker threads,
    multiplexed streams, backpressure, BUSY load-shedding) and
    ``MuxSocketTransport`` (many concurrent pulls over a few shared
    connections, same byte-exact accounting);
  * :mod:`repro.delivery.delta`     — ``DeltaSession`` compatibility shim
    (pipelined wire sessions);
  * :mod:`repro.delivery.swarm`     — EdgePier-style peer mode: provisioned
    clients serve chunks to later pullers before the registry is consulted.

Observability: every layer above meters itself into a
:class:`repro.obs.MetricsRegistry` (see ``docs/OBSERVABILITY.md``), and a
live server's full snapshot is scrapeable over the socket protocol via
``Op.METRICS`` (``SocketTransport.scrape_metrics``).
"""

from .aio import (AsyncRegistryServer, AsyncServerStats, MuxSocketTransport,
                  serve_registry_async)
from .cache import CacheStats, TieredChunkCache
from .client import ImageClient
from .delta import DeliveryError, DeliveryStats, DeltaSession
from .net import (JournalFollower, SocketRegistryServer, SocketServerStats,
                  SocketTransport, serve_registry)
from .plan import PullPlan, SourceLeg, TransferReport
from .server import RegistryServer, ServerStats
from .swarm import SwarmNode, SwarmStats, SwarmTracker, swarm_pull
from .transport import (FetchResult, LocalTransport, PushOutcome,
                        ReplicatedTransport, SwarmTransport, Transport,
                        TransportMeter, WireTransport)
from .wire import (ErrorCode, FrameType, Op, WireError, decode_chunk_batch,
                   decode_error, decode_frame, decode_has, decode_index,
                   decode_info, decode_metrics, decode_missing,
                   decode_receipt, decode_recipe, decode_record_frame,
                   decode_repl_ack, decode_request, decode_response,
                   decode_ship, decode_snapshot, decode_tag_list,
                   decode_tags_request, decode_want, encode_chunk_batch,
                   encode_error, encode_frame, encode_has, encode_index,
                   encode_info, encode_metrics, encode_missing,
                   encode_receipt, encode_recipe, encode_record_frame,
                   encode_repl_ack, encode_request, encode_response,
                   encode_ship, encode_snapshot, encode_tag_list,
                   encode_tags_request, encode_want)

__all__ = [
    "CacheStats", "TieredChunkCache",
    "ImageClient",
    "DeliveryError", "DeliveryStats", "DeltaSession",
    "PullPlan", "SourceLeg", "TransferReport",
    "RegistryServer", "ServerStats",
    "JournalFollower", "SocketRegistryServer", "SocketServerStats",
    "SocketTransport", "serve_registry",
    "AsyncRegistryServer", "AsyncServerStats", "MuxSocketTransport",
    "serve_registry_async",
    "SwarmNode", "SwarmStats", "SwarmTracker", "swarm_pull",
    "Transport", "LocalTransport", "WireTransport", "SwarmTransport",
    "ReplicatedTransport", "FetchResult", "PushOutcome", "TransportMeter",
    "FrameType", "Op", "ErrorCode", "WireError",
    "encode_frame", "decode_frame",
    "encode_index", "decode_index",
    "encode_recipe", "decode_recipe",
    "encode_chunk_batch", "decode_chunk_batch",
    "encode_want", "decode_want",
    "encode_has", "decode_has",
    "encode_missing", "decode_missing",
    "encode_tags_request", "decode_tags_request",
    "encode_tag_list", "decode_tag_list",
    "encode_error", "decode_error",
    "encode_receipt", "decode_receipt",
    "encode_info", "decode_info",
    "encode_metrics", "decode_metrics",
    "encode_ship", "decode_ship",
    "encode_snapshot", "decode_snapshot",
    "encode_record_frame", "decode_record_frame",
    "encode_repl_ack", "decode_repl_ack",
    "encode_request", "decode_request",
    "encode_response", "decode_response",
]
