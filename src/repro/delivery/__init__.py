"""``repro.delivery`` — the measurable delivery stack on top of the CDMT core.

The core (``repro.core``) proves the paper's *algorithms*; this package turns
them into a delivery *system* whose byte counts are real:

  * :mod:`repro.delivery.wire`   — varint-framed binary wire format for CDMT
    indexes, recipes, chunk batches, and want-lists (round-trip, self-verifying);
  * :mod:`repro.delivery.cache`  — tiered chunk cache (in-memory LRU over the
    disk/log ``ChunkStore``) with hit/miss/eviction accounting;
  * :mod:`repro.delivery.server` — concurrent registry frontend: many pullers,
    request coalescing, batched chunk responses, exact egress/ingress meters;
  * :mod:`repro.delivery.delta`  — session protocol pipelining Algorithm 2
    compare with chunk transfer (compare keeps walking while batches fetch);
  * :mod:`repro.delivery.swarm`  — EdgePier-style peer mode: provisioned
    clients serve chunks to later pullers before the registry is consulted.
"""

from .cache import CacheStats, TieredChunkCache
from .delta import DeliveryError, DeliveryStats, DeltaSession
from .server import RegistryServer, ServerStats
from .swarm import SwarmNode, SwarmStats, SwarmTracker, swarm_pull
from .wire import (FrameType, WireError, decode_chunk_batch, decode_frame,
                   decode_index, decode_recipe, decode_want, encode_chunk_batch,
                   encode_frame, encode_index, encode_recipe, encode_want)

__all__ = [
    "CacheStats", "TieredChunkCache",
    "DeliveryError", "DeliveryStats", "DeltaSession",
    "RegistryServer", "ServerStats",
    "SwarmNode", "SwarmStats", "SwarmTracker", "swarm_pull",
    "FrameType", "WireError",
    "encode_frame", "decode_frame",
    "encode_index", "decode_index",
    "encode_recipe", "decode_recipe",
    "encode_chunk_batch", "decode_chunk_batch",
    "encode_want", "decode_want",
]
