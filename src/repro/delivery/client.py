"""The unified delivery client — one Algorithm-2 implementation, any
:class:`~repro.delivery.transport.Transport`.

:class:`ImageClient` is the single client-facing API of the repo.  The
legacy entry points (``repro.core.pushpull.Client``, ``DeltaSession``,
``swarm_pull``) are thin shims that construct an ``ImageClient`` over the
matching transport, so the compare/transfer/accounting logic exists exactly
once:

  * ``plan_pull`` — download the KB-sized index + recipe, run Algorithm 2
    against the local tree, consult the local store for cross-lineage
    dedup, and return an inspectable :class:`~repro.delivery.plan.PullPlan`
    (what will move, what it should cost) without moving a chunk;
  * ``execute`` — stream the plan's fetch list in pipelined batches through
    the transport, with per-source accounting and (for multi-source
    transports) automatic failover, then verify + ingest atomically;
  * ``push`` — Algorithm 2 against the registry head, presence-check the
    candidate set (``has_chunks``: ship only what the backend truly lacks),
    and hand the transport a verified push;
  * ``upgrade`` — pull the lineage head; ``materialize`` — reconstruct.

Every operation returns a :class:`~repro.delivery.plan.TransferReport`.

Observability: the client adopts its transport's
:class:`~repro.obs.MetricsRegistry` (so one snapshot covers the client's
``client_*`` histograms *and* the transport's byte/latency series) and
accepts a :class:`~repro.obs.Tracer` — disabled by default, near-zero cost
— that records one span tree per pull (``pull`` → ``plan_pull`` /
``execute`` → per-batch ``fetch_batch`` children, attributed across the
pipeline's pool threads via explicit parent hand-off).
"""

from __future__ import annotations

import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence

from repro.core import cdc
from repro.core.cdmt import (CDMT, CDMTParams, DEFAULT_PARAMS,
                             iter_missing_leaves)
from repro.core.errors import DeliveryError
from repro.core.store import DedupStore, Recipe
from repro.obs import (LATENCY_BUCKETS, MetricsRegistry, NULL_TRACER,
                       Tracer)

from . import wire
from .plan import PullPlan, TransferReport
from .transport import Transport

__all__ = ["ImageClient"]


class ImageClient:
    """A client node (local dedup store + per-lineage CDMT) bound to one
    transport.

    ``store`` / ``indexes`` / ``tag_trees`` may be donated so several
    clients (or the legacy shims) share one local state while talking
    through different transports; by default the client owns fresh state.
    """

    def __init__(self, transport: Optional[Transport], *,
                 store: Optional[DedupStore] = None,
                 indexes: Optional[Dict[str, CDMT]] = None,
                 tag_trees: Optional[Dict[str, CDMT]] = None,
                 cdc_params: cdc.CDCParams = cdc.DEFAULT_PARAMS,
                 cdmt_params: CDMTParams = DEFAULT_PARAMS,
                 directory: Optional[str] = None,
                 batch_chunks: int = 64, pipeline_depth: int = 4,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Tracer = NULL_TRACER):
        self.transport = transport
        self.store = store if store is not None \
            else DedupStore(directory, cdc_params)
        self.cdmt_params = cdmt_params
        self.indexes: Dict[str, CDMT] = indexes if indexes is not None else {}
        # per-tag tree cache: "lineage:tag" -> CDMT.  Without it, every
        # push/pull of a non-head tag rebuilt the full tree from the recipe
        # (O(n) hashing); with it, a cached tree is returned directly and a
        # cold tag is built incrementally against the head (O(k·depth)).
        self.tag_trees: Dict[str, CDMT] = \
            tag_trees if tag_trees is not None else {}
        self.batch_chunks = max(1, batch_chunks)
        self.pipeline_depth = max(1, pipeline_depth)
        self.log: List[TransferReport] = []
        # adopt the transport's registry so client_* series land next to
        # transport_* ones; an explicit `metrics` overrides, a transportless
        # client gets its own
        if metrics is None:
            metrics = getattr(transport, "metrics", None)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer
        tname = transport.name if transport is not None else "none"
        self._m_pull = self.metrics.histogram(
            "client_pull_seconds", "end-to-end pull execution latency",
            ("transport",), buckets=LATENCY_BUCKETS).labels(tname)
        self._m_push = self.metrics.histogram(
            "client_push_seconds", "end-to-end push latency",
            ("transport",), buckets=LATENCY_BUCKETS).labels(tname)
        self._m_pull_chunks = self.metrics.counter(
            "client_chunks_pulled_total", "chunks moved by pulls",
            ("transport",)).labels(tname)
        self._m_pull_bytes = self.metrics.counter(
            "client_wire_bytes_total",
            "total wire bytes across pulls and pushes",
            ("transport",)).labels(tname)

    def bind(self, transport: Transport) -> "ImageClient":
        """A client over ``transport`` sharing this client's local state."""
        return ImageClient(transport, store=self.store, indexes=self.indexes,
                           tag_trees=self.tag_trees,
                           cdc_params=self.store.cdc_params,
                           cdmt_params=self.cdmt_params,
                           batch_chunks=self.batch_chunks,
                           pipeline_depth=self.pipeline_depth,
                           tracer=self.tracer)

    def _require_transport(self) -> Transport:
        if self.transport is None:
            raise DeliveryError(
                "ImageClient has no transport bound — use bind() or pass "
                "one at construction")
        return self.transport

    # ---------------------------------------------------------------- commit

    # api-boundary
    def commit(self, lineage: str, tag: str, data: bytes) -> Recipe:
        """Chunk + locally store a new artifact version, build local CDMT
        (incrementally against the lineage head when one exists)."""
        recipe = self.store.ingest(f"{lineage}:{tag}", data)
        head = self.indexes.get(lineage)
        if head is not None and head.root is not None:
            tree = CDMT.build_incremental(head, recipe.fps,
                                          params=self.cdmt_params)
        else:
            tree = CDMT.build(recipe.fps, params=self.cdmt_params)
        self.indexes[lineage] = tree
        self.tag_trees[f"{lineage}:{tag}"] = tree
        return recipe

    # api-boundary
    def index_for_tag(self, lineage: str, tag: str) -> CDMT:
        """The CDMT for a committed tag, from the per-tag cache when warm.

        A cold non-head tag is built **incrementally** against the lineage
        head (leaf sequences of adjacent versions overlap heavily), so
        repeated pushes/pulls of older tags no longer pay a full O(n)
        rebuild; the result is cached."""
        key = f"{lineage}:{tag}"
        recipe = self.store.recipes.get(key)
        if recipe is None:
            raise DeliveryError(
                f"index_for_tag: {key!r} has never been committed or "
                f"pulled on this client")
        cached = self.tag_trees.get(key)
        if cached is not None and cached.leaf_fps() == list(recipe.fps):
            return cached
        head = self.indexes.get(lineage)
        if head is not None and head.leaf_fps() == list(recipe.fps):
            tree = head
        elif head is not None and head.root is not None:
            tree = CDMT.build_incremental(head, recipe.fps,
                                          params=self.cdmt_params)
        else:
            tree = CDMT.build(recipe.fps, params=self.cdmt_params)
        self.tag_trees[key] = tree
        return tree

    # api-boundary
    def materialize(self, lineage: str, tag: str) -> bytes:
        return self.store.restore(f"{lineage}:{tag}")

    # ------------------------------------------------------------------ pull

    # api-boundary
    def plan_pull(self, lineage: str, tag: str) -> PullPlan:
        """Decide a pull without transferring a chunk (Algorithm 2 + local
        store dedup).  ``execute`` runs the resulting plan."""
        with self.tracer.span("plan_pull", lineage=lineage, tag=tag) as sp:
            plan = self._plan_pull(lineage, tag)
            sp.annotate(chunks_missing=len(plan.missing),
                        already_local=plan.already_local,
                        expected_wire_bytes=plan.expected_wire_bytes)
            return plan

    def _plan_pull(self, lineage: str, tag: str) -> PullPlan:
        transport = self._require_transport()
        index, index_bytes = transport.get_index(lineage, tag)
        recipe, recipe_bytes = transport.get_recipe(lineage, tag)
        comparisons = [0]

        def tick():
            comparisons[0] += 1

        local = self.indexes.get(lineage)
        missing: List[bytes] = []
        already_local = 0
        for fp in iter_missing_leaves(local, index, on_compare=tick):
            # global dedup: a chunk may live locally under another lineage
            if self.store.chunks.has(fp):
                already_local += 1
            else:
                missing.append(fp)
        size_of = dict(zip(recipe.fps, recipe.sizes))
        expected_chunk_bytes = sum(size_of[fp] for fp in missing)
        expected_wire = index_bytes + recipe_bytes
        if missing:
            sizes = [size_of[fp] for fp in missing]
            # the backend may split each request batch into smaller response
            # frames (RegistryServer.max_batch_chunks) — quote that exactly.
            # A transport with extra per-response cost (the socket path's
            # envelope) quotes its own batches via the hook instead.
            quote = getattr(transport, "quote_chunk_batches", None)
            sub = getattr(transport, "response_batch_chunks",
                          self.batch_chunks)
            for start in range(0, len(sizes), self.batch_chunks):
                part = sizes[start:start + self.batch_chunks]
                if quote is not None:
                    expected_wire += quote(part)
                else:
                    expected_wire += wire.chunk_batches_wire_bytes(part, sub)
        return PullPlan(lineage=lineage, tag=tag, transport=transport.name,
                        index=index, recipe=recipe, missing=missing,
                        chunks_total=len(recipe.fps),
                        already_local=already_local,
                        raw_bytes=recipe.total_size,
                        expected_chunk_bytes=expected_chunk_bytes,
                        expected_wire_bytes=expected_wire,
                        comparisons=comparisons[0],
                        index_bytes=index_bytes, recipe_bytes=recipe_bytes)

    # api-boundary
    def execute(self, plan: PullPlan) -> TransferReport:
        """Run a pull plan: stream the fetch list in pipelined batches,
        account per source, verify coverage, ingest atomically.

        Failover across sources happens inside the transport (each batch
        returns per-source legs); a fingerprint no source could serve fails
        the whole pull with :class:`DeliveryError` before anything is
        committed to the local store."""
        transport = self._require_transport()
        if transport.name != plan.transport:
            raise DeliveryError(
                f"plan was made for transport {plan.transport!r}, "
                f"executing on {transport.name!r}")
        t0 = time.perf_counter()
        report = TransferReport(op="pull", lineage=plan.lineage, tag=plan.tag,
                                transport=transport.name,
                                chunks_total=plan.chunks_total,
                                raw_bytes=plan.raw_bytes,
                                index_bytes=plan.index_bytes,
                                recipe_bytes=plan.recipe_bytes,
                                comparisons=plan.comparisons)
        received: Dict[bytes, bytes] = {}
        # re-check the store at execute time: chunks may have landed (another
        # lineage's pull) between plan and execute
        to_fetch = [fp for fp in plan.missing
                    if not self.store.chunks.has(fp)]
        with self.tracer.span("execute", lineage=plan.lineage, tag=plan.tag,
                              transport=transport.name,
                              chunks=len(to_fetch)) as exec_sp:
            # batches run on pool threads: capture the submitting thread's
            # span and attach each batch's child explicitly
            parent = self.tracer.current()

            def fetch(batch, n):
                with self.tracer.span("fetch_batch", parent=parent,
                                      batch=n, chunks=len(batch)):
                    return transport.fetch_chunks(plan.lineage, plan.tag,
                                                  batch)

            with ThreadPoolExecutor(max_workers=self.pipeline_depth) as pool:
                pending: "deque" = deque()
                for i, start in enumerate(
                        range(0, len(to_fetch), self.batch_chunks)):
                    batch = to_fetch[start:start + self.batch_chunks]
                    # bounded pipeline: never more than pipeline_depth
                    # batches in flight — drain the oldest *before*
                    # submitting the next
                    while len(pending) >= self.pipeline_depth:
                        self._drain(pending.popleft(), received, report)
                    pending.append(pool.submit(fetch, batch, i))
                while pending:
                    self._drain(pending.popleft(), received, report)

            undelivered = [fp for fp in to_fetch if fp not in received]
            if undelivered:
                raise DeliveryError(
                    f"pull {plan.lineage}:{plan.tag}: no source could serve "
                    f"{len(undelivered)} requested chunk(s) "
                    f"(first: {undelivered[0].hex()[:12]})")
            # transports hashing payloads on decode skip the 2nd hash here
            with self.tracer.span("ingest", chunks=len(received)):
                self.store.ingest_chunks(
                    f"{plan.lineage}:{plan.tag}", plan.recipe.fps, received,
                    plan.recipe.sizes,
                    verify=not transport.verifies_payloads)
            self.indexes[plan.lineage] = plan.index
            self.tag_trees[f"{plan.lineage}:{plan.tag}"] = plan.index
            transport.notify_pulled(plan.lineage, plan.tag)
            exec_sp.annotate(chunks_moved=report.chunks_moved,
                             wire_bytes=report.total_wire_bytes)
        self._m_pull.observe(time.perf_counter() - t0)
        self._m_pull_chunks.inc(report.chunks_moved)
        self._m_pull_bytes.inc(report.total_wire_bytes)
        self.log.append(report)
        return report

    @staticmethod
    def _drain(fut, received: Dict[bytes, bytes],
               report: TransferReport) -> None:
        result = fut.result()
        received.update(result.chunks)
        for leg in result.legs:
            report.merge_leg(leg)

    # api-boundary
    def pull(self, lineage: str, tag: str) -> TransferReport:
        """Plan + execute in one call (the common case)."""
        with self.tracer.span("pull", lineage=lineage, tag=tag):
            return self.execute(self.plan_pull(lineage, tag))

    # api-boundary
    def upgrade(self, lineage: str) -> TransferReport:
        """Pull the lineage head (rolling-upgrade entry point)."""
        tags = self._require_transport().tags(lineage)
        if not tags:
            raise DeliveryError(f"upgrade: unknown lineage {lineage!r}")
        return self.pull(lineage, tags[-1])

    # ------------------------------------------------------------------ push

    # api-boundary
    def push(self, lineage: str, tag: str,
             parent_version: Optional[int] = None) -> TransferReport:
        """Push a committed version: Algorithm 2 against the registry head,
        presence-check the diff, ship only chunks the backend lacks."""
        t0 = time.perf_counter()
        with self.tracer.span("push", lineage=lineage, tag=tag) as sp:
            report = self._push(lineage, tag, parent_version)
            sp.annotate(chunks_moved=report.chunks_moved,
                        wire_bytes=report.total_wire_bytes)
        self._m_push.observe(time.perf_counter() - t0)
        self._m_pull_bytes.inc(report.total_wire_bytes)
        return report

    def _push(self, lineage: str, tag: str,
              parent_version: Optional[int] = None) -> TransferReport:
        transport = self._require_transport()
        recipe = self.store.recipes.get(f"{lineage}:{tag}")
        if recipe is None:
            raise DeliveryError(
                f"push {lineage}:{tag}: version was never committed on "
                f"this client — call commit() first")
        local_idx = self.index_for_tag(lineage, tag)
        report = TransferReport(op="push", lineage=lineage, tag=tag,
                                transport=transport.name,
                                chunks_total=len(recipe.fps),
                                raw_bytes=recipe.total_size)
        remote_idx, down_bytes = transport.get_latest_index(lineage)
        report.index_bytes += down_bytes
        comparisons = [0]

        def tick():
            comparisons[0] += 1

        candidates = list(iter_missing_leaves(remote_idx, local_idx,
                                              on_compare=tick))
        report.comparisons = comparisons[0]
        if candidates:
            # the index says these changed; the presence check says which the
            # backend truly lacks (cross-lineage server-side dedup)
            to_send, has_bytes = transport.has_chunks(candidates)
            report.want_bytes += has_bytes
        else:
            to_send = []
        payload: Dict[bytes, bytes] = {}
        for fp in to_send:
            try:
                payload[fp] = self.store.chunks.get(fp)
            except KeyError:
                raise DeliveryError(
                    f"push {lineage}:{tag}: candidate chunk "
                    f"{fp.hex()[:12]} is not in the local store") from None
        outcome = transport.push(lineage, tag, recipe, payload,
                                 parent_version=parent_version,
                                 claimed_root=local_idx.root,
                                 claimed_params=self.cdmt_params)
        report.index_bytes += outcome.header_bytes
        report.recipe_bytes = outcome.recipe_bytes
        report.chunks_moved = len(payload)
        report.rounds = outcome.rounds
        leg = report.leg("registry")
        leg.chunks += len(payload)
        leg.chunk_bytes += outcome.chunk_bytes
        leg.rounds += outcome.rounds
        report.chunk_bytes += outcome.chunk_bytes
        self.log.append(report)
        return report
