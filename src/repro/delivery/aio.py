"""Async data plane — an event-loop registry front door plus a
multiplexing transport that survive 1k+ concurrent pullers.

The threaded :class:`~repro.delivery.net.SocketRegistryServer` spends one
thread per connection, which caps the delivery stack at a few hundred
clients.  This module is the scale seam:

  * :class:`AsyncRegistryServer` — a non-blocking TCP front door over the
    same thread-safe :class:`~repro.delivery.server.RegistryServer`
    handlers.  One asyncio event loop owns every connection; handler work
    (store reads, CDMT verification, journal commits) runs on a bounded
    worker pool of **O(cores)** threads, so ten thousand idle connections
    cost file descriptors, not stacks.  The wire protocol is the
    **multiplexed envelope** (``wire.encode_mux_request`` /
    ``encode_mux_response_*``): every request carries a stream id, every
    response message routes by it, so any number of request/response
    streams interleave over one connection.
  * **Fair scheduling** — a streamed WANT answer is produced one
    CHUNK_BATCH at a time, each batch a separate worker-pool job, and the
    per-connection writer lock is released between messages.  A
    thousand-chunk pull therefore shares the pool and the socket at frame
    granularity with everything else; one huge pull cannot starve a
    thousand small ones.
  * **Backpressure + admission control** — a connection may hold at most
    ``max_stream_inflight`` streams; past that the server stops *reading*
    it (TCP pushes back on the client, no unbounded buffering).  Globally,
    past ``max_inflight`` admitted requests the server **sheds**: the
    request is answered immediately with a typed ``ErrorCode.BUSY`` ERROR
    frame instead of stalling accepts, and ``async_shed_total`` counts it.
  * :class:`MuxSocketTransport` — a conforming
    :class:`~repro.delivery.transport.Transport` that multiplexes every
    exchange over a small set of shared connections (one reader thread per
    connection, not per request).  ``ImageClient.execute``'s pipelined
    batches interleave on the same sockets; byte accounting is exact
    socket bytes and ``quote_chunk_batches`` quotes the mux envelope to
    the byte, so plan == execute, same as the threaded transport.

Concurrency contract
    ``AsyncRegistryServer``'s connection and stream state is touched only
    from the event-loop thread (the one lock, ``_lifecycle_lock``, makes
    ``stop()`` idempotent across caller threads).  Handlers run on the
    worker pool and are thread-safe by the wrapped ``RegistryServer``'s
    contract; frames of one stream are produced serially, so the
    ``want_plan`` generator is never entered concurrently.
    ``MuxSocketTransport`` is thread-safe: any number of caller threads
    open streams concurrently; per-connection stream tables are guarded by
    the connection's lock and each stream hands its messages to exactly
    one waiting caller through its own queue.

Crash-recovery contract
    Identical to the threaded server: the front door owns no durable
    state.  Killing the process costs at most the in-flight requests —
    every client sees a dead connection and raises ``DeliveryError`` with
    nothing committed to its local store.
"""

from __future__ import annotations

import asyncio
import contextlib
import dataclasses
import os
import queue
import socket
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cdmt import CDMT, CDMTParams
from repro.core.errors import DeliveryError
from repro.core.registry import PushRejected, Registry
from repro.core.store import Recipe
from repro.obs import MetricsRegistry, MetricsSnapshot

from . import wire
from .net import (DEFAULT_TIMEOUT, _ConnectionClosed, _read_exact,
                  _read_frame, _read_uvarint, dispatch_request)
from .plan import SourceLeg
from .server import RegistryServer
from .transport import (REGISTRY_SOURCE, FetchResult, PushOutcome,
                        TransportMeter)

__all__ = ["AsyncRegistryServer", "AsyncServerStats", "MuxSocketTransport",
           "serve_registry_async"]

_DONE = object()          # sentinel: the want_plan frame iterator is spent


# ---------------------------------------------------------------- server


@dataclasses.dataclass
class AsyncServerStats:
    """Adapter view over the ``async_*`` metric series (same shape as the
    threaded server's :class:`~repro.delivery.net.SocketServerStats`, plus
    the load-shed counter)."""
    connections: int = 0
    requests: int = 0
    errors: int = 0                # streams answered with an ERROR frame
    sheds: int = 0                 # requests refused by admission control
    ingress_bytes: int = 0         # request envelopes read off sockets
    egress_bytes: int = 0          # response messages written to sockets

    def snapshot(self) -> "AsyncServerStats":
        return dataclasses.replace(self)


class _AioConn:
    """Per-connection event-loop state — touched only on the loop thread."""

    __slots__ = ("reader", "writer", "wlock", "sem", "tasks")

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter, max_stream_inflight: int):
        self.reader = reader
        self.writer = writer
        # writer lock: released between messages, so concurrent streams
        # interleave on the socket at message granularity (the fairness
        # point of the mux framing)
        self.wlock = asyncio.Lock()
        # per-client backpressure: past this many in-flight streams the
        # read loop stops consuming the connection and TCP pushes back
        self.sem = asyncio.Semaphore(max_stream_inflight)
        self.tasks: set = set()


class AsyncRegistryServer:
    """Event-loop TCP front door over a :class:`RegistryServer`.

    Speaks the **multiplexed** envelope protocol (stream-id routed — see
    ``docs/WIRE_PROTOCOL.md``); the threaded
    :class:`~repro.delivery.net.SocketRegistryServer` remains the
    compatibility backend for plain-envelope clients.  ``port=0`` binds an
    ephemeral port; read ``address`` after construction.  The loop runs in
    one dedicated thread and handler work on ``workers`` pool threads
    (default ``os.cpu_count()``) — connection count never adds threads.

    ``idle_timeout`` (seconds, ``None`` = never) reaps connections that
    idle *between* requests, closing the unbounded-idle window pooled
    clients used to rely on; a well-behaved client redials transparently.
    """

    def __init__(self, server: RegistryServer, host: str = "127.0.0.1",
                 port: int = 0, backlog: int = 1024,
                 workers: Optional[int] = None,
                 max_inflight: int = 1024,
                 max_stream_inflight: int = 64,
                 idle_timeout: Optional[float] = None,
                 io_timeout: float = DEFAULT_TIMEOUT):
        self.server = server
        self.workers = workers if workers is not None \
            else max(2, os.cpu_count() or 2)
        self.max_inflight = max_inflight
        self.max_stream_inflight = max(1, max_stream_inflight)
        self.idle_timeout = idle_timeout
        self.io_timeout = io_timeout
        self.metrics = server.metrics
        m = self.metrics
        self._m_connections = m.counter(
            "async_connections_total", "TCP connections accepted").labels()
        self._m_open = m.gauge(
            "async_open_connections", "currently open connections").labels()
        self._m_requests = m.counter(
            "async_requests_total", "mux request envelopes read").labels()
        self._m_errors = m.counter(
            "async_errors_total",
            "streams answered with an ERROR frame").labels()
        self._m_shed = m.counter(
            "async_shed_total",
            "requests refused by admission control (BUSY)").labels()
        self._m_reaped = m.counter(
            "async_idle_reaped_total",
            "connections closed by the idle reaper").labels()
        self._m_ingress = m.counter(
            "async_ingress_bytes_total",
            "request envelope bytes read off sockets").labels()
        self._m_egress = m.counter(
            "async_egress_bytes_total",
            "response message bytes written to sockets").labels()
        self._m_inflight = m.gauge(
            "async_inflight_requests",
            "admitted requests not yet fully answered").labels()
        self._m_queue = m.gauge(
            "async_queue_depth",
            "handler jobs queued for a worker-pool thread").labels()
        lat = m.histogram(
            "async_request_seconds",
            "admission-to-last-byte stream latency (queueing included)",
            ("op",))
        self._m_lat = {op: lat.labels(op.name.lower()) for op in wire.Op}
        self._inflight = 0  # guarded-by: external(event-loop thread)
        self._conns: set = set()  # guarded-by: external(event-loop thread)
        self._stopped = False  # guarded-by: _lifecycle_lock
        self._lifecycle_lock = threading.Lock()
        self._pool = ThreadPoolExecutor(
            max_workers=self.workers, thread_name_prefix="async-registry")
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(target=self._run_loop,
                                        name="async-registry-loop",
                                        daemon=True)
        self._thread.start()
        fut = asyncio.run_coroutine_threadsafe(
            self._start(host, port, backlog), self._loop)
        self.address: Tuple[str, int] = fut.result(timeout=10)

    # ------------------------------------------------------------ lifecycle

    def _run_loop(self) -> None:
        asyncio.set_event_loop(self._loop)
        self._loop.run_forever()

    async def _start(self, host: str, port: int, backlog: int
                     ) -> Tuple[str, int]:
        self._aserver = await asyncio.start_server(
            self._serve_conn, host, port, backlog=backlog)
        return self._aserver.sockets[0].getsockname()[:2]

    def __enter__(self) -> "AsyncRegistryServer":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def stop(self) -> None:
        with self._lifecycle_lock:
            if self._stopped:
                return
            self._stopped = True
        fut = asyncio.run_coroutine_threadsafe(self._shutdown(), self._loop)
        with contextlib.suppress(Exception):
            fut.result(timeout=10)
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(timeout=10)
        self._pool.shutdown(wait=True, cancel_futures=True)
        if not self._thread.is_alive():
            self._loop.close()

    async def _shutdown(self) -> None:
        self._aserver.close()
        await self._aserver.wait_closed()
        for conn in list(self._conns):
            for t in list(conn.tasks):
                t.cancel()
            conn.writer.close()

    @property
    def thread_count(self) -> int:
        """Threads this front door owns: the loop plus the worker pool —
        O(cores), independent of connection count (the scale claim the
        benchmark pins)."""
        return 1 + self.workers

    @property
    def stats(self) -> AsyncServerStats:
        return AsyncServerStats(
            connections=self._m_connections.value(),
            requests=self._m_requests.value(),
            errors=self._m_errors.value(),
            sheds=self._m_shed.value(),
            ingress_bytes=self._m_ingress.value(),
            egress_bytes=self._m_egress.value())

    def snapshot(self) -> AsyncServerStats:
        return self.stats

    # ----------------------------------------------------------- connection

    async def _serve_conn(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        sock = writer.get_extra_info("socket")
        if sock is not None:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        conn = _AioConn(reader, writer, self.max_stream_inflight)
        self._conns.add(conn)
        self._m_connections.inc()
        self._m_open.inc()
        try:
            while True:
                req = await self._read_request(reader)
                if req is None:
                    break                    # clean EOF / idle reaped
                op, sid, lineage, tag, frames, nbytes = req
                self._m_requests.inc()
                self._m_ingress.inc(nbytes)
                if self._inflight >= self.max_inflight:
                    # admission control: answer, don't stall the accept or
                    # read path — the client sees a typed, retryable error
                    self._m_shed.inc()
                    self._m_errors.inc()
                    await self._send_error(
                        conn, sid, wire.ErrorCode.BUSY,
                        f"server busy: {self._inflight} requests in "
                        f"flight (limit {self.max_inflight}) — retry")
                    continue
                await conn.sem.acquire()     # per-client backpressure
                task = self._loop.create_task(
                    self._answer(conn, sid, op, lineage, tag, frames))
                conn.tasks.add(task)
                task.add_done_callback(
                    lambda t, c=conn: self._stream_done(c, t))
        except _ConnectionClosed:
            pass                             # peer vanished mid-request
        except wire.WireError:
            # malformed envelope: the stream offset is unknowable, so the
            # only honest signal is a close (mux has no "current stream"
            # to attach an ERROR frame to)
            self._m_errors.inc()
        finally:
            for t in list(conn.tasks):
                t.cancel()
            with contextlib.suppress(OSError):
                conn.writer.close()
            self._conns.discard(conn)
            self._m_open.dec()

    def _stream_done(self, conn: _AioConn, task: "asyncio.Task") -> None:
        conn.tasks.discard(task)
        conn.sem.release()
        if task.cancelled():
            return
        if task.exception() is not None:
            # failure after a stream header was committed: close the
            # connection — every client stream on it fails loudly
            conn.writer.close()

    async def _read_request(self, reader: asyncio.StreamReader
                            ) -> Optional[Tuple[wire.Op, int, str, str,
                                                List[bytes], int]]:
        """One mux request envelope, or None on clean EOF / idle reap.
        The wait for the first byte honors ``idle_timeout``; once a
        request starts, the rest must arrive within ``io_timeout``."""
        try:
            if self.idle_timeout is not None:
                first = await asyncio.wait_for(reader.readexactly(1),
                                               self.idle_timeout)
            else:
                first = await reader.readexactly(1)
        except asyncio.IncompleteReadError:
            return None
        except asyncio.TimeoutError:
            self._m_reaped.inc()
            return None
        try:
            return await asyncio.wait_for(
                self._read_request_body(reader, first), self.io_timeout)
        except asyncio.IncompleteReadError as e:
            raise _ConnectionClosed(str(e)) from e
        except asyncio.TimeoutError as e:
            raise _ConnectionClosed("mid-request timeout") from e

    async def _read_request_body(self, reader: asyncio.StreamReader,
                                 first: bytes
                                 ) -> Tuple[wire.Op, int, str, str,
                                            List[bytes], int]:
        hdr = first + await reader.readexactly(7)
        nbytes = 8
        op, sid = wire.check_mux_request_header(hdr)
        lineage, nb = await self._aread_str(reader)
        nbytes += nb
        tag, nb = await self._aread_str(reader)
        nbytes += nb
        n_frames, nb = await self._aread_uvarint(reader)
        nbytes += nb
        if n_frames > wire.MAX_ENVELOPE_FRAMES:
            raise wire.WireError(f"request carries {n_frames} frames, "
                                 f"limit {wire.MAX_ENVELOPE_FRAMES}")
        frames: List[bytes] = []
        for _ in range(n_frames):
            size, nb = await self._aread_uvarint(reader)
            if size > wire.MAX_FRAME_BYTES:
                raise wire.WireError(f"frame of {size} bytes exceeds "
                                     f"{wire.MAX_FRAME_BYTES}")
            frames.append(await reader.readexactly(size))
            nbytes += nb + size
        return op, sid, lineage, tag, frames, nbytes

    @staticmethod
    async def _aread_uvarint(reader: asyncio.StreamReader
                             ) -> Tuple[int, int]:
        result = 0
        shift = 0
        for i in range(10):
            b = (await reader.readexactly(1))[0]
            result |= (b & 0x7F) << shift
            if not b & 0x80:
                return result, i + 1
            shift += 7
        raise wire.WireError("uvarint too long (>10 bytes)")

    async def _aread_str(self, reader: asyncio.StreamReader
                         ) -> Tuple[str, int]:
        n, nb = await self._aread_uvarint(reader)
        if n > wire.MAX_ROUTING_BYTES:
            raise wire.WireError(f"routing string of {n} bytes exceeds "
                                 f"{wire.MAX_ROUTING_BYTES}")
        return (await reader.readexactly(n)).decode("utf-8"), nb + n

    # -------------------------------------------------------------- answer

    async def _send(self, conn: _AioConn, data: bytes) -> None:
        async with conn.wlock:
            conn.writer.write(data)
            await conn.writer.drain()        # socket backpressure honored
        self._m_egress.inc(len(data))

    async def _send_error(self, conn: _AioConn, sid: int,
                          code: wire.ErrorCode, msg: str) -> None:
        await self._send(conn, wire.encode_mux_response_header(
            sid, wire.STATUS_ERROR, 1))
        await self._send(conn, wire.encode_mux_response_frame(
            sid, wire.encode_error(code, msg)))

    async def _run(self, fn, *args):
        """One handler job on the worker pool; the queue-depth gauge
        counts jobs submitted but not yet started."""
        self._m_queue.inc()

        def job():
            self._m_queue.dec()
            return fn(*args)

        return await self._loop.run_in_executor(self._pool, job)

    async def _answer(self, conn: _AioConn, sid: int, op: wire.Op,
                      lineage: str, tag: str, frames: List[bytes]) -> None:
        self._inflight += 1
        self._m_inflight.inc()
        t0 = time.perf_counter()
        streamed = False
        try:
            if op in (wire.Op.WANT, wire.Op.SNAPSHOT_SHIP):
                if len(frames) != 1:
                    raise wire.WireError(
                        f"{op.name} request carries {len(frames)} body "
                        f"frame(s), expected 1")
                plan = (self.server.want_plan if op is wire.Op.WANT
                        else self.server.snapshot_plan)
                n, frame_iter = await self._run(plan, frames[0])
                await self._send(conn, wire.encode_mux_response_header(
                    sid, wire.STATUS_OK, n))
                streamed = True              # header out: count committed
                try:
                    while True:
                        # one frame per pool job: a huge WANT (or snapshot
                        # bootstrap) shares the workers — and the socket —
                        # at frame granularity
                        f = await self._run(next, frame_iter, _DONE)
                        if f is _DONE:
                            break
                        await self._send(
                            conn, wire.encode_mux_response_frame(sid, f))
                finally:
                    with contextlib.suppress(Exception):
                        frame_iter.close()
            else:
                out = await self._run(dispatch_request, self.server, op,
                                      lineage, tag, frames)
                await self._send(conn, wire.encode_mux_response_header(
                    sid, wire.STATUS_OK, len(out)))
                for f in out:
                    await self._send(
                        conn, wire.encode_mux_response_frame(sid, f))
        except asyncio.CancelledError:
            raise
        except Exception as e:
            if streamed or isinstance(e, (OSError, _ConnectionClosed)):
                # the frame count is committed (or the socket is gone):
                # any "error frame" now would decode as stream data.
                # Close — every client stream on this conn fails loudly.
                raise _ConnectionClosed(str(e)) from e
            code = (wire.ErrorCode.PUSH_REJECTED
                    if isinstance(e, PushRejected)
                    else wire.ErrorCode.WIRE if isinstance(e, wire.WireError)
                    else wire.ErrorCode.DELIVERY
                    if isinstance(e, DeliveryError)
                    else wire.ErrorCode.INTERNAL)
            self._m_errors.inc()
            await self._send_error(conn, sid, code,
                                   str(e) or type(e).__name__)
        finally:
            self._inflight -= 1
            self._m_inflight.dec()
            self._m_lat[op].observe(time.perf_counter() - t0)


# -------------------------------------------------------------- transport


class _Stream:
    """One in-flight client stream: the reader thread feeds messages in,
    exactly one caller thread consumes them."""

    __slots__ = ("q",)

    def __init__(self):
        self.q: "queue.Queue" = queue.Queue()


class _StaleStream(Exception):
    """The connection died before this stream's header arrived — the
    server never answered (an idle-reaped or freshly dead shared socket),
    so the exchange is safe to retry once on a new connection."""


class _MuxConn:
    """One shared client connection: a socket, a demultiplexing reader
    thread, and the stream table it routes into."""

    def __init__(self, address: Tuple[str, int], timeout: float):
        self.sock = socket.create_connection(address, timeout=timeout)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.sock.settimeout(None)   # liveness is enforced per-stream
        self.rfile = self.sock.makefile("rb")
        self._lock = threading.Lock()
        self._send_lock = threading.Lock()
        self._streams: Dict[int, _Stream] = {}  # guarded-by: _lock
        self._next_id = 1  # guarded-by: _lock
        self._dead = False  # guarded-by: _lock
        self._reader = threading.Thread(target=self._read_loop,
                                        name="mux-transport-read",
                                        daemon=True)
        self._reader.start()

    # ------------------------------------------------------------- streams

    def register(self) -> Tuple[int, _Stream]:
        """Allocate a stream id and its message queue."""
        with self._lock:
            if self._dead:
                raise _ConnectionClosed("mux connection is dead")
            while self._next_id in self._streams:
                self._next_id = (self._next_id % wire.MAX_STREAM_ID) + 1
            sid = self._next_id
            self._next_id = (self._next_id % wire.MAX_STREAM_ID) + 1
            st = _Stream()
            self._streams[sid] = st
            return sid, st

    def unregister(self, sid: int) -> None:
        with self._lock:
            self._streams.pop(sid, None)

    def n_streams(self) -> int:
        with self._lock:
            return len(self._streams)

    def is_dead(self) -> bool:
        with self._lock:
            return self._dead

    def send(self, data: bytes) -> None:
        with self._send_lock:
            self.sock.sendall(data)

    # -------------------------------------------------------------- reader

    def _read_loop(self) -> None:
        try:
            while True:
                hdr = _read_exact(self.rfile, 8)
                msg_type, sid = wire.check_mux_response_header(hdr)
                if msg_type == wire.MUX_HEADER:
                    status = _read_exact(self.rfile, 1)[0]
                    if status not in (wire.STATUS_OK, wire.STATUS_ERROR):
                        raise wire.WireError(
                            f"unknown response status {status}")
                    n, nb = _read_uvarint(self.rfile)
                    item = ("hdr", status, n, 9 + nb)
                else:
                    f, nb = _read_frame(self.rfile)
                    item = ("frame", f, None, 8 + nb)
                with self._lock:
                    st = self._streams.get(sid)
                if st is not None:
                    st.q.put(item)
                # unknown id: the stream timed out and unregistered — drop
        except (_ConnectionClosed, OSError, wire.WireError) as e:
            self._fail(e)

    def _fail(self, exc: BaseException) -> None:
        """Mark dead and wake every waiting stream with the failure."""
        with self._lock:
            self._dead = True
            waiting = list(self._streams.values())
            self._streams.clear()
        for st in waiting:
            st.q.put(("err", exc, None, 0))
        self.close(join_reader=False)

    def close(self, join_reader: bool = True) -> None:
        with contextlib.suppress(OSError):
            self.sock.shutdown(socket.SHUT_RDWR)
        with contextlib.suppress(OSError):
            self.rfile.close()
        with contextlib.suppress(OSError):
            self.sock.close()
        if join_reader and self._reader is not threading.current_thread():
            self._reader.join(timeout=5)


class MuxSocketTransport:
    """:class:`Transport` over multiplexed TCP to an
    :class:`AsyncRegistryServer`.

    All exchanges share at most ``connections`` sockets; concurrent
    callers (``ImageClient.execute``'s pipelined batches, or a thousand
    pullers handed the same transport) interleave their streams on them.
    Byte accounting mirrors the threaded transport — request envelopes as
    control/want traffic, the full mux response (HEADER + FRAME messages)
    as the matching response category — and ``quote_chunk_batches`` makes
    a pull plan's quote byte-exact, stream ids being fixed-width.

    A stream whose connection dies *before its header arrived* was never
    answered (typically an idle-reaped shared socket); it is retried once
    on a fresh connection instead of surfacing ``DeliveryError``.
    """

    name = "mux"
    verifies_payloads = True       # decode_chunk_batch hashes every payload

    def __init__(self, address: Tuple[str, int], batch_chunks: int = 64,
                 timeout: float = DEFAULT_TIMEOUT, connections: int = 4,
                 metrics: Optional[MetricsRegistry] = None):
        self.address = (address[0], int(address[1]))
        self.batch_chunks = max(1, batch_chunks)
        self.timeout = timeout
        self.max_connections = max(1, connections)
        self._conns: List[_MuxConn] = []  # guarded-by: _lock
        self._closed = False  # guarded-by: _lock
        self._lock = threading.Lock()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._meter = TransportMeter(self.metrics, self.name)
        self._m_conns = self.metrics.gauge(
            "transport_pool_connections",
            "open shared/pooled connections", ("transport",)
        ).labels(self.name)
        self._m_streams = self.metrics.gauge(
            "transport_open_streams",
            "mux streams currently in flight", ("transport",)
        ).labels(self.name)
        # one unmetered INFO exchange: the server's response split, so
        # pull plans quote the streamed CHUNK_BATCH framing exactly
        _, frames, _ = self._exchange(wire.Op.INFO, "", "")
        self.response_batch_chunks = wire.decode_info(frames[0])

    # ------------------------------------------------------------ lifecycle

    def close(self) -> None:
        with self._lock:
            self._closed = True
            conns, self._conns = self._conns, []
        for c in conns:
            c.close()
        self._m_conns.set(0)

    def __enter__(self) -> "MuxSocketTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---------------------------------------------------------- connections

    def _lease_conn(self) -> _MuxConn:
        """The live shared connection with the fewest in-flight streams,
        dialing a new one while under the ``connections`` cap."""
        with self._lock:
            if self._closed:
                raise DeliveryError("mux transport is closed")
            self._conns = [c for c in self._conns if not c.is_dead()]
            live = self._conns
            if live:
                conn = min(live, key=_MuxConn.n_streams)
                # reuse when idle or at the cap; dial only when every
                # open connection is busy and there is room to grow
                if conn.n_streams() == 0 or len(live) >= self.max_connections:
                    return conn
            n_live = len(live)
        self._m_conns.set(n_live)        # dead ones just dropped
        try:
            conn = _MuxConn(self.address, self.timeout)
        except OSError as e:
            raise DeliveryError(
                f"mux transport: cannot connect to "
                f"{self.address[0]}:{self.address[1]} ({e})") from e
        surplus: Optional[_MuxConn] = None
        with self._lock:
            if self._closed:
                surplus, conn = conn, None
            elif len(self._conns) >= self.max_connections:
                # lost a dial race: someone else filled the last slot —
                # fold back onto the least-loaded existing connection
                surplus, conn = conn, min(self._conns,
                                          key=_MuxConn.n_streams)
            else:
                self._conns.append(conn)
            n = len(self._conns)
        if surplus is not None:
            surplus.close()
        self._m_conns.set(n)
        if conn is None:
            raise DeliveryError("mux transport is closed")
        return conn

    def _discard(self, conn: _MuxConn) -> None:
        with self._lock:
            if conn in self._conns:
                self._conns.remove(conn)
            n = len(self._conns)
        conn.close()
        self._m_conns.set(n)

    # -------------------------------------------------------------- streams

    def _begin(self, op: wire.Op, lineage: str, tag: str,
               frames: Sequence[bytes]
               ) -> Tuple[_MuxConn, int, _Stream, int]:
        """Open a stream: lease a connection, register an id, send the
        request.  A connection that turns out dead at send time is
        discarded and the send retried on a fresh one."""
        last: Optional[BaseException] = None
        for _ in range(2):
            conn = self._lease_conn()
            try:
                sid, st = conn.register()
            except _ConnectionClosed as e:
                last = e
                self._discard(conn)
                continue
            req = wire.encode_mux_request(op, sid, lineage, tag, frames)
            try:
                conn.send(req)
            except OSError as e:
                last = e
                conn.unregister(sid)
                self._discard(conn)
                continue
            self._m_streams.inc()
            return conn, sid, st, len(req)
        raise DeliveryError(
            f"mux transport: {op.name} to {self.address[0]}:"
            f"{self.address[1]}: cannot open a stream ({last})") from last

    def _finish(self, conn: _MuxConn, sid: int) -> None:
        conn.unregister(sid)
        self._m_streams.dec()

    def _next_item(self, op: wire.Op, st: _Stream, *,
                   header_pending: bool) -> Tuple[str, object, object, int]:
        """One message off the stream queue; transport failures surface as
        typed exceptions (:class:`_StaleStream` only while the header is
        still pending — the safe-to-retry window)."""
        try:
            kind, a, b, nbytes = st.q.get(timeout=self.timeout)
        except queue.Empty:
            raise DeliveryError(
                f"mux transport: {op.name} to {self.address[0]}:"
                f"{self.address[1]}: timed out after {self.timeout}s"
            ) from None
        if kind == "err":
            if isinstance(a, wire.WireError):
                raise wire.WireError(str(a))
            if header_pending:
                raise _StaleStream(str(a))
            raise DeliveryError(
                f"mux transport: {op.name} to {self.address[0]}:"
                f"{self.address[1]}: connection lost mid-stream ({a})")
        return kind, a, b, nbytes

    def _await_header(self, op: wire.Op, st: _Stream) -> Tuple[int, int, int]:
        kind, status, n, nbytes = self._next_item(op, st,
                                                  header_pending=True)
        if kind != "hdr":
            raise wire.WireError(f"mux stream began with a {kind} message, "
                                 f"expected its header")
        return status, n, nbytes

    def _await_frame(self, op: wire.Op, st: _Stream) -> Tuple[bytes, int]:
        kind, frame, _, nbytes = self._next_item(op, st,
                                                 header_pending=False)
        if kind != "frame":
            raise wire.WireError(f"mux stream carried a second header")
        return frame, nbytes

    # ------------------------------------------------------------- exchange

    def _exchange(self, op: wire.Op, lineage: str, tag: str,
                  frames: Sequence[bytes] = ()
                  ) -> Tuple[int, List[bytes], int]:
        """One multiplexed round-trip: ``(request_bytes, response_frames,
        response_bytes)``, retried once if the shared connection proved
        stale before the server answered."""
        try:
            return self._exchange_once(op, lineage, tag, frames)
        except _StaleStream:
            pass
        try:
            return self._exchange_once(op, lineage, tag, frames)
        except _StaleStream as e:
            raise DeliveryError(
                f"mux transport: {op.name} to {self.address[0]}:"
                f"{self.address[1]}: connection lost ({e})") from e

    def _exchange_once(self, op: wire.Op, lineage: str, tag: str,
                       frames: Sequence[bytes]
                       ) -> Tuple[int, List[bytes], int]:
        conn, sid, st, req_len = self._begin(op, lineage, tag, frames)
        try:
            status, n, resp_bytes = self._await_header(op, st)
            out: List[bytes] = []
            for _ in range(n):
                f, nb = self._await_frame(op, st)
                resp_bytes += nb
                out.append(f)
        finally:
            self._finish(conn, sid)
        if status == wire.STATUS_ERROR:
            self._raise_remote(out)
        return req_len, out, resp_bytes

    @staticmethod
    def _raise_remote(frames: Sequence[bytes]) -> None:
        if not frames:
            raise DeliveryError("remote error with no ERROR frame")
        code, msg = wire.decode_error(frames[0])
        if code is wire.ErrorCode.PUSH_REJECTED:
            raise PushRejected(msg)
        if code is wire.ErrorCode.WIRE:
            raise wire.WireError(msg)
        if code is wire.ErrorCode.BUSY:
            raise DeliveryError(f"server busy (load shed): {msg}")
        raise DeliveryError(msg)

    # ------------------------------------------------------------ transport

    # api-boundary
    def get_index(self, lineage: str, tag: str) -> Tuple[CDMT, int]:
        t0 = time.perf_counter()
        req_b, frames, resp_b = self._exchange(wire.Op.INDEX, lineage, tag)
        self._meter.rec("index", t0, index=req_b + resp_b)
        return wire.decode_index(frames[0]), req_b + resp_b

    # api-boundary
    def get_latest_index(self, lineage: str) -> Tuple[Optional[CDMT], int]:
        t0 = time.perf_counter()
        req_b, frames, resp_b = self._exchange(wire.Op.LATEST_INDEX,
                                               lineage, "")
        self._meter.rec("index", t0, index=req_b + resp_b)
        if not frames:
            return None, req_b + resp_b
        return wire.decode_index(frames[0]), req_b + resp_b

    # api-boundary
    def get_recipe(self, lineage: str, tag: str) -> Tuple[Recipe, int]:
        t0 = time.perf_counter()
        req_b, frames, resp_b = self._exchange(wire.Op.RECIPE, lineage, tag)
        self._meter.rec("recipe", t0, recipe=req_b + resp_b)
        return wire.decode_recipe(frames[0]), req_b + resp_b

    # api-boundary
    def fetch_chunks(self, lineage: str, tag: str,
                     fps: Sequence[bytes]) -> FetchResult:
        """One WANT stream; CHUNK_BATCH frames are decoded as the reader
        thread delivers them, so the hash-verify of one batch overlaps the
        socket reads of the next — and of every other in-flight stream."""
        t0 = time.perf_counter()
        want = wire.encode_want(fps)
        try:
            chunks, req_b, resp_b, error_frames = \
                self._fetch_once(lineage, tag, want)
        except _StaleStream:
            try:
                chunks, req_b, resp_b, error_frames = \
                    self._fetch_once(lineage, tag, want)
            except _StaleStream as e:
                raise DeliveryError(
                    f"mux transport: WANT to {self.address[0]}:"
                    f"{self.address[1]}: connection lost ({e})") from e
        if error_frames is not None:
            self._raise_remote(error_frames)
        leg = SourceLeg(source=REGISTRY_SOURCE, chunks=len(chunks),
                        chunk_bytes=resp_b, want_bytes=req_b, rounds=1)
        self._meter.rec_legs(t0, [leg])
        return FetchResult(chunks=chunks, legs=[leg])

    def _fetch_once(self, lineage: str, tag: str, want: bytes
                    ) -> Tuple[Dict[bytes, bytes], int, int,
                               Optional[List[bytes]]]:
        conn, sid, st, req_len = self._begin(wire.Op.WANT, lineage, tag,
                                             [want])
        chunks: Dict[bytes, bytes] = {}
        error_frames: Optional[List[bytes]] = None
        try:
            status, n, resp_bytes = self._await_header(wire.Op.WANT, st)
            if status == wire.STATUS_ERROR:
                error_frames = []
            for _ in range(n):
                f, nb = self._await_frame(wire.Op.WANT, st)
                resp_bytes += nb
                if error_frames is not None:
                    error_frames.append(f)
                else:
                    chunks.update(wire.decode_chunk_batch(f))
        finally:
            self._finish(conn, sid)
        return chunks, req_len, resp_bytes, error_frames

    # api-boundary
    def push(self, lineage: str, tag: str, recipe: Recipe,
             chunks: Dict[bytes, bytes], *,
             parent_version: Optional[int] = None,
             claimed_root: Optional[bytes] = None,
             claimed_params: Optional[CDMTParams] = None) -> PushOutcome:
        t0 = time.perf_counter()
        hdr = wire.encode_push_header(wire.PushHeader(
            lineage=lineage, tag=tag, root=claimed_root,
            parent_version=parent_version, params=claimed_params))
        recipe_frame = wire.encode_recipe(recipe)
        chunk_frames: List[bytes] = []
        fps = list(chunks)
        for start in range(0, len(fps), self.batch_chunks):
            part = {fp: chunks[fp]
                    for fp in fps[start:start + self.batch_chunks]}
            chunk_frames.append(wire.encode_chunk_batch(part))
        req_b, frames, resp_b = self._exchange(
            wire.Op.PUSH, lineage, tag, [hdr, recipe_frame] + chunk_frames)
        receipt = wire.decode_receipt(frames[0])
        # byte split matches the threaded transport: each body frame owns
        # its envelope length prefix; everything else rides header_bytes
        recipe_share = wire.uvarint_len(len(recipe_frame)) + len(recipe_frame)
        chunk_share = sum(wire.uvarint_len(len(f)) + len(f)
                          for f in chunk_frames)
        outcome = PushOutcome(
            receipt=receipt,
            header_bytes=req_b - recipe_share - chunk_share + resp_b,
            recipe_bytes=recipe_share,
            chunk_bytes=chunk_share,
            rounds=1 if chunks else 0)
        self._meter.rec("push", t0, index=outcome.header_bytes,
                        recipe=outcome.recipe_bytes,
                        chunk=outcome.chunk_bytes)
        return outcome

    # api-boundary
    def has_chunks(self, fps: Sequence[bytes]) -> Tuple[List[bytes], int]:
        t0 = time.perf_counter()
        req_b, frames, resp_b = self._exchange(wire.Op.HAS, "", "",
                                               [wire.encode_has(fps)])
        self._meter.rec("has", t0, want=req_b + resp_b)
        return wire.decode_missing(frames[0]), req_b + resp_b

    # api-boundary
    def tags(self, lineage: str) -> List[str]:
        t0 = time.perf_counter()
        _, frames, _ = self._exchange(wire.Op.TAGS, lineage, "",
                                      [wire.encode_tags_request(lineage)])
        self._meter.rec("tags", t0)
        return wire.decode_tag_list(frames[0])

    # api-boundary
    def notify_pulled(self, lineage: str, tag: str) -> None:
        pass

    # ------------------------------------------------------------- scraping

    def scrape_metrics(self) -> MetricsSnapshot:
        """One ``Op.METRICS`` exchange, unmetered (like the threaded
        transport) so ``transport_bytes_total`` stays report-exact."""
        _, frames, _ = self._exchange(wire.Op.METRICS, "", "")
        payload = wire.decode_metrics(frames[0])
        return MetricsSnapshot.from_json(payload.decode("utf-8"))

    # ---------------------------------------------------------- replication

    def ship_journal(self, replica: str, epoch: int, start: int,
                     limit: int = 512
                     ) -> Tuple[int, int, List[Tuple[int, bytes, bytes]]]:
        """One JOURNAL_SHIP exchange — same contract as the threaded
        transport's (checksum-verified records, nothing half-verified)."""
        _, frames, _ = self._exchange(
            wire.Op.JOURNAL_SHIP, "", "",
            [wire.encode_ship(replica, epoch, start, limit)])
        _, srv_epoch, head = wire.decode_repl_ack(frames[0])
        records = [wire.decode_record_frame(f) for f in frames[1:]]
        return srv_epoch, head, records

    def ack_journal(self, replica: str, epoch: int,
                    offset: int) -> Tuple[int, int]:
        _, frames, _ = self._exchange(
            wire.Op.REPL_ACK, "", "",
            [wire.encode_repl_ack(replica, epoch, offset)])
        _, srv_epoch, head = wire.decode_repl_ack(frames[0])
        return srv_epoch, head

    def replication_status(self) -> Tuple[int, int]:
        epoch, head, _ = self.ship_journal("", 0, 0, 0)
        return epoch, head

    def fetch_snapshot(self, replica: str = "standby"
                       ) -> Tuple[int, int, List[Tuple[int, bytes, bytes]]]:
        """One SNAPSHOT_SHIP exchange: the compacted state snapshot,
        streamed through the mux like a WANT response."""
        _, frames, _ = self._exchange(
            wire.Op.SNAPSHOT_SHIP, "", "",
            [wire.encode_snapshot(replica, 0, 0)])
        if not frames:
            raise wire.WireError("SNAPSHOT_SHIP response carried no frames")
        _, epoch, head = wire.decode_snapshot(frames[0])
        return epoch, head, [wire.decode_record_frame(f)
                             for f in frames[1:]]

    # -------------------------------------------------------------- quoting

    def quote_chunk_batches(self, sizes: Sequence[int]) -> int:
        """Exact socket bytes of one WANT stream's response for payloads
        ``sizes`` — CHUNK_BATCH frames at the server's split, wrapped in
        the mux HEADER + FRAME messages.  The stream id is fixed-width, so
        the quote needs no knowledge of which id will be allocated."""
        lens = wire.chunk_batch_frame_lens(sizes, self.response_batch_chunks)
        return wire.mux_response_envelope_bytes(lens)


def serve_registry_async(registry: Registry, host: str = "127.0.0.1",
                         port: int = 0, **server_kw) -> AsyncRegistryServer:
    """Convenience: wrap a bare :class:`Registry` in a frame-level
    :class:`RegistryServer` and put an event-loop front door on it."""
    return AsyncRegistryServer(RegistryServer(registry, **server_kw),
                               host=host, port=port)
