"""Pluggable transports — one client API over every delivery backend.

A :class:`Transport` answers the five questions the paper's client protocol
needs (and nothing else): *give me the index*, *give me the recipe*, *fetch
these chunks*, *take this push*, *which of these do you already have*.
:class:`repro.delivery.client.ImageClient` runs identical Algorithm-2 logic
against any implementation:

  * :class:`LocalTransport` — wraps a :class:`~repro.core.registry.Registry`
    in-process.  No frames are materialized; byte accounting uses the exact
    arithmetic sizing helpers in :mod:`repro.delivery.wire`, so reported
    bytes equal what the wire path would serialize.
  * :class:`WireTransport` — wraps a
    :class:`~repro.delivery.server.RegistryServer`.  Every exchange is a
    real encoded frame; payloads are fingerprint-verified on decode.
  * :class:`SwarmTransport` — composes peer providers (resolved per batch
    from a :class:`~repro.delivery.swarm.SwarmTracker`) over a registry
    fallback.  A dead peer is absorbed as a failover: the batch moves to the
    next provider and finally the registry, with each source's traffic and
    failures recorded on its own :class:`~repro.delivery.plan.SourceLeg`.

Control-plane methods (``has_chunks``, ``tags``) are KB-sized; data-plane
chunk traffic flows only through ``fetch_chunks``/``push``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, Sequence, Tuple,\
    runtime_checkable

from repro.core.cdmt import CDMT, CDMTParams
from repro.core.errors import DeliveryError
from repro.core.registry import PushReceipt, Registry
from repro.core.store import Recipe

from . import wire
from .plan import SourceLeg
from .server import RegistryServer

REGISTRY_SOURCE = "registry"


@dataclasses.dataclass
class FetchResult:
    """Chunks obtained for one batch, with per-source accounting."""
    chunks: Dict[bytes, bytes]
    legs: List[SourceLeg]


@dataclasses.dataclass
class PushOutcome:
    """What one push cost on the wire, per byte category."""
    receipt: PushReceipt
    header_bytes: int              # PUSH_HDR (wire) / index upload (local)
    recipe_bytes: int
    chunk_bytes: int
    rounds: int


@runtime_checkable
class Transport(Protocol):
    """The client-facing delivery protocol (duck-typed)."""

    name: str
    verifies_payloads: bool        # True: fetched payloads already hashed

    def get_index(self, lineage: str, tag: str) -> Tuple[CDMT, int]:
        """``(index, wire_bytes)``; :class:`DeliveryError` when unknown."""
        ...

    def get_latest_index(self, lineage: str
                         ) -> Tuple[Optional[CDMT], int]:
        """Lineage head index (None for a new lineage) + wire bytes."""
        ...

    def get_recipe(self, lineage: str, tag: str) -> Tuple[Recipe, int]:
        ...

    def fetch_chunks(self, lineage: str, tag: str,
                     fps: Sequence[bytes]) -> FetchResult:
        """Fetch one batch of chunk payloads.  Absent fps are omitted from
        the result (the caller decides whether absence is an error)."""
        ...

    def push(self, lineage: str, tag: str, recipe: Recipe,
             chunks: Dict[bytes, bytes], *,
             parent_version: Optional[int] = None,
             claimed_root: Optional[bytes] = None,
             claimed_params: Optional[CDMTParams] = None) -> PushOutcome:
        ...

    def has_chunks(self, fps: Sequence[bytes]
                   ) -> Tuple[List[bytes], int]:
        """``(missing_on_remote, control_wire_bytes)`` — lets a push ship
        only chunks the backend truly lacks (cross-lineage dedup)."""
        ...

    def tags(self, lineage: str) -> List[str]:
        ...

    def notify_pulled(self, lineage: str, tag: str) -> None:
        """Hook invoked after a successful pull fully ingests."""
        ...


# ----------------------------------------------------------------- in-process

class LocalTransport:
    """In-process transport over a :class:`Registry`.

    Byte accounting matches the wire path arithmetically (same sizing
    formulas, no frames built), with two deliberate differences inherited
    from the original in-process protocol: WANT frames cost nothing (the
    fetch is a function call) and a push uploads the full index instead of a
    PUSH_HDR (the in-process registry receives the tree object, it does not
    rebuild one from the recipe).
    """

    name = "local"
    verifies_payloads = False      # payloads come straight off local storage

    def __init__(self, registry: Registry):
        self.registry = registry

    def get_index(self, lineage: str, tag: str) -> Tuple[CDMT, int]:
        idx = self.registry.index_for_tag(lineage, tag)
        return idx, wire.index_wire_bytes(idx)

    def get_latest_index(self, lineage: str) -> Tuple[Optional[CDMT], int]:
        idx = self.registry.latest_index(lineage)
        return idx, wire.index_wire_bytes(idx) if idx is not None else 0

    def get_recipe(self, lineage: str, tag: str) -> Tuple[Recipe, int]:
        recipe = self.registry.recipe_for(lineage, tag)
        return recipe, wire.recipe_wire_bytes(recipe)

    def fetch_chunks(self, lineage: str, tag: str,
                     fps: Sequence[bytes]) -> FetchResult:
        chunks = self.registry.serve_chunks(fps)
        leg = SourceLeg(source=REGISTRY_SOURCE, chunks=len(chunks),
                        chunk_bytes=(wire.chunk_batch_wire_bytes(chunks)
                                     if chunks else 0),
                        rounds=1)
        return FetchResult(chunks=chunks, legs=[leg])

    def push(self, lineage: str, tag: str, recipe: Recipe,
             chunks: Dict[bytes, bytes], *,
             parent_version: Optional[int] = None,
             claimed_root: Optional[bytes] = None,
             claimed_params: Optional[CDMTParams] = None) -> PushOutcome:
        receipt = self.registry.receive_push(
            lineage, tag, recipe, chunks, parent_version=parent_version,
            claimed_root=claimed_root, claimed_params=claimed_params)
        idx = self.registry.index_for_tag(lineage, tag)
        return PushOutcome(
            receipt=receipt,
            header_bytes=wire.index_wire_bytes(idx),   # index upload
            recipe_bytes=wire.recipe_wire_bytes(recipe),
            chunk_bytes=wire.chunk_batch_wire_bytes(chunks) if chunks else 0,
            rounds=1 if chunks else 0)

    def has_chunks(self, fps: Sequence[bytes]) -> Tuple[List[bytes], int]:
        return self.registry.has_chunks(fps), 0

    def tags(self, lineage: str) -> List[str]:
        return self.registry.tags(lineage)

    def notify_pulled(self, lineage: str, tag: str) -> None:
        pass


# ----------------------------------------------------------------------- wire

class WireTransport:
    """Frame-level transport over a :class:`RegistryServer`.

    Every byte reported crossed the server boundary as a serialized frame;
    chunk payloads are blake2b-verified during ``decode_chunk_batch``.
    """

    name = "wire"
    verifies_payloads = True

    def __init__(self, server: RegistryServer, batch_chunks: int = 64):
        self.server = server
        self.batch_chunks = max(1, batch_chunks)   # push CHUNK_BATCH framing
        # the server splits each WANT into frames of at most this many
        # chunks — pull plans use it to quote response framing exactly
        self.response_batch_chunks = server.max_batch_chunks

    def get_index(self, lineage: str, tag: str) -> Tuple[CDMT, int]:
        frame = self.server.get_index(lineage, tag)
        return wire.decode_index(frame), len(frame)

    def get_latest_index(self, lineage: str) -> Tuple[Optional[CDMT], int]:
        frame = self.server.get_latest_index(lineage)
        if frame is None:
            return None, 0
        return wire.decode_index(frame), len(frame)

    def get_recipe(self, lineage: str, tag: str) -> Tuple[Recipe, int]:
        frame = self.server.get_recipe(lineage, tag)
        return wire.decode_recipe(frame), len(frame)

    def fetch_chunks(self, lineage: str, tag: str,
                     fps: Sequence[bytes]) -> FetchResult:
        want = wire.encode_want(fps)
        frames = self.server.handle_want(want)
        chunks: Dict[bytes, bytes] = {}
        nbytes = 0
        for f in frames:
            nbytes += len(f)
            chunks.update(wire.decode_chunk_batch(f))
        leg = SourceLeg(source=REGISTRY_SOURCE, chunks=len(chunks),
                        chunk_bytes=nbytes, want_bytes=len(want), rounds=1)
        return FetchResult(chunks=chunks, legs=[leg])

    def push(self, lineage: str, tag: str, recipe: Recipe,
             chunks: Dict[bytes, bytes], *,
             parent_version: Optional[int] = None,
             claimed_root: Optional[bytes] = None,
             claimed_params: Optional[CDMTParams] = None) -> PushOutcome:
        hdr = wire.encode_push_header(wire.PushHeader(
            lineage=lineage, tag=tag, root=claimed_root,
            parent_version=parent_version, params=claimed_params))
        recipe_frame = wire.encode_recipe(recipe)
        chunk_frames: List[bytes] = []
        fps = list(chunks)
        for start in range(0, len(fps), self.batch_chunks):
            part = {fp: chunks[fp]
                    for fp in fps[start:start + self.batch_chunks]}
            chunk_frames.append(wire.encode_chunk_batch(part))
        receipt = self.server.handle_push(hdr, recipe_frame, chunk_frames)
        # the registry rebuilds the index from the recipe, so no INDEX frame
        # is uploaded — the claimed root rides in the header
        return PushOutcome(receipt=receipt, header_bytes=len(hdr),
                           recipe_bytes=len(recipe_frame),
                           chunk_bytes=sum(len(f) for f in chunk_frames),
                           rounds=len(chunk_frames))

    def has_chunks(self, fps: Sequence[bytes]) -> Tuple[List[bytes], int]:
        req = wire.encode_has(fps)
        resp = self.server.handle_has(req)
        return wire.decode_missing(resp), len(req) + len(resp)

    def tags(self, lineage: str) -> List[str]:
        # control-plane query, but still protocol data: a TAGS frame in, a
        # TAG_LIST frame back, both metered by the server — the same frames
        # the socket path sends, so no byte silently skips the meters
        resp = self.server.handle_tags(wire.encode_tags_request(lineage))
        return wire.decode_tag_list(resp)

    def notify_pulled(self, lineage: str, tag: str) -> None:
        pass


# ---------------------------------------------------------------------- swarm

class SwarmTransport:
    """Peer-first transport: swarm providers over a registry fallback.

    Indexes, recipes, and pushes go to the registry (it stays the source of
    truth; peers only serve chunk payloads).  ``fetch_chunks`` resolves the
    current provider set from the tracker *per batch*, asks each provider
    for whatever is still wanted, and sends only the remainder to the
    registry — so a provider that dies mid-pull costs one failed round
    (recorded as a failover on its leg) and the batch completes from the
    next source.  After a successful pull the node registers as a provider.
    """

    name = "swarm"
    verifies_payloads = True

    def __init__(self, node, tracker, server,
                 max_peers: int = 4, batch_chunks: int = 64):
        self.node = node
        self.tracker = tracker
        # `server` is either a RegistryServer (historical form, wrapped in a
        # WireTransport) or any ready registry-facing Transport — e.g. a
        # SocketTransport, putting the swarm's fallback on a real socket.
        # `batch_chunks` only shapes the wrapper built here; a ready
        # transport keeps the framing it was constructed with.
        if isinstance(server, RegistryServer):
            self.registry_transport = WireTransport(
                server, batch_chunks=batch_chunks)
        else:
            self.registry_transport = server
        self.max_peers = max_peers

    # registry-delegated control plane --------------------------------------

    def get_index(self, lineage: str, tag: str) -> Tuple[CDMT, int]:
        return self.registry_transport.get_index(lineage, tag)

    def get_latest_index(self, lineage: str) -> Tuple[Optional[CDMT], int]:
        return self.registry_transport.get_latest_index(lineage)

    def get_recipe(self, lineage: str, tag: str) -> Tuple[Recipe, int]:
        return self.registry_transport.get_recipe(lineage, tag)

    def push(self, lineage: str, tag: str, recipe: Recipe,
             chunks: Dict[bytes, bytes], **kw) -> PushOutcome:
        return self.registry_transport.push(lineage, tag, recipe, chunks,
                                            **kw)

    def has_chunks(self, fps: Sequence[bytes]) -> Tuple[List[bytes], int]:
        return self.registry_transport.has_chunks(fps)

    def tags(self, lineage: str) -> List[str]:
        return self.registry_transport.tags(lineage)

    # peer-first data plane --------------------------------------------------

    def fetch_chunks(self, lineage: str, tag: str,
                     fps: Sequence[bytes]) -> FetchResult:
        chunks: Dict[bytes, bytes] = {}
        legs: List[SourceLeg] = []
        wanted = list(fps)
        peers = self.tracker.providers(lineage, tag, exclude=self.node,
                                       limit=self.max_peers)
        for peer in peers:
            if not wanted:
                break
            want = wire.encode_want(wanted)
            leg = SourceLeg(source=f"peer:{peer.name}",
                            want_bytes=len(want), rounds=1)
            legs.append(leg)
            try:
                frame = peer.serve_want(want)
            except DeliveryError:
                # dead/unreachable peer: failover to the next provider, and
                # tell the tracker — enough consecutive failures bench the
                # provider so later batches stop paying a failed round
                leg.failures += 1
                self.tracker.report_failure(peer)
                continue
            self.tracker.report_success(peer)
            # the frame crossed the wire either way — empty replies count too
            leg.chunk_bytes += len(frame)
            got = wire.decode_chunk_batch(frame)
            if got:
                leg.chunks += len(got)
                chunks.update(got)
                wanted = [fp for fp in wanted if fp not in got]
        if wanted:
            # final fallback: the registry serves whatever no peer held
            res = self.registry_transport.fetch_chunks(lineage, tag, wanted)
            chunks.update(res.chunks)
            legs.extend(res.legs)
        return FetchResult(chunks=chunks, legs=legs)

    def notify_pulled(self, lineage: str, tag: str) -> None:
        # freshly provisioned ⇒ this node can now serve the version
        self.tracker.register(lineage, tag, self.node)
