"""Pluggable transports — one client API over every delivery backend.

A :class:`Transport` answers the five questions the paper's client protocol
needs (and nothing else): *give me the index*, *give me the recipe*, *fetch
these chunks*, *take this push*, *which of these do you already have*.
:class:`repro.delivery.client.ImageClient` runs identical Algorithm-2 logic
against any implementation:

  * :class:`LocalTransport` — wraps a :class:`~repro.core.registry.Registry`
    in-process.  No frames are materialized; byte accounting uses the exact
    arithmetic sizing helpers in :mod:`repro.delivery.wire`, so reported
    bytes equal what the wire path would serialize.
  * :class:`WireTransport` — wraps a
    :class:`~repro.delivery.server.RegistryServer`.  Every exchange is a
    real encoded frame; payloads are fingerprint-verified on decode.
  * :class:`SwarmTransport` — composes peer providers (resolved per batch
    from a :class:`~repro.delivery.swarm.SwarmTracker`) over a registry
    fallback.  A dead peer is absorbed as a failover: the batch moves to the
    next provider and finally the registry, with each source's traffic and
    failures recorded on its own :class:`~repro.delivery.plan.SourceLeg`.

Control-plane methods (``has_chunks``, ``tags``) are KB-sized; data-plane
chunk traffic flows only through ``fetch_chunks``/``push``.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Dict, List, Optional, Protocol, Sequence, Set, Tuple,\
    runtime_checkable

from repro.core.cdmt import CDMT, CDMTParams
from repro.core.errors import DeliveryError
from repro.core.registry import PushReceipt, Registry
from repro.core.store import Recipe
from repro.obs import MetricsRegistry, MetricsSnapshot

from . import wire
from .plan import SourceLeg
from .server import RegistryServer

REGISTRY_SOURCE = "registry"

# client-side transport operations (labels of transport_op_seconds)
_METER_OPS = ("index", "recipe", "fetch", "push", "has", "tags")
# byte categories — chosen to mirror TransferReport exactly: after one pull
# on a fresh transport, index == report.index_bytes, recipe ==
# report.recipe_bytes, want == report.want_bytes, chunk ==
# report.chunk_bytes (the conformance test in tests/test_transport.py
# asserts this per transport)
_METER_CATEGORIES = ("index", "recipe", "want", "chunk")


class TransportMeter:
    """Pre-bound instrument set one transport instance records into.

    Byte accounting is taken from the same values the client folds into its
    :class:`~repro.delivery.plan.TransferReport` (returned frame lengths,
    source-leg want/chunk bytes), so per-transport metric totals and report
    totals agree to the byte.  Only successful operations are metered —
    a failed call contributed no report bytes either.
    """

    def __init__(self, metrics: MetricsRegistry, transport_name: str):
        lat = metrics.histogram(
            "transport_op_seconds",
            "client-side transport operation latency",
            ("transport", "op"))
        byt = metrics.counter(
            "transport_bytes_total",
            "wire bytes by TransferReport category",
            ("transport", "category"))
        self._lat = {op: lat.labels(transport_name, op)
                     for op in _METER_OPS}
        self._bytes = {cat: byt.labels(transport_name, cat)
                       for cat in _METER_CATEGORIES}

    def rec(self, op: str, t0: float, **categories: int) -> None:
        """Record one completed op: latency since ``t0`` plus any byte
        deltas (``index=``/``recipe=``/``want=``/``chunk=``)."""
        self._lat[op].observe(time.perf_counter() - t0)
        for cat, n in categories.items():
            if n:
                self._bytes[cat].inc(n)

    def rec_legs(self, t0: float, legs: Sequence[SourceLeg]) -> None:
        """Record one completed ``fetch_chunks`` from its source legs."""
        self.rec("fetch", t0,
                 want=sum(l.want_bytes for l in legs),
                 chunk=sum(l.chunk_bytes for l in legs))


@dataclasses.dataclass
class FetchResult:
    """Chunks obtained for one batch, with per-source accounting."""
    chunks: Dict[bytes, bytes]
    legs: List[SourceLeg]


@dataclasses.dataclass
class PushOutcome:
    """What one push cost on the wire, per byte category."""
    receipt: PushReceipt
    header_bytes: int              # PUSH_HDR (wire) / index upload (local)
    recipe_bytes: int
    chunk_bytes: int
    rounds: int


@runtime_checkable
class Transport(Protocol):
    """The client-facing delivery protocol (duck-typed)."""

    name: str
    verifies_payloads: bool        # True: fetched payloads already hashed

    def get_index(self, lineage: str, tag: str) -> Tuple[CDMT, int]:
        """``(index, wire_bytes)``; :class:`DeliveryError` when unknown."""
        ...

    def get_latest_index(self, lineage: str
                         ) -> Tuple[Optional[CDMT], int]:
        """Lineage head index (None for a new lineage) + wire bytes."""
        ...

    def get_recipe(self, lineage: str, tag: str) -> Tuple[Recipe, int]:
        ...

    def fetch_chunks(self, lineage: str, tag: str,
                     fps: Sequence[bytes]) -> FetchResult:
        """Fetch one batch of chunk payloads.  Absent fps are omitted from
        the result (the caller decides whether absence is an error)."""
        ...

    def push(self, lineage: str, tag: str, recipe: Recipe,
             chunks: Dict[bytes, bytes], *,
             parent_version: Optional[int] = None,
             claimed_root: Optional[bytes] = None,
             claimed_params: Optional[CDMTParams] = None) -> PushOutcome:
        ...

    def has_chunks(self, fps: Sequence[bytes]
                   ) -> Tuple[List[bytes], int]:
        """``(missing_on_remote, control_wire_bytes)`` — lets a push ship
        only chunks the backend truly lacks (cross-lineage dedup)."""
        ...

    def tags(self, lineage: str) -> List[str]:
        ...

    def notify_pulled(self, lineage: str, tag: str) -> None:
        """Hook invoked after a successful pull fully ingests."""
        ...


# ----------------------------------------------------------------- in-process

class LocalTransport:
    """In-process transport over a :class:`Registry`.

    Byte accounting matches the wire path arithmetically (same sizing
    formulas, no frames built), with two deliberate differences inherited
    from the original in-process protocol: WANT frames cost nothing (the
    fetch is a function call) and a push uploads the full index instead of a
    PUSH_HDR (the in-process registry receives the tree object, it does not
    rebuild one from the recipe).
    """

    name = "local"
    verifies_payloads = False      # payloads come straight off local storage

    def __init__(self, registry: Registry,
                 metrics: Optional[MetricsRegistry] = None):
        self.registry = registry
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._meter = TransportMeter(self.metrics, self.name)

    # api-boundary
    def get_index(self, lineage: str, tag: str) -> Tuple[CDMT, int]:
        t0 = time.perf_counter()
        idx = self.registry.index_for_tag(lineage, tag)
        nbytes = wire.index_wire_bytes(idx)
        self._meter.rec("index", t0, index=nbytes)
        return idx, nbytes

    # api-boundary
    def get_latest_index(self, lineage: str) -> Tuple[Optional[CDMT], int]:
        t0 = time.perf_counter()
        idx = self.registry.latest_index(lineage)
        nbytes = wire.index_wire_bytes(idx) if idx is not None else 0
        self._meter.rec("index", t0, index=nbytes)
        return idx, nbytes

    # api-boundary
    def get_recipe(self, lineage: str, tag: str) -> Tuple[Recipe, int]:
        t0 = time.perf_counter()
        recipe = self.registry.recipe_for(lineage, tag)
        nbytes = wire.recipe_wire_bytes(recipe)
        self._meter.rec("recipe", t0, recipe=nbytes)
        return recipe, nbytes

    # api-boundary
    def fetch_chunks(self, lineage: str, tag: str,
                     fps: Sequence[bytes]) -> FetchResult:
        t0 = time.perf_counter()
        chunks = self.registry.serve_chunks(fps)
        leg = SourceLeg(source=REGISTRY_SOURCE, chunks=len(chunks),
                        chunk_bytes=(wire.chunk_batch_wire_bytes(chunks)
                                     if chunks else 0),
                        rounds=1)
        self._meter.rec_legs(t0, [leg])
        return FetchResult(chunks=chunks, legs=[leg])

    # api-boundary
    def push(self, lineage: str, tag: str, recipe: Recipe,
             chunks: Dict[bytes, bytes], *,
             parent_version: Optional[int] = None,
             claimed_root: Optional[bytes] = None,
             claimed_params: Optional[CDMTParams] = None) -> PushOutcome:
        t0 = time.perf_counter()
        receipt = self.registry.receive_push(
            lineage, tag, recipe, chunks, parent_version=parent_version,
            claimed_root=claimed_root, claimed_params=claimed_params)
        idx = self.registry.index_for_tag(lineage, tag)
        outcome = PushOutcome(
            receipt=receipt,
            header_bytes=wire.index_wire_bytes(idx),   # index upload
            recipe_bytes=wire.recipe_wire_bytes(recipe),
            chunk_bytes=wire.chunk_batch_wire_bytes(chunks) if chunks else 0,
            rounds=1 if chunks else 0)
        self._meter.rec("push", t0, index=outcome.header_bytes,
                        recipe=outcome.recipe_bytes,
                        chunk=outcome.chunk_bytes)
        return outcome

    # api-boundary
    def has_chunks(self, fps: Sequence[bytes]) -> Tuple[List[bytes], int]:
        t0 = time.perf_counter()
        missing = self.registry.has_chunks(fps)
        self._meter.rec("has", t0)
        return missing, 0

    # api-boundary
    def tags(self, lineage: str) -> List[str]:
        t0 = time.perf_counter()
        out = self.registry.tags(lineage)
        self._meter.rec("tags", t0)
        return out

    # api-boundary
    def notify_pulled(self, lineage: str, tag: str) -> None:
        pass

    def replication_status(self) -> Tuple[int, int]:
        """The registry's replication ``(epoch, head)`` — liveness and
        freshness probe used by :class:`ReplicatedTransport`."""
        log = self.registry.replication
        return log.epoch, log.head()

    def fetch_snapshot(self, replica: str = "standby"
                       ) -> Tuple[int, int, List[Tuple[int, bytes, bytes]]]:
        """In-process SNAPSHOT_SHIP: the registry's collapsed state as
        ``(epoch, head, (rtype, payload, raw) records)`` — what a fresh
        standby bootstraps from instead of replaying history from offset
        0 (which a trimmed replication log no longer holds)."""
        epoch, head, raws = self.registry.state_snapshot()
        records = []
        for raw in raws:
            rtype, payload, _ = wire.decode_record(raw, 0)
            records.append((rtype, payload, raw))
        return epoch, head, records


# ----------------------------------------------------------------------- wire

class WireTransport:
    """Frame-level transport over a :class:`RegistryServer`.

    Every byte reported crossed the server boundary as a serialized frame;
    chunk payloads are blake2b-verified during ``decode_chunk_batch``.
    """

    name = "wire"
    verifies_payloads = True

    def __init__(self, server: RegistryServer, batch_chunks: int = 64,
                 metrics: Optional[MetricsRegistry] = None):
        self.server = server
        self.batch_chunks = max(1, batch_chunks)   # push CHUNK_BATCH framing
        # the server splits each WANT into frames of at most this many
        # chunks — pull plans use it to quote response framing exactly
        self.response_batch_chunks = server.max_batch_chunks
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._meter = TransportMeter(self.metrics, self.name)

    # api-boundary
    def get_index(self, lineage: str, tag: str) -> Tuple[CDMT, int]:
        t0 = time.perf_counter()
        frame = self.server.get_index(lineage, tag)
        self._meter.rec("index", t0, index=len(frame))
        return wire.decode_index(frame), len(frame)

    # api-boundary
    def get_latest_index(self, lineage: str) -> Tuple[Optional[CDMT], int]:
        t0 = time.perf_counter()
        frame = self.server.get_latest_index(lineage)
        self._meter.rec("index", t0,
                        index=len(frame) if frame is not None else 0)
        if frame is None:
            return None, 0
        return wire.decode_index(frame), len(frame)

    # api-boundary
    def get_recipe(self, lineage: str, tag: str) -> Tuple[Recipe, int]:
        t0 = time.perf_counter()
        frame = self.server.get_recipe(lineage, tag)
        self._meter.rec("recipe", t0, recipe=len(frame))
        return wire.decode_recipe(frame), len(frame)

    # api-boundary
    def fetch_chunks(self, lineage: str, tag: str,
                     fps: Sequence[bytes]) -> FetchResult:
        t0 = time.perf_counter()
        want = wire.encode_want(fps)
        frames = self.server.handle_want(want)
        chunks: Dict[bytes, bytes] = {}
        nbytes = 0
        for f in frames:
            nbytes += len(f)
            chunks.update(wire.decode_chunk_batch(f))
        leg = SourceLeg(source=REGISTRY_SOURCE, chunks=len(chunks),
                        chunk_bytes=nbytes, want_bytes=len(want), rounds=1)
        self._meter.rec_legs(t0, [leg])
        return FetchResult(chunks=chunks, legs=[leg])

    # api-boundary
    def push(self, lineage: str, tag: str, recipe: Recipe,
             chunks: Dict[bytes, bytes], *,
             parent_version: Optional[int] = None,
             claimed_root: Optional[bytes] = None,
             claimed_params: Optional[CDMTParams] = None) -> PushOutcome:
        t0 = time.perf_counter()
        hdr = wire.encode_push_header(wire.PushHeader(
            lineage=lineage, tag=tag, root=claimed_root,
            parent_version=parent_version, params=claimed_params))
        recipe_frame = wire.encode_recipe(recipe)
        chunk_frames: List[bytes] = []
        fps = list(chunks)
        for start in range(0, len(fps), self.batch_chunks):
            part = {fp: chunks[fp]
                    for fp in fps[start:start + self.batch_chunks]}
            chunk_frames.append(wire.encode_chunk_batch(part))
        receipt = self.server.handle_push(hdr, recipe_frame, chunk_frames)
        # the registry rebuilds the index from the recipe, so no INDEX frame
        # is uploaded — the claimed root rides in the header
        outcome = PushOutcome(receipt=receipt, header_bytes=len(hdr),
                              recipe_bytes=len(recipe_frame),
                              chunk_bytes=sum(len(f) for f in chunk_frames),
                              rounds=len(chunk_frames))
        self._meter.rec("push", t0, index=outcome.header_bytes,
                        recipe=outcome.recipe_bytes,
                        chunk=outcome.chunk_bytes)
        return outcome

    # api-boundary
    def has_chunks(self, fps: Sequence[bytes]) -> Tuple[List[bytes], int]:
        t0 = time.perf_counter()
        req = wire.encode_has(fps)
        resp = self.server.handle_has(req)
        self._meter.rec("has", t0, want=len(req) + len(resp))
        return wire.decode_missing(resp), len(req) + len(resp)

    # api-boundary
    def tags(self, lineage: str) -> List[str]:
        # control-plane query, but still protocol data: a TAGS frame in, a
        # TAG_LIST frame back, both metered by the server — the same frames
        # the socket path sends, so no byte silently skips the meters
        t0 = time.perf_counter()
        resp = self.server.handle_tags(wire.encode_tags_request(lineage))
        self._meter.rec("tags", t0)
        return wire.decode_tag_list(resp)

    def scrape_metrics(self) -> MetricsSnapshot:
        """The server's live metrics as a decoded
        :class:`repro.obs.MetricsSnapshot` (in-process analogue of the
        socket path's ``Op.METRICS`` scrape)."""
        frame = self.server.handle_metrics()
        return MetricsSnapshot.from_json(
            wire.decode_metrics(frame).decode("utf-8"))

    # api-boundary
    def notify_pulled(self, lineage: str, tag: str) -> None:
        pass

    # ---------------------------------------------------------- replication

    def ship_journal(self, replica: str, epoch: int, start: int,
                     limit: int = 512
                     ) -> Tuple[int, int, List[Tuple[int, bytes, bytes]]]:
        """In-process JOURNAL_SHIP (same frames the socket path ships):
        ``(epoch, head, checksum-verified (rtype, payload, raw) records)``."""
        frames = self.server.handle_ship(
            wire.encode_ship(replica, epoch, start, limit))
        _, srv_epoch, head = wire.decode_repl_ack(frames[0])
        return srv_epoch, head, [wire.decode_record_frame(f)
                                 for f in frames[1:]]

    def ack_journal(self, replica: str, epoch: int,
                    offset: int) -> Tuple[int, int]:
        resp = self.server.handle_repl_ack(
            wire.encode_repl_ack(replica, epoch, offset))
        _, srv_epoch, head = wire.decode_repl_ack(resp)
        return srv_epoch, head

    def replication_status(self) -> Tuple[int, int]:
        epoch, head, _ = self.ship_journal("", 0, 0, 0)
        return epoch, head

    def fetch_snapshot(self, replica: str = "standby"
                       ) -> Tuple[int, int, List[Tuple[int, bytes, bytes]]]:
        """In-process SNAPSHOT_SHIP (same frames the socket path streams):
        one SNAPSHOT header carrying the primary's ``(epoch, head)``
        resume position, then checksum-verified state records."""
        frames = self.server.handle_snapshot(
            wire.encode_snapshot(replica, 0, 0))
        _, epoch, head = wire.decode_snapshot(frames[0])
        return epoch, head, [wire.decode_record_frame(f)
                             for f in frames[1:]]


# ---------------------------------------------------------------------- swarm

class SwarmTransport:
    """Peer-first transport: swarm providers over a registry fallback.

    Indexes, recipes, and pushes go to the registry (it stays the source of
    truth; peers only serve chunk payloads).  ``fetch_chunks`` resolves the
    current provider set from the tracker *per batch*, asks each provider
    for whatever is still wanted, and sends only the remainder to the
    registry — so a provider that dies mid-pull costs one failed round
    (recorded as a failover on its leg) and the batch completes from the
    next source.  After a successful pull the node registers as a provider.
    """

    name = "swarm"
    verifies_payloads = True

    def __init__(self, node, tracker, server,
                 max_peers: int = 4, batch_chunks: int = 64,
                 metrics: Optional[MetricsRegistry] = None):
        self.node = node
        self.tracker = tracker
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._meter = TransportMeter(self.metrics, self.name)
        # `server` is either a RegistryServer (historical form, wrapped in a
        # WireTransport) or any ready registry-facing Transport — e.g. a
        # SocketTransport, putting the swarm's fallback on a real socket.
        # `batch_chunks` only shapes the wrapper built here; a ready
        # transport keeps the framing it was constructed with.  A wrapper
        # built here shares this transport's metrics registry (its own
        # series land under transport="wire"), so one snapshot shows the
        # swarm level and its registry fallback side by side.
        if isinstance(server, RegistryServer):
            self.registry_transport = WireTransport(
                server, batch_chunks=batch_chunks, metrics=self.metrics)
        else:
            self.registry_transport = server
        self.max_peers = max_peers

    # registry-delegated control plane --------------------------------------

    # api-boundary
    def get_index(self, lineage: str, tag: str) -> Tuple[CDMT, int]:
        t0 = time.perf_counter()
        tree, nbytes = self.registry_transport.get_index(lineage, tag)
        self._meter.rec("index", t0, index=nbytes)
        return tree, nbytes

    # api-boundary
    def get_latest_index(self, lineage: str) -> Tuple[Optional[CDMT], int]:
        t0 = time.perf_counter()
        tree, nbytes = self.registry_transport.get_latest_index(lineage)
        self._meter.rec("index", t0, index=nbytes)
        return tree, nbytes

    # api-boundary
    def get_recipe(self, lineage: str, tag: str) -> Tuple[Recipe, int]:
        t0 = time.perf_counter()
        recipe, nbytes = self.registry_transport.get_recipe(lineage, tag)
        self._meter.rec("recipe", t0, recipe=nbytes)
        return recipe, nbytes

    # api-boundary
    def push(self, lineage: str, tag: str, recipe: Recipe,
             chunks: Dict[bytes, bytes], **kw) -> PushOutcome:
        t0 = time.perf_counter()
        outcome = self.registry_transport.push(lineage, tag, recipe, chunks,
                                               **kw)
        self._meter.rec("push", t0, index=outcome.header_bytes,
                        recipe=outcome.recipe_bytes,
                        chunk=outcome.chunk_bytes)
        return outcome

    # api-boundary
    def has_chunks(self, fps: Sequence[bytes]) -> Tuple[List[bytes], int]:
        t0 = time.perf_counter()
        missing, nbytes = self.registry_transport.has_chunks(fps)
        self._meter.rec("has", t0, want=nbytes)
        return missing, nbytes

    # api-boundary
    def tags(self, lineage: str) -> List[str]:
        t0 = time.perf_counter()
        out = self.registry_transport.tags(lineage)
        self._meter.rec("tags", t0)
        return out

    # peer-first data plane --------------------------------------------------

    # api-boundary
    def fetch_chunks(self, lineage: str, tag: str,
                     fps: Sequence[bytes]) -> FetchResult:
        t0 = time.perf_counter()
        chunks: Dict[bytes, bytes] = {}
        legs: List[SourceLeg] = []
        wanted = list(fps)
        peers = self.tracker.providers(lineage, tag, exclude=self.node,
                                       limit=self.max_peers)
        for peer in peers:
            if not wanted:
                break
            want = wire.encode_want(wanted)
            leg = SourceLeg(source=f"peer:{peer.name}",
                            want_bytes=len(want), rounds=1)
            legs.append(leg)
            try:
                frame = peer.serve_want(want)
            except DeliveryError:
                # dead/unreachable peer: failover to the next provider, and
                # tell the tracker — enough consecutive failures bench the
                # provider so later batches stop paying a failed round
                leg.failures += 1
                self.tracker.report_failure(peer)
                continue
            self.tracker.report_success(peer)
            # the frame crossed the wire either way — empty replies count too
            leg.chunk_bytes += len(frame)
            got = wire.decode_chunk_batch(frame)
            if got:
                leg.chunks += len(got)
                chunks.update(got)
                wanted = [fp for fp in wanted if fp not in got]
        if wanted:
            # final fallback: the registry serves whatever no peer held
            res = self.registry_transport.fetch_chunks(lineage, tag, wanted)
            chunks.update(res.chunks)
            legs.extend(res.legs)
        self._meter.rec_legs(t0, legs)
        return FetchResult(chunks=chunks, legs=legs)

    # api-boundary
    def notify_pulled(self, lineage: str, tag: str) -> None:
        # freshly provisioned ⇒ this node can now serve the version
        self.tracker.register(lineage, tag, self.node)


# ----------------------------------------------------------------- replicated

class ReplicatedTransport:
    """N replicas of one registry behind a single :class:`Transport`.

    ``replicas`` are transports to registries kept in sync by journal
    shipping (see :class:`repro.delivery.net.JournalFollower`); index
    ``primary`` is the one accepting pushes.  Behavior:

      * **Writes** (``push``, and the authoritative control reads
        ``get_index`` / ``get_recipe`` / ``tags`` / ``has_chunks``) go to
        the current primary.  The root the primary returns for a tag is
        remembered — it is the freshness reference every standby is checked
        against.
      * **Chunk reads** (``fetch_chunks``) rotate across live replicas, so
        N replicas each carry ~1/N of the data-plane egress.  Before a
        standby serves its first batch of a pull, it is **probed**: its
        index for the tag must exist and hash to the primary-recorded root.
        A standby that fails the probe — or omits requested payloads — is
        *stale* for that tag: the batch (and the tag's later batches) fall
        through to the next replica and finally the primary, and the
        stale-detection is counted on ``stale_detected``.  Probe and
        failed-round traffic rides in ``want_bytes`` on the replica's
        :class:`~repro.delivery.plan.SourceLeg`, so the plan identity
        ``index + recipe + chunk_bytes == expected_wire_bytes`` stays exact.
      * **Promotion**: a replica whose transport fails is health-probed
        (``replication_status`` — a zero-budget JOURNAL_SHIP); a dead
        primary is replaced by the standby with the freshest replication
        position (highest ``(epoch, head)`` — freshest-root wins, since the
        head counts committed roots), mid-pull, without failing the client
        operation.  ``promotions`` counts them.

    Quote exactness (``plan_pull``): delegated to the primary's own quoting
    hook.  Replicas of one primary should be configured with the same
    response batch split — then a batch's chunk bytes are identical
    whichever replica serves it, and a replicated plan quotes socket bytes
    (envelopes included) to the byte.

    Thread-safe: ``ImageClient.execute`` fans pipelined batches across
    threads; rotation, death/staleness marks, and promotion are guarded by
    one lock, held only around bookkeeping (never across network calls).
    """

    name = "replicated"

    # instances start their read rotation at staggered positions, so a
    # fleet of single-batch pullers (each its own transport) spreads across
    # the replicas instead of all electing the same first choice
    _stagger = itertools.count()

    def __init__(self, replicas: Sequence[Transport], primary: int = 0,
                 metrics: Optional[MetricsRegistry] = None):
        if not replicas:
            raise ValueError("ReplicatedTransport needs at least one replica")
        if not 0 <= primary < len(replicas):
            raise ValueError(f"primary index {primary} out of range")
        self.replicas: List[Transport] = list(replicas)
        self.verifies_payloads = all(t.verifies_payloads
                                     for t in self.replicas)
        self._lock = threading.Lock()
        self._primary = primary            # guarded-by: _lock
        self._dead: Set[int] = set()       # guarded-by: _lock
        self._stale: Dict[Tuple[str, str], Set[int]] = {}    # guarded-by: _lock
        self._checked: Dict[Tuple[str, str], Set[int]] = {}  # guarded-by: _lock
        self._roots: Dict[Tuple[str, str], Optional[bytes]] = {}  # guarded-by: _lock
        self._rr = next(ReplicatedTransport._stagger)  # guarded-by: _lock
        self.promotions = 0        # guarded-by: _lock
        self.stale_detected = 0    # guarded-by: _lock
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._meter = TransportMeter(self.metrics, self.name)
        self._m_promotions = self.metrics.counter(
            "replicated_promotions_total",
            "dead primaries replaced by a standby").labels()
        self._m_stale = self.metrics.counter(
            "replicated_stale_detected_total",
            "stale replica probes/fetches absorbed").labels()

    # ------------------------------------------------------------- lifecycle

    @property
    def primary_index(self) -> int:
        with self._lock:
            return self._primary

    @property
    def primary_transport(self) -> Transport:
        with self._lock:
            return self.replicas[self._primary]

    def close(self) -> None:
        for t in self.replicas:
            close = getattr(t, "close", None)
            if close is not None:
                close()

    def __enter__(self) -> "ReplicatedTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------- health bookkeeping

    def _mark_dead(self, idx: int) -> None:
        with self._lock:
            self._dead.add(idx)

    def _mark_stale(self, idx: int, key: Tuple[str, str]) -> None:
        with self._lock:
            self._stale.setdefault(key, set()).add(idx)
            self.stale_detected += 1
        self._m_stale.inc()

    def _probe_alive(self, idx: int) -> bool:
        """Distinguish a dead replica from a live one returning a protocol
        error: a zero-budget ship must succeed on any live registry."""
        status = getattr(self.replicas[idx], "replication_status", None)
        if status is None:
            return True
        try:
            status()
            return True
        except DeliveryError:
            return False

    def _promote(self) -> None:
        """Replace a dead primary with the freshest live standby (highest
        ``(epoch, head)`` replication position)."""
        with self._lock:
            if self._primary not in self._dead:
                return                     # another thread already promoted
            candidates = [i for i in range(len(self.replicas))
                          if i not in self._dead]
        best: Optional[int] = None
        best_pos = (-1, -1)
        for i in candidates:
            status = getattr(self.replicas[i], "replication_status", None)
            if status is None:
                pos = (0, 0)
            else:
                try:
                    pos = status()
                except DeliveryError:
                    self._mark_dead(i)
                    continue
            if pos > best_pos:
                best, best_pos = i, pos
        if best is None:
            raise DeliveryError(
                "replicated transport: primary is dead and no standby is "
                "reachable")
        with self._lock:
            if self._primary in self._dead:
                self._primary = best
                self.promotions += 1
                promoted = True
            else:
                promoted = False
        if promoted:
            self._m_promotions.inc()

    def _on_primary(self, fn):
        """Run ``fn(primary_transport)``; a dead primary is replaced by the
        freshest standby and the call retried there.  Protocol-level errors
        from a live primary (unknown tag, rejected push) re-raise."""
        for _ in range(len(self.replicas) + 1):
            with self._lock:
                idx = self._primary
            try:
                return fn(self.replicas[idx])
            except DeliveryError:
                if self._probe_alive(idx):
                    raise
                self._mark_dead(idx)
                self._promote()
        raise DeliveryError("replicated transport: no live replica")

    # --------------------------------------------- control plane (primary)

    # api-boundary
    def get_index(self, lineage: str, tag: str) -> Tuple[CDMT, int]:
        t0 = time.perf_counter()
        tree, nbytes = self._on_primary(lambda t: t.get_index(lineage, tag))
        with self._lock:
            self._roots[(lineage, tag)] = tree.root
        self._meter.rec("index", t0, index=nbytes)
        return tree, nbytes

    # api-boundary
    def get_latest_index(self, lineage: str) -> Tuple[Optional[CDMT], int]:
        t0 = time.perf_counter()
        tree, nbytes = self._on_primary(lambda t: t.get_latest_index(lineage))
        self._meter.rec("index", t0, index=nbytes)
        return tree, nbytes

    # api-boundary
    def get_recipe(self, lineage: str, tag: str) -> Tuple[Recipe, int]:
        t0 = time.perf_counter()
        recipe, nbytes = self._on_primary(lambda t: t.get_recipe(lineage, tag))
        self._meter.rec("recipe", t0, recipe=nbytes)
        return recipe, nbytes

    # api-boundary
    def tags(self, lineage: str) -> List[str]:
        t0 = time.perf_counter()
        out = self._on_primary(lambda t: t.tags(lineage))
        self._meter.rec("tags", t0)
        return out

    # api-boundary
    def has_chunks(self, fps: Sequence[bytes]) -> Tuple[List[bytes], int]:
        t0 = time.perf_counter()
        missing, nbytes = self._on_primary(lambda t: t.has_chunks(fps))
        self._meter.rec("has", t0, want=nbytes)
        return missing, nbytes

    # api-boundary
    def push(self, lineage: str, tag: str, recipe: Recipe,
             chunks: Dict[bytes, bytes], *,
             parent_version: Optional[int] = None,
             claimed_root: Optional[bytes] = None,
             claimed_params: Optional[CDMTParams] = None) -> PushOutcome:
        t0 = time.perf_counter()
        outcome = self._on_primary(lambda t: t.push(
            lineage, tag, recipe, chunks, parent_version=parent_version,
            claimed_root=claimed_root, claimed_params=claimed_params))
        self._meter.rec("push", t0, index=outcome.header_bytes,
                        recipe=outcome.recipe_bytes,
                        chunk=outcome.chunk_bytes)
        return outcome

    # api-boundary
    def notify_pulled(self, lineage: str, tag: str) -> None:
        pass

    # ------------------------------------------------- data plane (fan-out)

    def _source_name(self, idx: int) -> str:
        with self._lock:
            primary = self._primary
        return REGISTRY_SOURCE if idx == primary else f"replica:{idx}"

    def _read_order(self, key: Tuple[str, str]) -> List[int]:
        """Live, not-stale-for-this-tag replicas, rotated one step per call
        — consecutive batches land on different replicas."""
        with self._lock:
            live = [i for i in range(len(self.replicas))
                    if i not in self._dead
                    and i not in self._stale.get(key, ())]
            if not live:
                return [self._primary]
            start = self._rr % len(live)
            self._rr += 1
            return live[start:] + live[:start]

    def _probe_fresh(self, idx: int, key: Tuple[str, str]) -> Tuple[bool, int]:
        """One KB-sized index fetch against a standby before its first batch
        of a pull: the tag must exist there and hash to the root the primary
        served.  Returns ``(fresh, probe_wire_bytes)``."""
        try:
            tree, nbytes = self.replicas[idx].get_index(*key)
        except DeliveryError:
            if self._probe_alive(idx):
                self._mark_stale(idx, key)     # tag not replicated yet
            else:
                self._mark_dead(idx)
            return False, 0
        with self._lock:
            expected = self._roots.setdefault(key, tree.root)
        if tree.root != expected:
            self._mark_stale(idx, key)         # diverged: CDMT root mismatch
            return False, nbytes
        with self._lock:
            self._checked.setdefault(key, set()).add(idx)
        return True, nbytes

    # api-boundary
    def fetch_chunks(self, lineage: str, tag: str,
                     fps: Sequence[bytes]) -> FetchResult:
        t0 = time.perf_counter()
        key = (lineage, tag)
        chunks: Dict[bytes, bytes] = {}
        legs: List[SourceLeg] = []
        wanted = list(fps)
        primary_answered = False
        for idx in self._read_order(key):
            if not wanted:
                break
            with self._lock:
                is_primary = idx == self._primary
                checked = idx in self._checked.get(key, ())
                if idx in self._stale.get(key, ()) or idx in self._dead:
                    continue
            probe_bytes = 0
            if not is_primary and not checked:
                fresh, probe_bytes = self._probe_fresh(idx, key)
                if not fresh:
                    legs.append(SourceLeg(source=self._source_name(idx),
                                          want_bytes=probe_bytes, rounds=1,
                                          failures=1))
                    continue
            try:
                res = self.replicas[idx].fetch_chunks(lineage, tag, wanted)
            except DeliveryError:
                if self._probe_alive(idx):
                    raise                      # protocol error from a live one
                name = self._source_name(idx)  # before promotion renames it
                self._mark_dead(idx)
                if is_primary:
                    # promote NOW, mid-pull — later batches and the next
                    # control-plane call go straight to the new primary
                    try:
                        self._promote()
                    except DeliveryError:
                        pass       # no standby left: the loop (and finally
                                   # _on_primary) surface it if chunks remain
                legs.append(SourceLeg(source=name, want_bytes=probe_bytes,
                                      rounds=1, failures=1))
                continue
            name = self._source_name(idx)
            for leg in res.legs:
                leg.source = name
            if res.legs and probe_bytes:
                res.legs[0].want_bytes += probe_bytes
            legs.extend(res.legs)
            chunks.update(res.chunks)
            wanted = [fp for fp in wanted if fp not in res.chunks]
            if is_primary:
                primary_answered = True
            elif wanted:
                # a fresh-looking standby omitted payloads its index
                # references: its chunk store lags — stale for this tag,
                # the remainder falls through to the next source
                self._mark_stale(idx, key)
        if wanted and not primary_answered:
            # rotation never reached a (live) primary: ask it directly,
            # promoting first if the old primary died mid-pull
            res = self._on_primary(
                lambda t: t.fetch_chunks(lineage, tag, wanted))
            for leg in res.legs:
                leg.source = REGISTRY_SOURCE
            legs.extend(res.legs)
            chunks.update(res.chunks)
        self._meter.rec_legs(t0, legs)
        return FetchResult(chunks=chunks, legs=legs)

    # -------------------------------------------------------------- quoting

    def quote_chunk_batches(self, sizes: Sequence[int],
                            replica: Optional[int] = None) -> int:
        """Quote via one replica's framing — the primary by default,
        ``replica`` (an index into ``replicas``) to quote a specific
        standby's response split.  Exact when every replica serves the
        same response batch split (deploy them that way); the per-replica
        form lets a planner verify that assumption against each standby
        (a snapshot-bootstrapped one included) instead of trusting it."""
        if replica is None:
            t = self.primary_transport
        else:
            if not 0 <= replica < len(self.replicas):
                raise ValueError(f"replica index {replica} out of range")
            t = self.replicas[replica]
        quote = getattr(t, "quote_chunk_batches", None)
        if quote is not None:
            return quote(sizes)
        sub = getattr(t, "response_batch_chunks", None) or max(1, len(sizes))
        return wire.chunk_batches_wire_bytes(sizes, sub)
