"""Binary wire format for CDMT delivery (varint-framed).

Everything that crosses the client↔registry↔peer boundary is one of five
frame types, each ``MAGIC | version | type | uvarint(len) | payload``:

  ``INDEX``        a whole CDMT.  The encoding ships only the *leaf*
                   fingerprints plus per-level fanout runs — internal node ids
                   are blake2b over child ids, so the decoder *recomputes*
                   them.  This keeps the index at ~``n_leaves × digest`` bytes
                   (the paper's "KB-sized index") and makes the frame
                   self-verifying: a corrupted byte changes the recomputed
                   root.
  ``RECIPE``       ordered (fp, size) list reconstructing one artifact.
  ``CHUNK_BATCH``  fp-prefixed chunk payloads; the decoder checks each
                   payload's blake2b against its fp (authenticated transfer).
  ``WANT``         a fingerprint request list (pull / peer fetch).
  ``PUSH_HDR``     push envelope: lineage, tag, claimed root, parent version.
  ``HAS``          presence query: which of these fps does the server hold?
  ``MISSING``      the reply — fps the server does NOT hold (a push then
                   ships exactly these, enabling cross-lineage dedup).
  ``TAGS``         tag-listing query for one lineage (control plane — tag
                   names are protocol data, not an attribute reach).
  ``TAG_LIST``     the reply: the lineage's tag names in version order.
  ``ERROR``        protocol-level failure: an error code plus message, so a
                   remote server's rejection crosses the wire as data and is
                   re-raised client-side as the matching exception.
  ``RECEIPT``      a serialized :class:`~repro.core.registry.PushReceipt` —
                   what a socket push gets back instead of a Python object.
  ``INFO``         server parameters a client needs to quote costs exactly
                   (today: the server's response batch split).
  ``SHIP``         a standby's journal-ship request: replica name, epoch,
                   resume offset, record budget (0 = pure status probe).
  ``RECORD``       one checksummed journal record in transit — the payload
                   is the *encoded* record (``wire.encode_record`` bytes),
                   so a standby re-verifies the checksum before replay.
  ``REPL_ACK``     replication position: replica name, epoch, offset.  Sent
                   by a standby to report applied progress, and returned by
                   the primary (as a ship-response header and as the ack
                   reply) to publish its current epoch and log head.
  ``METRICS``      a live metrics scrape: one UTF-8 JSON document in the
                   ``repro.obs.MetricsSnapshot`` shape, so any client can
                   read a server's counters/gauges/histograms over the
                   same socket that moves chunks.
  ``SNAPSHOT``     a snapshot-bootstrap position: replica name, epoch,
                   resume offset.  Sent by a fresh standby to request a
                   compacted state snapshot, and returned by the primary as
                   the stream header announcing the epoch and the offset
                   ordinary ``JOURNAL_SHIP`` resumes from; the snapshot's
                   state records follow as ``RECORD`` frames.

All decoders raise :class:`WireError` on truncation, bad magic, trailing
garbage, or fingerprint mismatch — never a bare ``IndexError``/``KeyError``.

For real sockets, frames travel inside length-prefixed **envelopes** (see
``encode_request`` / ``encode_response_header``): a request names an
:class:`Op` plus lineage/tag routing strings and carries zero or more body
frames; a response is a status byte plus a frame count, then the frames —
which lets a server *stream* a multi-frame WANT answer while the client
decodes batches as they arrive.  Envelope overhead is exactly computable
(``request_envelope_bytes`` / ``response_envelope_bytes``), so a pull plan
can quote socket bytes to the byte before opening a connection.

The async data plane multiplexes many streams over one connection using
the **mux envelopes** (``encode_mux_request`` / ``encode_mux_response_*``):
the same frames, routed by a fixed-width stream id, with equally exact
sizing (``mux_request_envelope_bytes`` / ``mux_response_envelope_bytes``).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.core import hashing
from repro.core.cdmt import CDMT, CDMTNode, CDMTParams
from repro.core.store import Recipe

MAGIC = b"CW"
VERSION = 1
_HEADER = len(MAGIC) + 2  # magic + version byte + type byte


class WireError(ValueError):
    """Malformed, truncated, or tampered wire data."""


class FrameType(enum.IntEnum):
    INDEX = 1
    RECIPE = 2
    CHUNK_BATCH = 3
    WANT = 4
    PUSH_HDR = 5
    HAS = 6
    MISSING = 7
    TAGS = 8
    TAG_LIST = 9
    ERROR = 10
    RECEIPT = 11
    INFO = 12
    SHIP = 13
    RECORD = 14
    REPL_ACK = 15
    METRICS = 16
    SNAPSHOT = 17


class Op(enum.IntEnum):
    """Request operations a delivery endpoint answers (socket envelope)."""
    INDEX = 1          # -> INDEX frame
    LATEST_INDEX = 2   # -> INDEX frame, or zero frames for a new lineage
    RECIPE = 3         # -> RECIPE frame
    WANT = 4           # WANT frame -> streamed CHUNK_BATCH frames
    HAS = 5            # HAS frame -> MISSING frame
    PUSH = 6           # PUSH_HDR + RECIPE + CHUNK_BATCH* -> RECEIPT frame
    TAGS = 7           # TAGS frame -> TAG_LIST frame
    INFO = 8           # -> INFO frame
    JOURNAL_SHIP = 9   # SHIP frame -> REPL_ACK frame + RECORD frames
    REPL_ACK = 10      # REPL_ACK frame -> REPL_ACK frame (primary's head)
    METRICS = 11       # -> METRICS frame (JSON metrics snapshot)
    SNAPSHOT_SHIP = 12  # SNAPSHOT frame -> SNAPSHOT frame + RECORD frames
                        # (streamed compacted state; standby bootstrap)


class ErrorCode(enum.IntEnum):
    """What kind of exception an ERROR frame re-raises client-side."""
    DELIVERY = 1       # repro.core.errors.DeliveryError
    PUSH_REJECTED = 2  # repro.core.registry.PushRejected
    WIRE = 3           # WireError (malformed request reached the server)
    INTERNAL = 4       # anything else — surfaced as DeliveryError
    BUSY = 5           # admission control shed the request (retryable;
                       # surfaced as DeliveryError)


# ----------------------------------------------------------------- varints

def encode_uvarint(n: int) -> bytes:
    """LEB128 unsigned varint."""
    if n < 0:
        raise WireError(f"uvarint cannot encode negative value {n}")
    out = bytearray()
    while True:
        b = n & 0x7F
        n >>= 7
        if n:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def decode_uvarint(buf: bytes, off: int = 0) -> Tuple[int, int]:
    """Returns ``(value, new_offset)``; raises :class:`WireError` on
    truncation or a varint longer than 10 bytes (overflow guard)."""
    result = 0
    shift = 0
    for i in range(10):
        if off + i >= len(buf):
            raise WireError("truncated uvarint")
        b = buf[off + i]
        result |= (b & 0x7F) << shift
        if not b & 0x80:
            return result, off + i + 1
        shift += 7
    raise WireError("uvarint too long (>10 bytes)")


def _take(buf: bytes, off: int, n: int, what: str) -> Tuple[bytes, int]:
    if off + n > len(buf):
        raise WireError(f"truncated {what}: need {n} bytes at offset {off}, "
                        f"have {len(buf) - off}")
    return buf[off:off + n], off + n


# ------------------------------------------------------------------ frames

def encode_frame(ftype: FrameType, payload: bytes) -> bytes:
    return (MAGIC + bytes((VERSION, int(ftype)))
            + encode_uvarint(len(payload)) + payload)


def decode_frame(buf: bytes, off: int = 0,
                 expect: Optional[FrameType] = None
                 ) -> Tuple[FrameType, bytes, int]:
    """Decode one frame at ``off``; returns ``(type, payload, new_offset)``."""
    hdr, off = _take(buf, off, _HEADER, "frame header")
    if hdr[:2] != MAGIC:
        raise WireError(f"bad magic {hdr[:2]!r}")
    if hdr[2] != VERSION:
        raise WireError(f"unsupported wire version {hdr[2]}")
    try:
        ftype = FrameType(hdr[3])
    except ValueError:
        raise WireError(f"unknown frame type {hdr[3]}") from None
    size, off = decode_uvarint(buf, off)
    payload, off = _take(buf, off, size, f"{ftype.name} payload")
    if expect is not None and ftype is not expect:
        raise WireError(f"expected {expect.name} frame, got {ftype.name}")
    return ftype, payload, off


def _decode_single(buf: bytes, expect: FrameType) -> bytes:
    ftype, payload, off = decode_frame(buf, 0, expect=expect)
    if off != len(buf):
        raise WireError(f"{len(buf) - off} trailing bytes after "
                        f"{expect.name} frame")
    return payload


# ------------------------------------------------------------------- INDEX

def encode_index(t: CDMT) -> bytes:
    """Serialize a CDMT: params, leaf fps, then per-level fanout runs.

    Internal-node fingerprints are NOT shipped — they are a pure function of
    the leaves and the cut structure, so the decoder recomputes (and thereby
    verifies) them.
    """
    p = t.params
    out = bytearray()
    out += encode_uvarint(p.window)
    out += encode_uvarint(p.rule_bits)
    out += encode_uvarint(p.max_fanout)
    out += encode_uvarint(hashing.DIGEST_SIZE)
    out += encode_uvarint(len(t.levels))
    if t.levels:
        leaves = t.levels[0]
        out += encode_uvarint(len(leaves))
        for fp in leaves:
            out += fp
        for lvl_i in range(1, len(t.levels)):
            lvl = t.levels[lvl_i]
            out += encode_uvarint(len(lvl))
            for pfp in lvl:
                out += encode_uvarint(len(t.nodes[pfp].children))
    return encode_frame(FrameType.INDEX, bytes(out))


def decode_index(buf: bytes) -> CDMT:
    """Rebuild a CDMT from an INDEX frame, recomputing internal node ids."""
    payload = _decode_single(buf, FrameType.INDEX)
    off = 0
    window, off = decode_uvarint(payload, off)
    rule_bits, off = decode_uvarint(payload, off)
    max_fanout, off = decode_uvarint(payload, off)
    digest, off = decode_uvarint(payload, off)
    if digest != hashing.DIGEST_SIZE:
        raise WireError(f"digest size {digest} != {hashing.DIGEST_SIZE}")
    if window < 1 or max_fanout < 1:
        raise WireError("invalid CDMT params on wire")
    n_levels, off = decode_uvarint(payload, off)
    t = CDMT(params=CDMTParams(window=window, rule_bits=rule_bits,
                               max_fanout=max_fanout))
    if n_levels == 0:
        if off != len(payload):
            raise WireError("trailing bytes in empty INDEX payload")
        return t

    n_leaves, off = decode_uvarint(payload, off)
    level: List[bytes] = []
    for _ in range(n_leaves):
        fp, off = _take(payload, off, digest, "leaf fp")
        level.append(fp)
        if fp not in t.nodes:
            t.nodes[fp] = CDMTNode(fp=fp, children=(), is_leaf=True,
                                   n_leaves=1)
    t.levels.append(list(level))

    for _ in range(n_levels - 1):
        n_parents, off = decode_uvarint(payload, off)
        if n_parents == 0:
            raise WireError("empty CDMT level on wire")
        nxt: List[bytes] = []
        pos = 0
        for _ in range(n_parents):
            fanout, off = decode_uvarint(payload, off)
            if fanout == 0 or pos + fanout > len(level):
                raise WireError("level fanouts do not partition child level")
            kids = tuple(level[pos:pos + fanout])
            pos += fanout
            fp = hashing.node_fingerprint(kids)
            if fp not in t.nodes:
                t.nodes[fp] = CDMTNode(
                    fp=fp, children=kids, is_leaf=False,
                    n_leaves=sum(t.nodes[c].n_leaves for c in kids))
            nxt.append(fp)
        if pos != len(level):
            raise WireError("level fanouts do not cover child level")
        t.levels.append(list(nxt))
        level = nxt
    if len(level) != 1:
        raise WireError(f"top level has {len(level)} roots, expected 1")
    if off != len(payload):
        raise WireError("trailing bytes in INDEX payload")
    t.root = level[0]
    return t


# ------------------------------------------------------------------ RECIPE

def encode_recipe(r: Recipe) -> bytes:
    name = r.name.encode("utf-8")
    out = bytearray()
    out += encode_uvarint(len(name))
    out += name
    out += encode_uvarint(len(r.fps))
    for fp in r.fps:
        out += fp
    for size in r.sizes:
        out += encode_uvarint(size)
    return encode_frame(FrameType.RECIPE, bytes(out))


def decode_recipe(buf: bytes) -> Recipe:
    payload = _decode_single(buf, FrameType.RECIPE)
    off = 0
    name_len, off = decode_uvarint(payload, off)
    name_b, off = _take(payload, off, name_len, "recipe name")
    n, off = decode_uvarint(payload, off)
    fps: List[bytes] = []
    for _ in range(n):
        fp, off = _take(payload, off, hashing.DIGEST_SIZE, "recipe fp")
        fps.append(fp)
    sizes: List[int] = []
    for _ in range(n):
        s, off = decode_uvarint(payload, off)
        sizes.append(s)
    if off != len(payload):
        raise WireError("trailing bytes in RECIPE payload")
    return Recipe(name=name_b.decode("utf-8"), fps=fps, sizes=sizes)


# ------------------------------------------------------------- CHUNK_BATCH

def encode_chunk_batch(chunks: Mapping[bytes, bytes]) -> bytes:
    """Batch chunk payloads: ``n | (fp | uvarint(len) | data)*``."""
    out = bytearray()
    out += encode_uvarint(len(chunks))
    for fp, data in chunks.items():
        if len(fp) != hashing.DIGEST_SIZE:
            raise WireError(f"bad fingerprint length {len(fp)}")
        out += fp
        out += encode_uvarint(len(data))
        out += data
    return encode_frame(FrameType.CHUNK_BATCH, bytes(out))


def decode_chunk_batch(buf: bytes, verify: bool = True) -> Dict[bytes, bytes]:
    """Decode a batch; with ``verify`` each payload's blake2b must equal its
    wire fp (the transfer is authenticated end-to-end)."""
    payload = _decode_single(buf, FrameType.CHUNK_BATCH)
    off = 0
    n, off = decode_uvarint(payload, off)
    out: Dict[bytes, bytes] = {}
    for _ in range(n):
        fp, off = _take(payload, off, hashing.DIGEST_SIZE, "chunk fp")
        size, off = decode_uvarint(payload, off)
        data, off = _take(payload, off, size, "chunk data")
        if verify and hashing.chunk_fingerprint(data) != fp:
            raise WireError(f"chunk {fp.hex()[:12]} payload hash mismatch")
        out[fp] = data
    if off != len(payload):
        raise WireError("trailing bytes in CHUNK_BATCH payload")
    return out


# ------------------------------------------------- WANT / HAS / MISSING
#
# All three are fingerprint-list frames; they differ only in frame type
# (WANT requests payloads, HAS queries presence, MISSING is HAS's reply).

def _encode_fp_list(ftype: FrameType, fps: Sequence[bytes]) -> bytes:
    out = bytearray()
    out += encode_uvarint(len(fps))
    for fp in fps:
        if len(fp) != hashing.DIGEST_SIZE:
            raise WireError(f"bad fingerprint length {len(fp)}")
        out += fp
    return encode_frame(ftype, bytes(out))


def _decode_fp_list(buf: bytes, ftype: FrameType) -> List[bytes]:
    payload = _decode_single(buf, ftype)
    off = 0
    n, off = decode_uvarint(payload, off)
    fps: List[bytes] = []
    for _ in range(n):
        fp, off = _take(payload, off, hashing.DIGEST_SIZE,
                        f"{ftype.name.lower()} fp")
        fps.append(fp)
    if off != len(payload):
        raise WireError(f"trailing bytes in {ftype.name} payload")
    return fps


def encode_want(fps: Sequence[bytes]) -> bytes:
    return _encode_fp_list(FrameType.WANT, fps)


def decode_want(buf: bytes) -> List[bytes]:
    return _decode_fp_list(buf, FrameType.WANT)


def encode_has(fps: Sequence[bytes]) -> bytes:
    return _encode_fp_list(FrameType.HAS, fps)


def decode_has(buf: bytes) -> List[bytes]:
    return _decode_fp_list(buf, FrameType.HAS)


def encode_missing(fps: Sequence[bytes]) -> bytes:
    return _encode_fp_list(FrameType.MISSING, fps)


def decode_missing(buf: bytes) -> List[bytes]:
    return _decode_fp_list(buf, FrameType.MISSING)


# ---------------------------------------------------------------- PUSH_HDR

@dataclasses.dataclass
class PushHeader:
    lineage: str
    tag: str
    root: Optional[bytes]           # client-claimed CDMT root (None: empty
    parent_version: Optional[int]   # artifact — its CDMT has no root)
    params: Optional[CDMTParams] = None   # tree params the root was built
                                          # with (travel with the claim)


def encode_push_header(h: PushHeader) -> bytes:
    lin = h.lineage.encode("utf-8")
    tag = h.tag.encode("utf-8")
    out = bytearray()
    out += encode_uvarint(len(lin))
    out += lin
    out += encode_uvarint(len(tag))
    out += tag
    if h.root is None:
        out += encode_uvarint(0)
    else:
        if len(h.root) != hashing.DIGEST_SIZE:
            raise WireError(f"bad claimed-root length {len(h.root)}")
        out += encode_uvarint(1)
        out += h.root
        p = h.params if h.params is not None else CDMTParams()
        out += encode_uvarint(p.window)
        out += encode_uvarint(p.rule_bits)
        out += encode_uvarint(p.max_fanout)
    if h.parent_version is None:
        out += encode_uvarint(0)
    else:
        out += encode_uvarint(1)
        out += encode_uvarint(h.parent_version)
    return encode_frame(FrameType.PUSH_HDR, bytes(out))


def decode_push_header(buf: bytes) -> PushHeader:
    payload = _decode_single(buf, FrameType.PUSH_HDR)
    off = 0
    lin_len, off = decode_uvarint(payload, off)
    lin, off = _take(payload, off, lin_len, "push lineage")
    tag_len, off = decode_uvarint(payload, off)
    tag, off = _take(payload, off, tag_len, "push tag")
    has_root, off = decode_uvarint(payload, off)
    root: Optional[bytes] = None
    params: Optional[CDMTParams] = None
    if has_root:
        root, off = _take(payload, off, hashing.DIGEST_SIZE, "push root")
        window, off = decode_uvarint(payload, off)
        rule_bits, off = decode_uvarint(payload, off)
        max_fanout, off = decode_uvarint(payload, off)
        if window < 1 or max_fanout < 1:
            raise WireError("invalid CDMT params in PUSH_HDR")
        params = CDMTParams(window=window, rule_bits=rule_bits,
                            max_fanout=max_fanout)
    has_parent, off = decode_uvarint(payload, off)
    parent: Optional[int] = None
    if has_parent:
        parent, off = decode_uvarint(payload, off)
    if off != len(payload):
        raise WireError("trailing bytes in PUSH_HDR payload")
    return PushHeader(lineage=lin.decode("utf-8"), tag=tag.decode("utf-8"),
                      root=root, parent_version=parent, params=params)


# ------------------------------------------------------- TAGS / TAG_LIST

def _encode_str(s: str) -> bytes:
    b = s.encode("utf-8")
    return encode_uvarint(len(b)) + b


def _decode_str(payload: bytes, off: int, what: str) -> Tuple[str, int]:
    n, off = decode_uvarint(payload, off)
    raw, off = _take(payload, off, n, what)
    return raw.decode("utf-8"), off


def encode_tags_request(lineage: str) -> bytes:
    return encode_frame(FrameType.TAGS, _encode_str(lineage))


def decode_tags_request(buf: bytes) -> str:
    payload = _decode_single(buf, FrameType.TAGS)
    lineage, off = _decode_str(payload, 0, "tags lineage")
    if off != len(payload):
        raise WireError("trailing bytes in TAGS payload")
    return lineage


def encode_tag_list(tags: Sequence[str]) -> bytes:
    out = bytearray()
    out += encode_uvarint(len(tags))
    for t in tags:
        out += _encode_str(t)
    return encode_frame(FrameType.TAG_LIST, bytes(out))


def decode_tag_list(buf: bytes) -> List[str]:
    payload = _decode_single(buf, FrameType.TAG_LIST)
    off = 0
    n, off = decode_uvarint(payload, off)
    tags: List[str] = []
    for _ in range(n):
        t, off = _decode_str(payload, off, "tag name")
        tags.append(t)
    if off != len(payload):
        raise WireError("trailing bytes in TAG_LIST payload")
    return tags


# ------------------------------------------------------------------- ERROR

def encode_error(code: ErrorCode, message: str) -> bytes:
    return encode_frame(FrameType.ERROR,
                        encode_uvarint(int(code)) + _encode_str(message))


def decode_error(buf: bytes) -> Tuple[ErrorCode, str]:
    payload = _decode_single(buf, FrameType.ERROR)
    raw_code, off = decode_uvarint(payload, 0)
    try:
        code = ErrorCode(raw_code)
    except ValueError:
        code = ErrorCode.INTERNAL      # future codes degrade gracefully
    message, off = _decode_str(payload, off, "error message")
    if off != len(payload):
        raise WireError("trailing bytes in ERROR payload")
    return code, message


# ----------------------------------------------------------------- RECEIPT

def encode_receipt(r: "PushReceipt") -> bytes:
    out = bytearray()
    out += _encode_str(r.lineage)
    out += _encode_str(r.tag)
    out += encode_uvarint(r.version)
    out += encode_uvarint(r.chunks_received)
    out += encode_uvarint(r.bytes_received)
    out += encode_uvarint(r.index_bytes)
    if r.root is None:                 # empty artifact: its CDMT has no root
        out += encode_uvarint(0)
    else:
        if len(r.root) != hashing.DIGEST_SIZE:
            raise WireError(f"bad receipt root length {len(r.root)}")
        out += encode_uvarint(1)
        out += r.root
    out += encode_uvarint(r.nodes_created)
    out += encode_uvarint(r.nodes_hashed)
    out += encode_uvarint(r.hash_calls)
    out += encode_uvarint(1 if r.deduplicated else 0)
    return encode_frame(FrameType.RECEIPT, bytes(out))


def decode_receipt(buf: bytes) -> "PushReceipt":
    from repro.core.registry import PushReceipt
    payload = _decode_single(buf, FrameType.RECEIPT)
    off = 0
    lineage, off = _decode_str(payload, off, "receipt lineage")
    tag, off = _decode_str(payload, off, "receipt tag")
    version, off = decode_uvarint(payload, off)
    chunks_received, off = decode_uvarint(payload, off)
    bytes_received, off = decode_uvarint(payload, off)
    index_bytes, off = decode_uvarint(payload, off)
    has_root, off = decode_uvarint(payload, off)
    root = None
    if has_root:
        root, off = _take(payload, off, hashing.DIGEST_SIZE, "receipt root")
    nodes_created, off = decode_uvarint(payload, off)
    nodes_hashed, off = decode_uvarint(payload, off)
    hash_calls, off = decode_uvarint(payload, off)
    dedup, off = decode_uvarint(payload, off)
    if off != len(payload):
        raise WireError("trailing bytes in RECEIPT payload")
    return PushReceipt(lineage=lineage, tag=tag, version=version,
                       chunks_received=chunks_received,
                       bytes_received=bytes_received,
                       index_bytes=index_bytes, root=root,
                       nodes_created=nodes_created,
                       nodes_hashed=nodes_hashed, hash_calls=hash_calls,
                       deduplicated=bool(dedup))


# -------------------------------------------------------------------- INFO

def encode_info(response_batch_chunks: int) -> bytes:
    return encode_frame(FrameType.INFO,
                        encode_uvarint(response_batch_chunks))


def decode_info(buf: bytes) -> int:
    payload = _decode_single(buf, FrameType.INFO)
    val, off = decode_uvarint(payload, 0)
    if off != len(payload):
        raise WireError("trailing bytes in INFO payload")
    return val


# ----------------------------------------------------------------- METRICS
#
# A live metrics scrape: the payload is one UTF-8 JSON document — the
# ``repro.obs.MetricsSnapshot.to_json`` form (``{"v": 1, "families":
# [...]}``).  Keeping the payload opaque JSON (rather than a binary schema)
# means the metric catalog can grow without a wire version bump; the frame
# header + length still make it a normal self-delimiting frame on the
# socket, and ``Op.METRICS`` answers with exactly one of these.

def encode_metrics(snapshot_json: bytes) -> bytes:
    return encode_frame(FrameType.METRICS, snapshot_json)


def decode_metrics(buf: bytes) -> bytes:
    """The snapshot JSON bytes (decode with
    :meth:`repro.obs.MetricsSnapshot.from_json`)."""
    return _decode_single(buf, FrameType.METRICS)


# ------------------------------------------- SHIP / RECORD / REPL_ACK
#
# Journal replication (standby follows primary).  A SHIP request names the
# replica, the epoch it believes the primary is in, the record offset to
# resume from, and a record budget; the answer is one REPL_ACK frame (the
# primary's epoch + log head) followed by RECORD frames, each wrapping one
# checksummed journal record verbatim.  A budget of 0 is a pure status
# probe — the freshness query replica-aware transports use for promotion.

def encode_ship(replica: str, epoch: int, start: int, limit: int) -> bytes:
    return encode_frame(FrameType.SHIP,
                        _encode_str(replica) + encode_uvarint(epoch)
                        + encode_uvarint(start) + encode_uvarint(limit))


def decode_ship(buf: bytes) -> Tuple[str, int, int, int]:
    """``(replica, epoch, start_offset, limit)``."""
    payload = _decode_single(buf, FrameType.SHIP)
    replica, off = _decode_str(payload, 0, "ship replica")
    epoch, off = decode_uvarint(payload, off)
    start, off = decode_uvarint(payload, off)
    limit, off = decode_uvarint(payload, off)
    if off != len(payload):
        raise WireError("trailing bytes in SHIP payload")
    return replica, epoch, start, limit


def encode_record_frame(raw_record: bytes) -> bytes:
    """Wrap one already-encoded checksummed record (the bytes
    :func:`encode_record` produced — what a :class:`ReplicationLog`
    stores) for transit."""
    return encode_frame(FrameType.RECORD, raw_record)


def decode_record_frame(buf: bytes) -> Tuple[int, bytes, bytes]:
    """Unwrap and **verify** one shipped record: the inner checksum must
    match and the record must fill the frame exactly.  Returns ``(rtype,
    payload, raw)`` — the arguments a standby replays plus the verified
    encoding itself, so the standby re-journals the primary's exact bytes
    without re-encoding."""
    raw = _decode_single(buf, FrameType.RECORD)
    rtype, payload, noff = decode_record(raw, 0)
    if noff != len(raw):
        raise WireError(f"{len(raw) - noff} trailing bytes after shipped "
                        f"record")
    return rtype, payload, raw


def encode_repl_ack(replica: str, epoch: int, offset: int) -> bytes:
    return encode_frame(FrameType.REPL_ACK,
                        _encode_str(replica) + encode_uvarint(epoch)
                        + encode_uvarint(offset))


def decode_repl_ack(buf: bytes) -> Tuple[str, int, int]:
    """``(replica, epoch, offset)`` — a replica's applied position (request
    direction) or the primary's log head (response direction)."""
    payload = _decode_single(buf, FrameType.REPL_ACK)
    replica, off = _decode_str(payload, 0, "repl-ack replica")
    epoch, off = decode_uvarint(payload, off)
    offset, off = decode_uvarint(payload, off)
    if off != len(payload):
        raise WireError("trailing bytes in REPL_ACK payload")
    return replica, epoch, offset


# ---------------------------------------------------------------- SNAPSHOT
#
# Snapshot bootstrap (fresh standby joins without replaying history).  A
# SNAPSHOT_SHIP request carries one SNAPSHOT frame naming the replica (epoch
# and offset are 0 — the standby knows nothing yet); the answer is one
# SNAPSHOT frame (the primary's epoch and the log-head offset the shipped
# state corresponds to) followed by RECORD frames wrapping the primary's
# collapsed state records.  After applying them, the standby resumes
# ordinary JOURNAL_SHIP from the header's offset.

def encode_snapshot(replica: str, epoch: int, offset: int) -> bytes:
    return encode_frame(FrameType.SNAPSHOT,
                        _encode_str(replica) + encode_uvarint(epoch)
                        + encode_uvarint(offset))


def decode_snapshot(buf: bytes) -> Tuple[str, int, int]:
    """``(replica, epoch, offset)`` — the requesting standby's name (request
    direction) or the primary's epoch + resume offset (response header)."""
    payload = _decode_single(buf, FrameType.SNAPSHOT)
    replica, off = _decode_str(payload, 0, "snapshot replica")
    epoch, off = decode_uvarint(payload, off)
    offset, off = decode_uvarint(payload, off)
    if off != len(payload):
        raise WireError("trailing bytes in SNAPSHOT payload")
    return replica, epoch, offset


# --------------------------------------------------------------- envelopes
#
# The socket protocol.  A request envelope routes an Op plus lineage/tag to
# a handler and carries the operation's body frames; a response envelope is
# a status byte plus a frame count, then length-prefixed frames.  The
# response *header* goes out before any frame is built, so a server streams
# a large WANT answer batch-by-batch while the client decodes in lockstep.

REQUEST_MAGIC = b"CQ"
RESPONSE_MAGIC = b"CR"
STATUS_OK = 0
STATUS_ERROR = 1

# sanity bounds a stream reader enforces before allocating: a corrupt or
# hostile length prefix must not make an endpoint buffer gigabytes
MAX_ROUTING_BYTES = 4096           # lineage / tag strings
MAX_ENVELOPE_FRAMES = 65536
MAX_FRAME_BYTES = 256 << 20        # one frame (a CHUNK_BATCH tops out far
                                   # below this at sane batch settings)


def check_request_header(hdr: bytes) -> Op:
    """Validate a 4-byte request envelope header; returns the op.  Shared
    by the buffer decoder and the socket stream reader."""
    if hdr[:2] != REQUEST_MAGIC:
        raise WireError(f"bad request magic {hdr[:2]!r}")
    if hdr[2] != VERSION:
        raise WireError(f"unsupported request version {hdr[2]}")
    try:
        return Op(hdr[3])
    except ValueError:
        raise WireError(f"unknown request op {hdr[3]}") from None


def check_response_header(hdr: bytes) -> int:
    """Validate a 4-byte response envelope header; returns the status."""
    if hdr[:2] != RESPONSE_MAGIC:
        raise WireError(f"bad response magic {hdr[:2]!r}")
    if hdr[2] != VERSION:
        raise WireError(f"unsupported response version {hdr[2]}")
    status = hdr[3]
    if status not in (STATUS_OK, STATUS_ERROR):
        raise WireError(f"unknown response status {status}")
    return status


def encode_request(op: Op, lineage: str, tag: str,
                   frames: Sequence[bytes] = ()) -> bytes:
    out = bytearray()
    out += REQUEST_MAGIC
    out.append(VERSION)
    out.append(int(op))
    out += _encode_str(lineage)
    out += _encode_str(tag)
    out += encode_uvarint(len(frames))
    for f in frames:
        out += encode_uvarint(len(f))
        out += f
    return bytes(out)


def decode_request(buf: bytes) -> Tuple[Op, str, str, List[bytes]]:
    hdr, off = _take(buf, 0, 4, "request header")
    op = check_request_header(hdr)
    lineage, off = _decode_str(buf, off, "request lineage")
    tag, off = _decode_str(buf, off, "request tag")
    n, off = decode_uvarint(buf, off)
    frames: List[bytes] = []
    for _ in range(n):
        size, off = decode_uvarint(buf, off)
        f, off = _take(buf, off, size, "request frame")
        frames.append(f)
    if off != len(buf):
        raise WireError(f"{len(buf) - off} trailing bytes after request")
    return op, lineage, tag, frames


def encode_response_header(status: int, n_frames: int) -> bytes:
    return (RESPONSE_MAGIC + bytes((VERSION, status))
            + encode_uvarint(n_frames))


def decode_response_header(buf: bytes, off: int = 0) -> Tuple[int, int, int]:
    """``(status, n_frames, new_offset)``."""
    hdr, off = _take(buf, off, 4, "response header")
    status = check_response_header(hdr)
    n, off = decode_uvarint(buf, off)
    return status, n, off


def encode_response(status: int, frames: Sequence[bytes]) -> bytes:
    """Whole response in one buffer (tests / non-streaming paths)."""
    out = bytearray(encode_response_header(status, len(frames)))
    for f in frames:
        out += encode_uvarint(len(f))
        out += f
    return bytes(out)


def decode_response(buf: bytes) -> Tuple[int, List[bytes]]:
    status, n, off = decode_response_header(buf, 0)
    frames: List[bytes] = []
    for _ in range(n):
        size, off = decode_uvarint(buf, off)
        f, off = _take(buf, off, size, "response frame")
        frames.append(f)
    if off != len(buf):
        raise WireError(f"{len(buf) - off} trailing bytes after response")
    return status, frames


# ------------------------------------------------------ multiplexed envelopes
#
# The async data plane interleaves many request/response streams over one
# TCP connection.  Each direction is a sequence of self-delimiting
# *messages* that carry a **stream id** so an endpoint can route them:
#
#   request  ``"CM" | version | op | stream_id(4) | str(lineage) | str(tag)
#             | u(n_frames) | (u(len) frame)*``
#   response ``"CS" | version | msg_type | stream_id(4) | ...`` where
#     ``msg_type == MUX_HEADER`` continues ``status(1) | u(n_frames)``
#     (commits the stream's status and total frame count, exactly like a
#     ``"CR"`` header) and ``msg_type == MUX_FRAME`` continues
#     ``u(len) | frame`` (one body frame of that stream).
#
# The stream id is a fixed-width 4-byte big-endian unsigned integer — not a
# varint — so envelope overhead is independent of the id value and a pull
# plan's byte quote stays exact without knowing which ids the transport
# will allocate.  FRAME messages of *different* streams may interleave
# freely; FRAME messages of one stream arrive in order, and the stream
# completes when ``n_frames`` of them have arrived.

MUX_REQUEST_MAGIC = b"CM"
MUX_RESPONSE_MAGIC = b"CS"
MUX_STREAM_ID_BYTES = 4
MAX_STREAM_ID = (1 << 32) - 1
_MUX_HEADER_LEN = 8        # magic(2) + version + op/msg_type + stream_id(4)

MUX_HEADER = 0             # response message types
MUX_FRAME = 1


def check_mux_request_header(hdr: bytes) -> Tuple[Op, int]:
    """Validate an 8-byte mux request header; returns ``(op, stream_id)``."""
    if hdr[:2] != MUX_REQUEST_MAGIC:
        raise WireError(f"bad mux request magic {hdr[:2]!r}")
    if hdr[2] != VERSION:
        raise WireError(f"unsupported mux request version {hdr[2]}")
    try:
        op = Op(hdr[3])
    except ValueError:
        raise WireError(f"unknown mux request op {hdr[3]}") from None
    return op, int.from_bytes(hdr[4:8], "big")


def check_mux_response_header(hdr: bytes) -> Tuple[int, int]:
    """Validate an 8-byte mux response message header; returns
    ``(msg_type, stream_id)``."""
    if hdr[:2] != MUX_RESPONSE_MAGIC:
        raise WireError(f"bad mux response magic {hdr[:2]!r}")
    if hdr[2] != VERSION:
        raise WireError(f"unsupported mux response version {hdr[2]}")
    if hdr[3] not in (MUX_HEADER, MUX_FRAME):
        raise WireError(f"unknown mux message type {hdr[3]}")
    return hdr[3], int.from_bytes(hdr[4:8], "big")


def _stream_id_bytes(stream_id: int) -> bytes:
    if not 0 <= stream_id <= MAX_STREAM_ID:
        raise WireError(f"stream id {stream_id} out of range")
    return stream_id.to_bytes(MUX_STREAM_ID_BYTES, "big")


def encode_mux_request(op: Op, stream_id: int, lineage: str, tag: str,
                       frames: Sequence[bytes] = ()) -> bytes:
    out = bytearray()
    out += MUX_REQUEST_MAGIC
    out.append(VERSION)
    out.append(int(op))
    out += _stream_id_bytes(stream_id)
    out += _encode_str(lineage)
    out += _encode_str(tag)
    out += encode_uvarint(len(frames))
    for f in frames:
        out += encode_uvarint(len(f))
        out += f
    return bytes(out)


def decode_mux_request(buf: bytes) -> Tuple[Op, int, str, str, List[bytes]]:
    hdr, off = _take(buf, 0, _MUX_HEADER_LEN, "mux request header")
    op, stream_id = check_mux_request_header(hdr)
    lineage, off = _decode_str(buf, off, "mux request lineage")
    tag, off = _decode_str(buf, off, "mux request tag")
    n, off = decode_uvarint(buf, off)
    frames: List[bytes] = []
    for _ in range(n):
        size, off = decode_uvarint(buf, off)
        f, off = _take(buf, off, size, "mux request frame")
        frames.append(f)
    if off != len(buf):
        raise WireError(f"{len(buf) - off} trailing bytes after mux request")
    return op, stream_id, lineage, tag, frames


def encode_mux_response_header(stream_id: int, status: int,
                               n_frames: int) -> bytes:
    """The HEADER message: commits a stream's status + total frame count."""
    if status not in (STATUS_OK, STATUS_ERROR):
        raise WireError(f"unknown response status {status}")
    return (MUX_RESPONSE_MAGIC + bytes((VERSION, MUX_HEADER))
            + _stream_id_bytes(stream_id) + bytes((status,))
            + encode_uvarint(n_frames))


def encode_mux_response_frame(stream_id: int, frame: bytes) -> bytes:
    """One FRAME message: a length-prefixed body frame of ``stream_id``."""
    return (MUX_RESPONSE_MAGIC + bytes((VERSION, MUX_FRAME))
            + _stream_id_bytes(stream_id) + encode_uvarint(len(frame))
            + frame)


def decode_mux_response_header(buf: bytes, off: int = 0
                               ) -> Tuple[int, int, int, int]:
    """Decode one HEADER message; ``(stream_id, status, n_frames, off)``."""
    hdr, off = _take(buf, off, _MUX_HEADER_LEN, "mux response header")
    msg_type, stream_id = check_mux_response_header(hdr)
    if msg_type != MUX_HEADER:
        raise WireError(f"expected mux HEADER message, got type {msg_type}")
    status_b, off = _take(buf, off, 1, "mux response status")
    status = status_b[0]
    if status not in (STATUS_OK, STATUS_ERROR):
        raise WireError(f"unknown response status {status}")
    n, off = decode_uvarint(buf, off)
    return stream_id, status, n, off


def decode_mux_response_frame(buf: bytes, off: int = 0
                              ) -> Tuple[int, bytes, int]:
    """Decode one FRAME message; ``(stream_id, frame, new_offset)``."""
    hdr, off = _take(buf, off, _MUX_HEADER_LEN, "mux frame header")
    msg_type, stream_id = check_mux_response_header(hdr)
    if msg_type != MUX_FRAME:
        raise WireError(f"expected mux FRAME message, got type {msg_type}")
    size, off = decode_uvarint(buf, off)
    frame, off = _take(buf, off, size, "mux frame body")
    return stream_id, frame, off


# ----------------------------------------------------------------- records
#
# Checksummed records: the same varint framing as frames, plus a trailing
# blake2b checksum over the whole record body.  A frame is self-verifying
# only when its payload is (INDEX recomputes node ids); a *record* is
# self-verifying for arbitrary payloads, which is what an append-only log
# needs to detect torn tails after a crash.  Used by the registry journal
# (:mod:`repro.core.journal`).

RECORD_MAGIC = b"CL"
RECORD_CHECK_SIZE = 8


def encode_record(rtype: int, payload: bytes) -> bytes:
    """``magic | version | type | uvarint(len) | payload | blake2b-8``."""
    if not 0 <= rtype <= 255:
        raise WireError(f"record type {rtype} out of range")
    body = (RECORD_MAGIC + bytes((VERSION, rtype))
            + encode_uvarint(len(payload)) + payload)
    return body + hashing.checksum(body, RECORD_CHECK_SIZE)


def decode_record(buf: bytes, off: int = 0) -> Tuple[int, bytes, int]:
    """Decode one checksummed record at ``off``; returns ``(type, payload,
    new_offset)``.  Raises :class:`WireError` on truncation or checksum
    mismatch — for an append-only log both mean the same thing: the tail
    after ``off`` is torn and must be discarded."""
    hdr, noff = _take(buf, off, 4, "record header")
    if hdr[:2] != RECORD_MAGIC:
        raise WireError(f"bad record magic {hdr[:2]!r}")
    if hdr[2] != VERSION:
        raise WireError(f"unsupported record version {hdr[2]}")
    rtype = hdr[3]
    size, noff = decode_uvarint(buf, noff)
    payload, noff = _take(buf, noff, size, "record payload")
    check, noff = _take(buf, noff, RECORD_CHECK_SIZE, "record checksum")
    if hashing.checksum(buf[off:noff - RECORD_CHECK_SIZE],
                        RECORD_CHECK_SIZE) != check:
        raise WireError("record checksum mismatch")
    return rtype, payload, noff


# ------------------------------------------------------------------ sizing

def uvarint_len(n: int) -> int:
    """Encoded length of ``n`` as a LEB128 uvarint, without encoding it."""
    size = 1
    while n > 0x7F:
        n >>= 7
        size += 1
    return size


def _frame_len(payload_len: int) -> int:
    return _HEADER + uvarint_len(payload_len) + payload_len


def index_wire_bytes(t: CDMT) -> int:
    """Actual serialized size of the index (replaces the old estimate).
    The index is KB-sized, so encoding it to measure is cheap."""
    return len(encode_index(t))


def recipe_wire_bytes(r: Recipe) -> int:
    payload = (uvarint_len(len(r.name.encode("utf-8")))
               + len(r.name.encode("utf-8"))
               + uvarint_len(len(r.fps))
               + len(r.fps) * hashing.DIGEST_SIZE
               + sum(uvarint_len(s) for s in r.sizes))
    return _frame_len(payload)


def chunk_batch_wire_bytes(chunks: Mapping[bytes, bytes]) -> int:
    """Exact ``len(encode_chunk_batch(chunks))`` computed arithmetically —
    measurement must not copy every chunk payload into a throwaway frame."""
    payload = uvarint_len(len(chunks)) + sum(
        hashing.DIGEST_SIZE + uvarint_len(len(d)) + len(d)
        for d in chunks.values())
    return _frame_len(payload)


def chunk_batch_frame_lens(sizes: Sequence[int],
                           batch_chunks: int) -> List[int]:
    """Exact per-frame CHUNK_BATCH lengths for payloads of ``sizes`` split
    into frames of ``batch_chunks`` — from sizes alone.  The socket path
    needs the individual frame lengths (each one carries an envelope length
    prefix), not just their sum."""
    batch_chunks = max(1, batch_chunks)
    lens: List[int] = []
    for start in range(0, len(sizes), batch_chunks):
        part = sizes[start:start + batch_chunks]
        payload = uvarint_len(len(part)) + sum(
            hashing.DIGEST_SIZE + uvarint_len(s) + s for s in part)
        lens.append(_frame_len(payload))
    return lens


def chunk_batches_wire_bytes(sizes: Sequence[int], batch_chunks: int) -> int:
    """Exact CHUNK_BATCH bytes for payloads of ``sizes`` delivered in frames
    of ``batch_chunks`` — from sizes alone, so a pull *plan* can quote its
    expected wire cost before a single payload is read."""
    return sum(chunk_batch_frame_lens(sizes, batch_chunks))


def request_envelope_bytes(lineage: str, tag: str,
                           frame_lens: Sequence[int]) -> int:
    """Exact ``len(encode_request(op, lineage, tag, frames))`` from the
    body-frame lengths alone (the op byte is fixed-width)."""
    lin = len(lineage.encode("utf-8"))
    tg = len(tag.encode("utf-8"))
    return (4 + uvarint_len(lin) + lin + uvarint_len(tg) + tg
            + uvarint_len(len(frame_lens))
            + sum(uvarint_len(n) + n for n in frame_lens))


def response_envelope_bytes(frame_lens: Sequence[int]) -> int:
    """Exact ``len(encode_response(status, frames))`` from frame lengths."""
    return (4 + uvarint_len(len(frame_lens))
            + sum(uvarint_len(n) + n for n in frame_lens))


def mux_request_envelope_bytes(lineage: str, tag: str,
                               frame_lens: Sequence[int]) -> int:
    """Exact ``len(encode_mux_request(op, sid, lineage, tag, frames))`` from
    the body-frame lengths alone — the stream id is fixed-width, so the
    size is independent of which id the transport allocates."""
    lin = len(lineage.encode("utf-8"))
    tg = len(tag.encode("utf-8"))
    return (_MUX_HEADER_LEN + uvarint_len(lin) + lin + uvarint_len(tg) + tg
            + uvarint_len(len(frame_lens))
            + sum(uvarint_len(n) + n for n in frame_lens))


def mux_response_envelope_bytes(frame_lens: Sequence[int]) -> int:
    """Exact total bytes of one complete mux response stream (the HEADER
    message plus one FRAME message per body frame) from frame lengths
    alone — what a pull plan quotes for the async transport."""
    return (_MUX_HEADER_LEN + 1 + uvarint_len(len(frame_lens))
            + sum(_MUX_HEADER_LEN + uvarint_len(n) + n
                  for n in frame_lens))
