"""Concurrent registry frontend — serves many simultaneous pullers.

Wraps a ``repro.core.registry.Registry`` behind the wire format:

  * every response is a serialized frame, and every byte that crosses the
    boundary is metered (``egress_bytes`` / ``ingress_bytes`` are *actual*
    frame lengths, not estimates);
  * chunk reads go through the tiered LRU cache (:mod:`repro.delivery.cache`);
  * identical in-flight chunk requests **coalesce**: when N pullers ask for
    the same fingerprint concurrently, one thread performs the store/cache
    read and the rest wait on its result (``coalesced_reads`` counts the
    piggy-backers) — under a thundering herd of upgrades the chunk log sees
    the working set once;
  * chunk responses are **batched**: a WANT list is answered with one or more
    CHUNK_BATCH frames of at most ``max_batch_chunks`` chunks, so a session
    can pipeline decode/ingest against later batches;
  * error paths are protocol-level: unknown lineages/tags surface as
    :class:`repro.core.errors.DeliveryError`, rejected pushes as
    :class:`repro.core.registry.PushRejected` — never a bare ``KeyError``.
    (Unknown fingerprints in a WANT are still silently omitted; the session
    layer decides whether absence is an error.)

Accounting is metrics-first: every handler increments ``registry_*`` series
in the server's :class:`~repro.obs.MetricsRegistry` (request counts and
latency histograms by ``op``, egress/ingress byte counters, an in-flight
gauge, per-replica standby lag — catalog in ``docs/OBSERVABILITY.md``), and
:class:`ServerStats` / :meth:`RegistryServer.snapshot` are *adapters* built
from those same series, field-compatible with the original ad-hoc
dataclass.  The metrics registry is internally locked, which also closes
the old unsynchronized-increment hazard under the threaded socket server.
:meth:`RegistryServer.handle_metrics` serves the whole registry (server +
cache + core) as one METRICS frame for the ``Op.METRICS`` scrape.

When the wrapped registry is directory-backed, an accepted ``handle_push``
is durable before the receipt returns (chunk fsync + journaled commit — see
:mod:`repro.core.registry`).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.errors import DeliveryError
from repro.core.registry import PushReceipt, Registry
from repro.core.store import Recipe
from repro.obs import MetricsRegistry

from . import wire
from .cache import DEFAULT_CAPACITY, TieredChunkCache

# every request op the frontend answers (labels of registry_requests_total)
_OPS = ("index", "recipe", "want", "has", "tags", "ship", "repl_ack",
        "push", "metrics", "snapshot")


@dataclasses.dataclass
class ServerStats:
    egress_bytes: int = 0          # serialized frames out (index/recipe/chunks)
    ingress_bytes: int = 0         # serialized frames in (wants/pushes)
    index_requests: int = 0
    recipe_requests: int = 0
    want_requests: int = 0
    has_requests: int = 0          # HAS presence queries answered
    tags_requests: int = 0         # TAGS listing queries answered
    ship_requests: int = 0         # JOURNAL_SHIP requests answered
    records_shipped: int = 0       # journal records streamed to standbys
    repl_acks: int = 0             # REPL_ACK progress reports received
    snapshot_requests: int = 0     # SNAPSHOT_SHIP bootstrap streams served
    chunks_served: int = 0
    chunk_bytes_served: int = 0
    store_reads: int = 0           # chunk reads that reached cache/store
    coalesced_reads: int = 0       # piggy-backed on an identical in-flight read
    pushes: int = 0
    warmed_chunks: int = 0         # cache entries pre-loaded at startup
    warm_hits: int = 0             # cache hits served by a warmed entry

    def snapshot(self) -> "ServerStats":
        return dataclasses.replace(self)


class _InFlight:
    __slots__ = ("event", "value", "error")

    def __init__(self):
        self.event = threading.Event()
        self.value: Optional[bytes] = None
        self.error: Optional[BaseException] = None


class RegistryServer:
    """Thread-safe wire frontend over an in-process ``Registry``."""

    def __init__(self, registry: Registry,
                 cache_bytes: int = DEFAULT_CAPACITY,
                 max_batch_chunks: int = 64,
                 warm_start: bool = True,
                 warm_scan_limit: int = 50_000,
                 metrics: Optional[MetricsRegistry] = None):
        self.registry = registry
        # one registry per server by default: the core Registry's own
        # metrics, so a scrape covers commit latency + frontend + cache in
        # a single snapshot.  Independent servers over different registries
        # therefore never share counters.
        if metrics is None:
            metrics = getattr(registry, "metrics", None)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.cache = TieredChunkCache(registry.store.chunks, cache_bytes,
                                      metrics=self.metrics)
        self.max_batch_chunks = max_batch_chunks
        self._stats_lock = threading.Lock()       # legacy name; unused fields
        self._registry_lock = threading.RLock()   # Registry itself is not MT-safe
        self._inflight: Dict[bytes, _InFlight] = {}  # guarded-by: _inflight_lock
        self._inflight_lock = threading.Lock()
        # replica name -> last acked replication offset (observability: a
        # primary can report standby lag without polling the standbys)
        self.replica_offsets: Dict[str, int] = {}  # guarded-by: _registry_lock
        m = self.metrics
        req = m.counter("registry_requests_total",
                        "requests answered by the registry frontend",
                        ("op",))
        lat = m.histogram("registry_request_seconds",
                          "registry frontend request latency", ("op",))
        self._m_req = {op: req.labels(op) for op in _OPS}
        self._m_lat = {op: lat.labels(op) for op in _OPS}
        self._m_egress = m.counter(
            "registry_egress_bytes_total",
            "serialized frame bytes out (index/recipe/chunks)").labels()
        self._m_ingress = m.counter(
            "registry_ingress_bytes_total",
            "serialized frame bytes in (wants/pushes)").labels()
        self._m_chunks = m.counter(
            "registry_chunks_served_total", "chunk payloads served").labels()
        self._m_chunk_bytes = m.counter(
            "registry_chunk_bytes_served_total",
            "chunk payload bytes served").labels()
        self._m_store_reads = m.counter(
            "registry_store_reads_total",
            "chunk reads that reached cache/store").labels()
        self._m_coalesced = m.counter(
            "registry_coalesced_reads_total",
            "reads piggy-backed on an identical in-flight read").labels()
        self._m_records_shipped = m.counter(
            "registry_records_shipped_total",
            "journal records streamed to standbys").labels()
        self._m_inflight_gauge = m.gauge(
            "registry_inflight_requests",
            "requests currently being answered").labels()
        self._m_lag = m.gauge(
            "replication_standby_lag",
            "primary log head minus the replica's last acked offset "
            "(records)", ("replica",))
        if warm_start and registry.store.chunks.directory is not None:
            self._warm_from_store(warm_scan_limit)

    @contextlib.contextmanager
    def _track(self, op: str):
        """Meter one request: count by op, time it, track in-flight."""
        self._m_inflight_gauge.inc()
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self._m_lat[op].observe(time.perf_counter() - t0)
            self._m_req[op].inc()
            self._m_inflight_gauge.dec()

    def _warm_from_store(self, scan_limit: int) -> int:
        """Pre-load the memory tier from the recovered chunk index so a
        restarted registry serves its first wave from RAM instead of cold
        (ROADMAP: "registry restart under load").  Most recently appended
        chunks first — the heads of each lineage are what pullers hit —
        until the cache's capacity budget is full.

        A chunk too large for the remaining budget is *skipped*, not a stop
        condition: smaller (older) chunks behind it may still fit, so one
        big recent chunk must not leave the rest of the budget cold.  The
        index sizes are known up-front, so a skip costs no chunk read; the
        walk is bounded by ``scan_limit`` entries so startup stays O(bounded)
        even over a huge store whose budget filled early."""
        store = self.registry.store.chunks
        entries = sorted(store.index_entries(),
                         key=lambda e: e[1], reverse=True)  # offset desc
        warmed = 0
        for fp, _off, size in entries[:max(0, scan_limit)]:
            free = self.cache.capacity_bytes - self.cache.resident_bytes
            if free <= 0:
                break
            if size > free:
                continue                   # skip-and-continue, no read done
            if self.cache.warm(fp, store.get(fp)):
                warmed += 1
        return warmed

    # ------------------------------------------------------------ index/recipe

    # api-boundary
    def get_index(self, lineage: str, tag: str) -> bytes:
        """Serialized INDEX frame for ``lineage:tag``.  An unknown lineage or
        tag raises the protocol-level :class:`repro.core.errors.DeliveryError`
        (never a bare ``KeyError``), so wire clients see a clean error."""
        with self._track("index"):
            with self._registry_lock:
                idx = self.registry.index_for_tag(lineage, tag)
                frame = wire.encode_index(idx)
            self._m_egress.inc(len(frame))
            return frame

    # api-boundary
    def get_latest_index(self, lineage: str) -> Optional[bytes]:
        """Serialized INDEX frame of the lineage head, or None (new lineage)."""
        with self._registry_lock:
            idx = self.registry.latest_index(lineage)
            frame = wire.encode_index(idx) if idx is not None else None
        if frame is not None:
            with self._track("index"):
                self._m_egress.inc(len(frame))
        return frame

    # api-boundary
    def get_recipe(self, lineage: str, tag: str) -> bytes:
        """Serialized RECIPE frame; :class:`DeliveryError` when unknown."""
        with self._track("recipe"):
            with self._registry_lock:
                frame = wire.encode_recipe(
                    self.registry.recipe_for(lineage, tag))
            self._m_egress.inc(len(frame))
            return frame

    # ----------------------------------------------------------------- chunks

    # api-boundary
    def handle_want(self, want_frame: bytes) -> List[bytes]:
        """Answer a WANT frame with batched CHUNK_BATCH frames.

        Unknown fingerprints are silently omitted (the client's decode sees
        which fps arrived); the session layer decides whether absence is an
        error.
        """
        _, frames = self.want_plan(want_frame)
        return list(frames)

    # api-boundary
    def want_plan(self, want_frame: bytes
                  ) -> Tuple[int, Iterable[bytes]]:
        """``(n_frames, frame iterator)`` for one WANT — the streaming form
        of :meth:`handle_want`.  The frame count is known before a single
        chunk is read (it depends only on the want length and the batch
        split), so a socket server can commit a response header and then
        write each CHUNK_BATCH as it is built, overlapping store reads with
        the client's decode of earlier batches."""
        fps = wire.decode_want(want_frame)
        self._m_ingress.inc(len(want_frame))
        n_frames = max(1, -(-len(fps) // self.max_batch_chunks))
        return n_frames, self._want_frames(fps)

    def _want_frames(self, fps: Sequence[bytes]) -> Iterable[bytes]:
        # the request is metered around actual frame production, so the
        # latency histogram covers the store reads a streamed WANT overlaps
        # with the client's decode
        with self._track("want"):
            produced = False
            for start in range(0, len(fps), self.max_batch_chunks):
                batch: Dict[bytes, bytes] = {}
                for fp in fps[start:start + self.max_batch_chunks]:
                    data = self._read_chunk(fp)
                    if data is not None:
                        batch[fp] = data
                frame = wire.encode_chunk_batch(batch)
                produced = True
                self._m_egress.inc(len(frame))
                self._m_chunks.inc(len(batch))
                self._m_chunk_bytes.inc(sum(len(v) for v in batch.values()))
                yield frame
            if not produced:                 # empty WANT still gets an answer
                frame = wire.encode_chunk_batch({})
                self._m_egress.inc(len(frame))
                yield frame

    # api-boundary
    def handle_has(self, has_frame: bytes) -> bytes:
        """Answer a HAS presence query with a MISSING frame — the fps the
        registry does *not* hold.  A pusher then ships exactly these,
        getting cross-lineage server-side dedup for free."""
        with self._track("has"):
            fps = wire.decode_has(has_frame)
            with self._registry_lock:
                missing = self.registry.has_chunks(fps)
            resp = wire.encode_missing(missing)
            self._m_ingress.inc(len(has_frame))
            self._m_egress.inc(len(resp))
            return resp

    # api-boundary
    def handle_tags(self, tags_frame: bytes) -> bytes:
        """Answer a TAGS listing query with a TAG_LIST frame.

        Tag names are control-plane *protocol data*: routing them through a
        frame (instead of a Python attribute reach into the registry) keeps
        them metered and makes the query answerable over a socket."""
        with self._track("tags"):
            lineage = wire.decode_tags_request(tags_frame)
            with self._registry_lock:
                resp = wire.encode_tag_list(self.registry.tags(lineage))
            self._m_ingress.inc(len(tags_frame))
            self._m_egress.inc(len(resp))
            return resp

    # ------------------------------------------------------------ replication

    # api-boundary
    def handle_ship(self, ship_frame: bytes) -> List[bytes]:
        """Answer a SHIP request: one REPL_ACK frame carrying the primary's
        epoch + log head, then up to ``limit`` RECORD frames from the
        requested offset.

        ``limit == 0`` is a pure status probe (freshness query) and is
        answered regardless of the follower's epoch; with ``limit > 0`` an
        epoch mismatch raises :class:`DeliveryError` — offsets from another
        epoch are meaningless and replaying across one would corrupt the
        standby.
        """
        with self._track("ship"):
            replica, epoch, start, limit = wire.decode_ship(ship_frame)
            log = self.registry.replication
            with self._registry_lock:
                if limit and epoch != log.epoch:
                    raise DeliveryError(
                        f"replication epoch mismatch: primary is at epoch "
                        f"{log.epoch}, {replica or 'standby'} asked for "
                        f"epoch {epoch} — the standby must full-resync from "
                        f"an empty directory")
                records = log.records_from(start, limit) if limit else []
                head = log.head()
                cur_epoch = log.epoch
            frames = [wire.encode_repl_ack("", cur_epoch, head)]
            frames += [wire.encode_record_frame(r) for r in records]
            self._m_records_shipped.inc(len(records))
            self._m_ingress.inc(len(ship_frame))
            self._m_egress.inc(sum(len(f) for f in frames))
            return frames

    # api-boundary
    def handle_repl_ack(self, ack_frame: bytes) -> bytes:
        """Record a standby's applied offset; reply with the primary's
        current epoch + head so the follower knows its remaining lag.

        An ack from another epoch (a late report racing a GC rollover)
        carries a meaningless offset: it is dropped — and any offset the
        replica reported under the old epoch is forgotten — so the lag
        table never mixes offsets across epochs."""
        with self._track("repl_ack"):
            replica, epoch, offset = wire.decode_repl_ack(ack_frame)
            log = self.registry.replication
            with self._registry_lock:
                head = log.head()
                if epoch == log.epoch:
                    self.replica_offsets[replica] = offset
                    self._m_lag.labels(replica).set(max(0, head - offset))
                    # every tracked replica has applied everything below the
                    # minimum acked offset: trim the log prefix so in-epoch
                    # memory is bounded by the slowest replica's lag, not by
                    # history (a fresh standby joins via SNAPSHOT_SHIP, so
                    # nothing ever needs the trimmed records again)
                    self.registry.trim_replication(
                        min(self.replica_offsets.values()))
                else:
                    self.replica_offsets.pop(replica, None)
                resp = wire.encode_repl_ack(replica, log.epoch, head)
            self._m_ingress.inc(len(ack_frame))
            self._m_egress.inc(len(resp))
            return resp

    # api-boundary
    def handle_snapshot(self, snapshot_frame: bytes) -> List[bytes]:
        """Answer a SNAPSHOT_SHIP bootstrap request in one buffer — the
        non-streaming form of :meth:`snapshot_plan`."""
        _, frames = self.snapshot_plan(snapshot_frame)
        return list(frames)

    # api-boundary
    def snapshot_plan(self, snapshot_frame: bytes
                      ) -> Tuple[int, Iterable[bytes]]:
        """``(n_frames, frame iterator)`` for one SNAPSHOT_SHIP request —
        the streaming form, mirroring :meth:`want_plan`: one SNAPSHOT
        header frame (the primary's epoch + the resume offset the shipped
        state corresponds to) followed by one RECORD frame per collapsed
        state record.  The frame count is committed before streaming; the
        state records are materialized under the registry lock (they are
        KB-sized, like the index) so the stream itself holds no lock."""
        replica, _epoch, _offset = wire.decode_snapshot(snapshot_frame)
        self._m_ingress.inc(len(snapshot_frame))
        with self._registry_lock:
            epoch, head, raws = self.registry.state_snapshot()
        return 1 + len(raws), self._snapshot_frames(epoch, head, raws)

    def _snapshot_frames(self, epoch: int, head: int,
                         raws: Sequence[bytes]) -> Iterable[bytes]:
        with self._track("snapshot"):
            header = wire.encode_snapshot("", epoch, head)
            self._m_egress.inc(len(header))
            yield header
            for raw in raws:
                frame = wire.encode_record_frame(raw)
                self._m_egress.inc(len(frame))
                self._m_records_shipped.inc()
                yield frame

    def _read_chunk(self, fp: bytes) -> Optional[bytes]:
        """Cache/store read with request coalescing."""
        while True:
            with self._inflight_lock:
                slot = self._inflight.get(fp)
                leader = slot is None
                if leader:
                    slot = _InFlight()
                    self._inflight[fp] = slot
            if leader:
                try:
                    try:
                        slot.value = self.cache.get(fp)
                        self._m_store_reads.inc()
                    except KeyError:
                        slot.value = None    # registry does not have it
                    except BaseException as e:
                        slot.error = e       # followers must retry, not
                        raise                # treat the chunk as absent
                finally:
                    with self._inflight_lock:
                        del self._inflight[fp]
                    slot.event.set()
                return slot.value
            slot.event.wait()
            if slot.error is not None:       # leader failed (I/O error etc.)
                continue                     # retry as a fresh leader
            self._m_coalesced.inc()
            return slot.value

    # ------------------------------------------------------------------- push

    # api-boundary
    def handle_push(self, header_frame: bytes, recipe_frame: bytes,
                    chunk_frames: Sequence[bytes]) -> PushReceipt:
        """Accept a wire push: decode, verify, commit.

        The chunk batches are decoded with fingerprint verification and the
        registry additionally checks the rebuilt CDMT root against the
        client-claimed root in the header (paper Sec. V authentication).
        Ingress is metered up-front: the frames crossed the wire whether or
        not the push is ultimately accepted.
        """
        with self._track("push"):
            nbytes = (len(header_frame) + len(recipe_frame)
                      + sum(len(f) for f in chunk_frames))
            self._m_ingress.inc(nbytes)
            hdr = wire.decode_push_header(header_frame)
            recipe = wire.decode_recipe(recipe_frame)
            if hdr.root is None and recipe.fps:
                # only an empty artifact may omit the root — otherwise
                # omission would bypass the registry's index verification
                raise wire.WireError(
                    f"push {hdr.lineage}:{hdr.tag}: non-empty recipe with "
                    f"no claimed root")
            chunks: Dict[bytes, bytes] = {}
            for f in chunk_frames:
                chunks.update(wire.decode_chunk_batch(f))  # hashes payloads
            with self._registry_lock:
                receipt = self.registry.receive_push(
                    hdr.lineage, hdr.tag, recipe, chunks,
                    parent_version=hdr.parent_version, claimed_root=hdr.root,
                    claimed_params=hdr.params, chunks_verified=True)
            for fp, data in chunks.items():
                self.cache.put(fp, data)     # warm the cache for pullers
            return receipt

    # ---------------------------------------------------------------- metrics

    # api-boundary
    def handle_metrics(self) -> bytes:
        """One METRICS frame: the whole registry (frontend + cache + core)
        serialized as a JSON snapshot — the ``Op.METRICS`` scrape body."""
        with self._track("metrics"):
            frame = wire.encode_metrics(
                self.metrics.snapshot().to_json().encode("utf-8"))
            self._m_egress.inc(len(frame))
            return frame

    # ------------------------------------------------------------- accounting

    @property
    def stats(self) -> ServerStats:
        """Adapter: the legacy stats dataclass, read from the metric
        children (field names unchanged, values always current)."""
        cache_stats = self.cache.stats
        return ServerStats(
            egress_bytes=self._m_egress.value(),
            ingress_bytes=self._m_ingress.value(),
            index_requests=self._m_req["index"].value(),
            recipe_requests=self._m_req["recipe"].value(),
            want_requests=self._m_req["want"].value(),
            has_requests=self._m_req["has"].value(),
            tags_requests=self._m_req["tags"].value(),
            ship_requests=self._m_req["ship"].value(),
            records_shipped=self._m_records_shipped.value(),
            repl_acks=self._m_req["repl_ack"].value(),
            snapshot_requests=self._m_req["snapshot"].value(),
            chunks_served=self._m_chunks.value(),
            chunk_bytes_served=self._m_chunk_bytes.value(),
            store_reads=self._m_store_reads.value(),
            coalesced_reads=self._m_coalesced.value(),
            pushes=self._m_req["push"].value(),
            warmed_chunks=cache_stats.warmed,
            warm_hits=cache_stats.warm_hits)

    def snapshot(self) -> ServerStats:
        return self.stats

    def cache_hit_rate(self) -> float:
        return self.cache.stats.hit_rate
