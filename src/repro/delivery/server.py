"""Concurrent registry frontend — serves many simultaneous pullers.

Wraps a ``repro.core.registry.Registry`` behind the wire format:

  * every response is a serialized frame, and every byte that crosses the
    boundary is metered (``egress_bytes`` / ``ingress_bytes`` are *actual*
    frame lengths, not estimates);
  * chunk reads go through the tiered LRU cache (:mod:`repro.delivery.cache`);
  * identical in-flight chunk requests **coalesce**: when N pullers ask for
    the same fingerprint concurrently, one thread performs the store/cache
    read and the rest wait on its result (``coalesced_reads`` counts the
    piggy-backers) — under a thundering herd of upgrades the chunk log sees
    the working set once;
  * chunk responses are **batched**: a WANT list is answered with one or more
    CHUNK_BATCH frames of at most ``max_batch_chunks`` chunks, so a session
    can pipeline decode/ingest against later batches;
  * error paths are protocol-level: unknown lineages/tags surface as
    :class:`repro.core.errors.DeliveryError`, rejected pushes as
    :class:`repro.core.registry.PushRejected` — never a bare ``KeyError``.
    (Unknown fingerprints in a WANT are still silently omitted; the session
    layer decides whether absence is an error.)

When the wrapped registry is directory-backed, an accepted ``handle_push``
is durable before the receipt returns (chunk fsync + journaled commit — see
:mod:`repro.core.registry`).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.errors import DeliveryError
from repro.core.registry import PushReceipt, Registry
from repro.core.store import Recipe

from . import wire
from .cache import DEFAULT_CAPACITY, TieredChunkCache


@dataclasses.dataclass
class ServerStats:
    egress_bytes: int = 0          # serialized frames out (index/recipe/chunks)
    ingress_bytes: int = 0         # serialized frames in (wants/pushes)
    index_requests: int = 0
    recipe_requests: int = 0
    want_requests: int = 0
    has_requests: int = 0          # HAS presence queries answered
    tags_requests: int = 0         # TAGS listing queries answered
    ship_requests: int = 0         # JOURNAL_SHIP requests answered
    records_shipped: int = 0       # journal records streamed to standbys
    repl_acks: int = 0             # REPL_ACK progress reports received
    chunks_served: int = 0
    chunk_bytes_served: int = 0
    store_reads: int = 0           # chunk reads that reached cache/store
    coalesced_reads: int = 0       # piggy-backed on an identical in-flight read
    pushes: int = 0
    warmed_chunks: int = 0         # cache entries pre-loaded at startup
    warm_hits: int = 0             # cache hits served by a warmed entry

    def snapshot(self) -> "ServerStats":
        return dataclasses.replace(self)


class _InFlight:
    __slots__ = ("event", "value", "error")

    def __init__(self):
        self.event = threading.Event()
        self.value: Optional[bytes] = None
        self.error: Optional[BaseException] = None


class RegistryServer:
    """Thread-safe wire frontend over an in-process ``Registry``."""

    def __init__(self, registry: Registry,
                 cache_bytes: int = DEFAULT_CAPACITY,
                 max_batch_chunks: int = 64,
                 warm_start: bool = True,
                 warm_scan_limit: int = 50_000):
        self.registry = registry
        self.cache = TieredChunkCache(registry.store.chunks, cache_bytes)
        self.max_batch_chunks = max_batch_chunks
        self.stats = ServerStats()
        self._stats_lock = threading.Lock()
        self._registry_lock = threading.RLock()   # Registry itself is not MT-safe
        self._inflight: Dict[bytes, _InFlight] = {}
        self._inflight_lock = threading.Lock()
        # replica name -> last acked replication offset (observability: a
        # primary can report standby lag without polling the standbys)
        self.replica_offsets: Dict[str, int] = {}
        if warm_start and registry.store.chunks.directory is not None:
            self.stats.warmed_chunks = self._warm_from_store(warm_scan_limit)

    def _warm_from_store(self, scan_limit: int) -> int:
        """Pre-load the memory tier from the recovered chunk index so a
        restarted registry serves its first wave from RAM instead of cold
        (ROADMAP: "registry restart under load").  Most recently appended
        chunks first — the heads of each lineage are what pullers hit —
        until the cache's capacity budget is full.

        A chunk too large for the remaining budget is *skipped*, not a stop
        condition: smaller (older) chunks behind it may still fit, so one
        big recent chunk must not leave the rest of the budget cold.  The
        index sizes are known up-front, so a skip costs no chunk read; the
        walk is bounded by ``scan_limit`` entries so startup stays O(bounded)
        even over a huge store whose budget filled early."""
        store = self.registry.store.chunks
        entries = sorted(store.index_entries(),
                         key=lambda e: e[1], reverse=True)  # offset desc
        warmed = 0
        for fp, _off, size in entries[:max(0, scan_limit)]:
            free = self.cache.capacity_bytes - self.cache.stats.resident_bytes
            if free <= 0:
                break
            if size > free:
                continue                   # skip-and-continue, no read done
            if self.cache.warm(fp, store.get(fp)):
                warmed += 1
        return warmed

    # ------------------------------------------------------------ index/recipe

    def get_index(self, lineage: str, tag: str) -> bytes:
        """Serialized INDEX frame for ``lineage:tag``.  An unknown lineage or
        tag raises the protocol-level :class:`repro.core.errors.DeliveryError`
        (never a bare ``KeyError``), so wire clients see a clean error."""
        with self._registry_lock:
            idx = self.registry.index_for_tag(lineage, tag)
            frame = wire.encode_index(idx)
        with self._stats_lock:
            self.stats.index_requests += 1
            self.stats.egress_bytes += len(frame)
        return frame

    def get_latest_index(self, lineage: str) -> Optional[bytes]:
        """Serialized INDEX frame of the lineage head, or None (new lineage)."""
        with self._registry_lock:
            idx = self.registry.latest_index(lineage)
            frame = wire.encode_index(idx) if idx is not None else None
        if frame is not None:
            with self._stats_lock:
                self.stats.index_requests += 1
                self.stats.egress_bytes += len(frame)
        return frame

    def get_recipe(self, lineage: str, tag: str) -> bytes:
        """Serialized RECIPE frame; :class:`DeliveryError` when unknown."""
        with self._registry_lock:
            frame = wire.encode_recipe(self.registry.recipe_for(lineage, tag))
        with self._stats_lock:
            self.stats.recipe_requests += 1
            self.stats.egress_bytes += len(frame)
        return frame

    # ----------------------------------------------------------------- chunks

    def handle_want(self, want_frame: bytes) -> List[bytes]:
        """Answer a WANT frame with batched CHUNK_BATCH frames.

        Unknown fingerprints are silently omitted (the client's decode sees
        which fps arrived); the session layer decides whether absence is an
        error.
        """
        _, frames = self.want_plan(want_frame)
        return list(frames)

    def want_plan(self, want_frame: bytes
                  ) -> Tuple[int, Iterable[bytes]]:
        """``(n_frames, frame iterator)`` for one WANT — the streaming form
        of :meth:`handle_want`.  The frame count is known before a single
        chunk is read (it depends only on the want length and the batch
        split), so a socket server can commit a response header and then
        write each CHUNK_BATCH as it is built, overlapping store reads with
        the client's decode of earlier batches."""
        fps = wire.decode_want(want_frame)
        with self._stats_lock:
            self.stats.want_requests += 1
            self.stats.ingress_bytes += len(want_frame)
        n_frames = max(1, -(-len(fps) // self.max_batch_chunks))
        return n_frames, self._want_frames(fps)

    def _want_frames(self, fps: Sequence[bytes]) -> Iterable[bytes]:
        produced = False
        for start in range(0, len(fps), self.max_batch_chunks):
            batch: Dict[bytes, bytes] = {}
            for fp in fps[start:start + self.max_batch_chunks]:
                data = self._read_chunk(fp)
                if data is not None:
                    batch[fp] = data
            frame = wire.encode_chunk_batch(batch)
            produced = True
            with self._stats_lock:
                self.stats.egress_bytes += len(frame)
                self.stats.chunks_served += len(batch)
                self.stats.chunk_bytes_served += sum(len(v) for v in batch.values())
            yield frame
        if not produced:                     # empty WANT still gets an answer
            frame = wire.encode_chunk_batch({})
            with self._stats_lock:
                self.stats.egress_bytes += len(frame)
            yield frame

    def handle_has(self, has_frame: bytes) -> bytes:
        """Answer a HAS presence query with a MISSING frame — the fps the
        registry does *not* hold.  A pusher then ships exactly these,
        getting cross-lineage server-side dedup for free."""
        fps = wire.decode_has(has_frame)
        with self._registry_lock:
            missing = self.registry.has_chunks(fps)
        resp = wire.encode_missing(missing)
        with self._stats_lock:
            self.stats.has_requests += 1
            self.stats.ingress_bytes += len(has_frame)
            self.stats.egress_bytes += len(resp)
        return resp

    def handle_tags(self, tags_frame: bytes) -> bytes:
        """Answer a TAGS listing query with a TAG_LIST frame.

        Tag names are control-plane *protocol data*: routing them through a
        frame (instead of a Python attribute reach into the registry) keeps
        them metered and makes the query answerable over a socket."""
        lineage = wire.decode_tags_request(tags_frame)
        with self._registry_lock:
            resp = wire.encode_tag_list(self.registry.tags(lineage))
        with self._stats_lock:
            self.stats.tags_requests += 1
            self.stats.ingress_bytes += len(tags_frame)
            self.stats.egress_bytes += len(resp)
        return resp

    # ------------------------------------------------------------ replication

    def handle_ship(self, ship_frame: bytes) -> List[bytes]:
        """Answer a SHIP request: one REPL_ACK frame carrying the primary's
        epoch + log head, then up to ``limit`` RECORD frames from the
        requested offset.

        ``limit == 0`` is a pure status probe (freshness query) and is
        answered regardless of the follower's epoch; with ``limit > 0`` an
        epoch mismatch raises :class:`DeliveryError` — offsets from another
        epoch are meaningless and replaying across one would corrupt the
        standby.
        """
        replica, epoch, start, limit = wire.decode_ship(ship_frame)
        log = self.registry.replication
        with self._registry_lock:
            if limit and epoch != log.epoch:
                raise DeliveryError(
                    f"replication epoch mismatch: primary is at epoch "
                    f"{log.epoch}, {replica or 'standby'} asked for epoch "
                    f"{epoch} — the standby must full-resync from an empty "
                    f"directory")
            records = log.records_from(start, limit) if limit else []
            head = log.head()
            cur_epoch = log.epoch
        frames = [wire.encode_repl_ack("", cur_epoch, head)]
        frames += [wire.encode_record_frame(r) for r in records]
        with self._stats_lock:
            self.stats.ship_requests += 1
            self.stats.records_shipped += len(records)
            self.stats.ingress_bytes += len(ship_frame)
            self.stats.egress_bytes += sum(len(f) for f in frames)
        return frames

    def handle_repl_ack(self, ack_frame: bytes) -> bytes:
        """Record a standby's applied offset; reply with the primary's
        current epoch + head so the follower knows its remaining lag.

        An ack from another epoch (a late report racing a GC rollover)
        carries a meaningless offset: it is dropped — and any offset the
        replica reported under the old epoch is forgotten — so the lag
        table never mixes offsets across epochs."""
        replica, epoch, offset = wire.decode_repl_ack(ack_frame)
        log = self.registry.replication
        with self._registry_lock:
            if epoch == log.epoch:
                self.replica_offsets[replica] = offset
            else:
                self.replica_offsets.pop(replica, None)
            resp = wire.encode_repl_ack(replica, log.epoch, log.head())
        with self._stats_lock:
            self.stats.repl_acks += 1
            self.stats.ingress_bytes += len(ack_frame)
            self.stats.egress_bytes += len(resp)
        return resp

    def _read_chunk(self, fp: bytes) -> Optional[bytes]:
        """Cache/store read with request coalescing."""
        while True:
            with self._inflight_lock:
                slot = self._inflight.get(fp)
                leader = slot is None
                if leader:
                    slot = _InFlight()
                    self._inflight[fp] = slot
            if leader:
                try:
                    try:
                        slot.value = self.cache.get(fp)
                        with self._stats_lock:
                            self.stats.store_reads += 1
                    except KeyError:
                        slot.value = None    # registry does not have it
                    except BaseException as e:
                        slot.error = e       # followers must retry, not
                        raise                # treat the chunk as absent
                finally:
                    with self._inflight_lock:
                        del self._inflight[fp]
                    slot.event.set()
                return slot.value
            slot.event.wait()
            if slot.error is not None:       # leader failed (I/O error etc.)
                continue                     # retry as a fresh leader
            with self._stats_lock:
                self.stats.coalesced_reads += 1
            return slot.value

    # ------------------------------------------------------------------- push

    def handle_push(self, header_frame: bytes, recipe_frame: bytes,
                    chunk_frames: Sequence[bytes]) -> PushReceipt:
        """Accept a wire push: decode, verify, commit.

        The chunk batches are decoded with fingerprint verification and the
        registry additionally checks the rebuilt CDMT root against the
        client-claimed root in the header (paper Sec. V authentication).
        Ingress is metered up-front: the frames crossed the wire whether or
        not the push is ultimately accepted.
        """
        nbytes = (len(header_frame) + len(recipe_frame)
                  + sum(len(f) for f in chunk_frames))
        with self._stats_lock:
            self.stats.ingress_bytes += nbytes
        hdr = wire.decode_push_header(header_frame)
        recipe = wire.decode_recipe(recipe_frame)
        if hdr.root is None and recipe.fps:
            # only an empty artifact may omit the root — otherwise omission
            # would bypass the registry's index verification
            raise wire.WireError(
                f"push {hdr.lineage}:{hdr.tag}: non-empty recipe with no "
                f"claimed root")
        chunks: Dict[bytes, bytes] = {}
        for f in chunk_frames:
            chunks.update(wire.decode_chunk_batch(f))   # hashes every payload
        with self._registry_lock:
            receipt = self.registry.receive_push(
                hdr.lineage, hdr.tag, recipe, chunks,
                parent_version=hdr.parent_version, claimed_root=hdr.root,
                claimed_params=hdr.params, chunks_verified=True)
        for fp, data in chunks.items():
            self.cache.put(fp, data)         # warm the cache for pullers
        with self._stats_lock:
            self.stats.pushes += 1
        return receipt

    # ------------------------------------------------------------- accounting

    def snapshot(self) -> ServerStats:
        warm_hits = self.cache.stats.warm_hits
        with self._stats_lock:
            self.stats.warm_hits = warm_hits
            return self.stats.snapshot()

    def cache_hit_rate(self) -> float:
        return self.cache.stats.hit_rate
