"""Tiered chunk cache: in-memory LRU over the log-structured ``ChunkStore``.

Both sides of the wire use it — the registry frontend serves hot chunks
without touching the chunk log (many pullers upgrading the same lineage hit
the same few-hundred-KB working set), and clients keep recently materialized
chunks resident for swarm serving.

Accounting is explicit (:class:`CacheStats`): the scale benchmark reports the
hit rate alongside registry egress, because a warm cache is what makes the
coalesced frontend O(working set) instead of O(requests) in store reads.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional

from repro.core.store import ChunkStore

DEFAULT_CAPACITY = 32 << 20  # 32 MiB — plenty for the scaled-down corpus


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    puts: int = 0
    resident_bytes: int = 0
    capacity_bytes: int = 0
    warmed: int = 0                # entries pre-loaded via warm()
    warm_hits: int = 0             # hits served by a pre-warmed entry

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class TieredChunkCache:
    """Write-through LRU in front of a ``ChunkStore``.

    * ``get`` — memory first (hit), else backing store (miss + promote);
    * ``put`` — write-through: backing store then memory;
    * eviction — strict LRU by bytes against ``capacity_bytes``.

    Thread-safe: the registry frontend calls it from many puller threads.
    Chunks larger than the capacity bypass the memory tier entirely.
    """

    def __init__(self, backing: ChunkStore,
                 capacity_bytes: int = DEFAULT_CAPACITY):
        self.backing = backing
        self.capacity_bytes = capacity_bytes
        self._lru: "OrderedDict[bytes, bytes]" = OrderedDict()
        self._resident = 0
        self._lock = threading.Lock()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._puts = 0
        self._warm: set = set()    # fps admitted via warm(), still resident
        self._warmed = 0
        self._warm_hits = 0

    # ---------------------------------------------------------------- reads

    def get(self, fp: bytes) -> bytes:
        with self._lock:
            data = self._lru.get(fp)
            if data is not None:
                self._lru.move_to_end(fp)
                self._hits += 1
                if fp in self._warm:
                    self._warm_hits += 1
                return data
            self._misses += 1
        data = self.backing.get(fp)        # may raise KeyError: truly absent
        with self._lock:
            self._admit(fp, data)
        return data

    def has(self, fp: bytes) -> bool:
        with self._lock:
            if fp in self._lru:
                return True
        return self.backing.has(fp)

    # --------------------------------------------------------------- writes

    def put(self, fp: bytes, data: bytes) -> bool:
        """Write-through store; returns True if the chunk was new."""
        new = self.backing.put(fp, data)
        with self._lock:
            self._puts += 1
            self._warm.discard(fp)         # freshly written, no longer "warm"
            self._admit(fp, data)
        return new

    def warm(self, fp: bytes, data: bytes) -> bool:
        """Pre-load an already-stored chunk into the memory tier (restart
        warm-up from a recovered chunk index).  No write-through, no
        eviction of existing residents: returns False — without admitting —
        once admission would displace anything, so warming fills only the
        cache's free budget."""
        with self._lock:
            if fp in self._lru:
                return True                # already resident
            if (len(data) > self.capacity_bytes
                    or self._resident + len(data) > self.capacity_bytes):
                return False
            self._lru[fp] = data
            self._resident += len(data)
            self._warm.add(fp)
            self._warmed += 1
        return True

    def _admit(self, fp: bytes, data: bytes) -> None:
        # caller holds the lock
        if len(data) > self.capacity_bytes:
            return
        prev = self._lru.pop(fp, None)
        if prev is not None:
            self._resident -= len(prev)
        self._lru[fp] = data
        self._resident += len(data)
        while self._resident > self.capacity_bytes:
            victim_fp, victim = self._lru.popitem(last=False)
            self._resident -= len(victim)
            self._warm.discard(victim_fp)
            self._evictions += 1

    # ----------------------------------------------------------- accounting

    @property
    def stats(self) -> CacheStats:
        with self._lock:
            return CacheStats(hits=self._hits, misses=self._misses,
                              evictions=self._evictions, puts=self._puts,
                              resident_bytes=self._resident,
                              capacity_bytes=self.capacity_bytes,
                              warmed=self._warmed,
                              warm_hits=self._warm_hits)

    def resident_fps(self) -> List[bytes]:
        with self._lock:
            return list(self._lru.keys())
