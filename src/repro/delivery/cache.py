"""Tiered chunk cache: in-memory LRU over the log-structured ``ChunkStore``.

Both sides of the wire use it — the registry frontend serves hot chunks
without touching the chunk log (many pullers upgrading the same lineage hit
the same few-hundred-KB working set), and clients keep recently materialized
chunks resident for swarm serving.

Accounting lives in a :class:`~repro.obs.MetricsRegistry` (``cache_*``
series — hits, misses, evictions, resident bytes; see
``docs/OBSERVABILITY.md``), so a registry scrape reports cache behavior
live.  :class:`CacheStats` remains the in-process view: an adapter built
from the same metric children, field-compatible with the original
dataclass.  Eviction bookkeeping (``_resident``, the warm set) stays in
plain attributes under the cache lock — correctness never depends on the
metrics being enabled.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional

from repro.core.store import ChunkStore
from repro.obs import MetricsRegistry

DEFAULT_CAPACITY = 32 << 20  # 32 MiB — plenty for the scaled-down corpus


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    puts: int = 0
    resident_bytes: int = 0
    capacity_bytes: int = 0
    warmed: int = 0                # entries pre-loaded via warm()
    warm_hits: int = 0             # hits served by a pre-warmed entry

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class TieredChunkCache:
    """Write-through LRU in front of a ``ChunkStore``.

    * ``get`` — memory first (hit), else backing store (miss + promote);
    * ``put`` — write-through: backing store then memory;
    * eviction — strict LRU by bytes against ``capacity_bytes``.

    Thread-safe: the registry frontend calls it from many puller threads.
    Chunks larger than the capacity bypass the memory tier entirely.

    ``metrics`` is the registry the ``cache_*`` series land in — pass the
    owning server's so one scrape covers both; by default the cache keeps a
    private one (a swarm node's cache must not pollute a registry's).
    """

    def __init__(self, backing: ChunkStore,
                 capacity_bytes: int = DEFAULT_CAPACITY,
                 metrics: Optional[MetricsRegistry] = None):
        self.backing = backing
        self.capacity_bytes = capacity_bytes
        self._lru: "OrderedDict[bytes, bytes]" = OrderedDict()  # guarded-by: _lock
        self._resident = 0  # guarded-by: _lock
        self._lock = threading.Lock()
        self._warm: set = set()    # guarded-by: _lock
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._m_hits = m.counter(
            "cache_hits_total", "chunk reads served from the memory tier"
        ).labels()
        self._m_misses = m.counter(
            "cache_misses_total", "chunk reads that fell through to the "
            "backing store").labels()
        self._m_evictions = m.counter(
            "cache_evictions_total", "LRU evictions").labels()
        self._m_puts = m.counter(
            "cache_puts_total", "write-through puts").labels()
        self._m_warmed = m.counter(
            "cache_warmed_total", "entries pre-loaded via warm()").labels()
        self._m_warm_hits = m.counter(
            "cache_warm_hits_total", "hits served by a pre-warmed entry"
        ).labels()
        self._m_resident = m.gauge(
            "cache_resident_bytes", "bytes resident in the memory tier"
        ).labels()
        self._m_capacity = m.gauge(
            "cache_capacity_bytes", "memory tier capacity").labels()
        self._m_capacity.set(capacity_bytes)

    # ---------------------------------------------------------------- reads

    def get(self, fp: bytes) -> bytes:
        with self._lock:
            data = self._lru.get(fp)
            if data is not None:
                self._lru.move_to_end(fp)
                self._m_hits.inc()
                if fp in self._warm:
                    self._m_warm_hits.inc()
                return data
        self._m_misses.inc()
        data = self.backing.get(fp)        # may raise KeyError: truly absent
        with self._lock:
            self._admit(fp, data)
        return data

    def has(self, fp: bytes) -> bool:
        with self._lock:
            if fp in self._lru:
                return True
        return self.backing.has(fp)

    # --------------------------------------------------------------- writes

    def put(self, fp: bytes, data: bytes) -> bool:
        """Write-through store; returns True if the chunk was new."""
        new = self.backing.put(fp, data)
        self._m_puts.inc()
        with self._lock:
            self._warm.discard(fp)         # freshly written, no longer "warm"
            self._admit(fp, data)
        return new

    def warm(self, fp: bytes, data: bytes) -> bool:
        """Pre-load an already-stored chunk into the memory tier (restart
        warm-up from a recovered chunk index).  No write-through, no
        eviction of existing residents: returns False — without admitting —
        once admission would displace anything, so warming fills only the
        cache's free budget."""
        with self._lock:
            if fp in self._lru:
                return True                # already resident
            if (len(data) > self.capacity_bytes
                    or self._resident + len(data) > self.capacity_bytes):
                return False
            self._lru[fp] = data
            self._resident += len(data)
            self._warm.add(fp)
            # meter inside the lock (like get/_admit): reading _resident
            # after release can publish a stale gauge out of order with a
            # concurrent put/eviction
            self._m_warmed.inc()
            self._m_resident.set(self._resident)
        return True

    def _admit(self, fp: bytes, data: bytes) -> None:  # requires-lock: _lock
        if len(data) > self.capacity_bytes:
            return
        prev = self._lru.pop(fp, None)
        if prev is not None:
            self._resident -= len(prev)
        self._lru[fp] = data
        self._resident += len(data)
        evicted = 0
        while self._resident > self.capacity_bytes:
            victim_fp, victim = self._lru.popitem(last=False)
            self._resident -= len(victim)
            self._warm.discard(victim_fp)
            evicted += 1
        if evicted:
            self._m_evictions.inc(evicted)
        self._m_resident.set(self._resident)

    # ----------------------------------------------------------- accounting

    @property
    def resident_bytes(self) -> int:
        """Current memory-tier occupancy (cheap — no stats object built)."""
        with self._lock:
            return self._resident

    @property
    def stats(self) -> CacheStats:
        return CacheStats(hits=self._m_hits.value(),
                          misses=self._m_misses.value(),
                          evictions=self._m_evictions.value(),
                          puts=self._m_puts.value(),
                          resident_bytes=self.resident_bytes,
                          capacity_bytes=self.capacity_bytes,
                          warmed=self._m_warmed.value(),
                          warm_hits=self._m_warm_hits.value())

    def resident_fps(self) -> List[bytes]:
        with self._lock:
            return list(self._lru.keys())
