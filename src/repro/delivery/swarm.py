"""EdgePier-style peer swarm (arXiv:2109.12983 applied to CDMT delivery).

Clients that finished provisioning a lineage register with a
:class:`SwarmTracker`; later pullers resolve their missing chunk set via the
registry's CDMT index as usual, but fetch the chunk *payloads* from peers
first — the central registry only serves the remainder (chunks no reachable
peer holds).  Every peer exchange uses the same WANT/CHUNK_BATCH wire frames
as the registry path, so peer traffic and registry egress are measured in the
same units and the offload fraction is exact.

The pull logic itself lives in the unified client:
:func:`swarm_pull` binds the node's local state to a
:class:`~repro.delivery.transport.SwarmTransport` (peer providers over a
registry fallback, with per-source accounting and dead-peer failover) and
delegates to :meth:`repro.delivery.client.ImageClient.pull`.  ``SwarmStats``
is an alias of the unified :class:`~repro.delivery.plan.TransferReport`,
whose ``peer_*`` counters are derived from the per-source legs.

The index and recipe still come from the registry: they are KB-sized and
carry the authentication root, so the registry stays the source of truth
while payload bandwidth spreads over the swarm (chunk batches are
fingerprint-verified on decode, so a peer cannot forge content).
"""

from __future__ import annotations

import itertools
import threading
from typing import Dict, List, Optional, Tuple

from repro.core import cdc
from repro.core.cdmt import CDMTParams, DEFAULT_PARAMS
from repro.core.errors import DeliveryError
from repro.core.pushpull import Client

from . import wire
from .cache import DEFAULT_CAPACITY, TieredChunkCache
from .client import ImageClient
from .plan import TransferReport
from .server import RegistryServer
from .transport import SwarmTransport

SwarmStats = TransferReport         # deprecation alias (pre-unification name)


class SwarmNode:
    """A client that can also *serve* its chunks to other swarm members."""

    def __init__(self, name: str,
                 cdc_params: cdc.CDCParams = cdc.DEFAULT_PARAMS,
                 cdmt_params: CDMTParams = DEFAULT_PARAMS,
                 cache_bytes: int = DEFAULT_CAPACITY):
        self.name = name
        self.client = Client(cdc_params=cdc_params, cdmt_params=cdmt_params)
        self.cache = TieredChunkCache(self.client.store.chunks, cache_bytes)
        self.alive = True       # guarded-by: _lock
        self.served_bytes = 0   # guarded-by: _lock
        self.served_chunks = 0  # guarded-by: _lock
        self._lock = threading.Lock()
        self._trackers: List["SwarmTracker"] = []   # guarded-by: _lock

    def kill(self) -> None:
        """Take the node offline: subsequent ``serve_want`` calls raise, so
        pullers fail over to the next provider / the registry."""
        with self._lock:
            self.alive = False

    def revive(self) -> None:
        """Come back online and re-register: every tracker that benched this
        node for repeated failures clears the backoff, so the node serves
        again without waiting to complete a fresh pull."""
        with self._lock:
            self.alive = True
            trackers = list(self._trackers)
        for t in trackers:
            t.revive(self)

    def _registered_with(self, tracker: "SwarmTracker") -> None:
        with self._lock:
            if tracker not in self._trackers:
                self._trackers.append(tracker)

    # ------------------------------------------------------------ peer server

    def serve_want(self, want_frame: bytes) -> bytes:
        """Answer a WANT with the subset of chunks this node holds (one
        CHUNK_BATCH frame; absent fps are omitted, the requester falls back
        to other peers / the registry for them).  A dead node raises
        :class:`DeliveryError` — the wire analogue of a connection refusal."""
        if not self.alive:  # unguarded-ok: lock-free fast path — a stale flag costs at most one failed round
            raise DeliveryError(f"peer {self.name} is unreachable")
        fps = wire.decode_want(want_frame)
        batch: Dict[bytes, bytes] = {}
        for fp in fps:
            if self.cache.has(fp):
                batch[fp] = self.cache.get(fp)
        frame = wire.encode_chunk_batch(batch)
        with self._lock:
            self.served_bytes += len(frame)
            self.served_chunks += len(batch)
        return frame


class SwarmTracker:
    """Who has which version (EdgePier's DHT, reduced to a table).

    Providers are tracked per ``(lineage, tag)``: a peer that finished
    provisioning v7 is a *complete* source for v7's chunks, while peers on
    other tags of the same lineage still hold the shared prefix — so lookups
    return exact-tag holders first, then same-lineage holders as a second
    tier.  Registrations of dead nodes linger (a lookup cannot prove
    liveness), but each tier orders currently-live nodes first so corpses
    never crowd live providers out of the ``limit`` slots; a returned
    provider that still fails is absorbed by the transport as a failover.

    **Health**: the transport reports each ``serve_want`` outcome back
    (:meth:`report_failure` / :meth:`report_success`).  A provider that
    fails ``failure_threshold`` times *consecutively* is benched — excluded
    from lookups entirely — so a dead node stops costing one failed round
    per batch forever.  Any success clears the streak; a benched node
    returns via :meth:`revive` (``SwarmNode.revive`` calls it on every
    tracker the node registered with) or by re-registering after a fresh
    pull.
    """

    def __init__(self, failure_threshold: int = 3):
        self.failure_threshold = max(1, failure_threshold)
        self._providers: Dict[Tuple[str, str], List[SwarmNode]] = {}  # guarded-by: _lock
        self._failures: Dict[int, int] = {}   # guarded-by: _lock
        self._lock = threading.Lock()
        self._rr = itertools.count()  # guarded-by: _lock

    def register(self, lineage: str, tag: str, node: SwarmNode) -> None:
        with self._lock:
            nodes = self._providers.setdefault((lineage, tag), [])
            if node not in nodes:
                nodes.append(node)
            self._failures.pop(id(node), None)   # a fresh pull proves health
        if hasattr(node, "_registered_with"):
            node._registered_with(self)

    # ------------------------------------------------------------- health

    def report_failure(self, node: SwarmNode) -> None:
        with self._lock:
            self._failures[id(node)] = self._failures.get(id(node), 0) + 1

    def report_success(self, node: SwarmNode) -> None:
        with self._lock:
            self._failures.pop(id(node), None)

    def revive(self, node: SwarmNode) -> None:
        """Clear a node's backoff so existing registrations serve again."""
        with self._lock:
            self._failures.pop(id(node), None)

    def is_benched(self, node: SwarmNode) -> bool:
        with self._lock:
            return self._failures.get(id(node), 0) >= self.failure_threshold

    def consecutive_failures(self, node: SwarmNode) -> int:
        with self._lock:
            return self._failures.get(id(node), 0)

    # ------------------------------------------------------------- lookups

    def providers(self, lineage: str, tag: str,
                  exclude: Optional[SwarmNode] = None,
                  limit: int = 4) -> List[SwarmNode]:
        """Up to ``limit`` providers — exact-tag holders first, same-lineage
        holders after, each tier rotated round-robin so concurrent pullers
        spread load across the swarm, and live nodes ahead of dead ones
        within each tier.  Benched providers (too many consecutive failures)
        are excluded outright."""
        with self._lock:
            thresh = self.failure_threshold

            def ok(n: SwarmNode) -> bool:
                return (n is not exclude
                        and self._failures.get(id(n), 0) < thresh)  # unguarded-ok: closure only invoked inside the with-block above

            exact = [n for n in self._providers.get((lineage, tag), ())
                     if ok(n)]
            rest: List[SwarmNode] = []
            for (lin, t), nodes in self._providers.items():
                if lin == lineage and t != tag:
                    rest.extend(n for n in nodes
                                if ok(n) and n not in exact
                                and n not in rest)
            rot = next(self._rr)
        out: List[SwarmNode] = []
        for tier in (exact, rest):
            if tier:
                start = rot % len(tier)
                rotated = tier[start:] + tier[:start]
                out.extend(sorted(rotated, key=lambda n: not n.alive))
        return out[:limit]


def swarm_pull(node: SwarmNode, server: RegistryServer, tracker: SwarmTracker,
               lineage: str, tag: str, batch_chunks: int = 64,
               max_peers: int = 4) -> TransferReport:
    """Pull ``lineage:tag``: index + recipe from the registry, chunk payloads
    peers-first, registry for the remainder.  Registers ``node`` as a
    provider on success.  Compatibility wrapper over
    ``ImageClient(SwarmTransport(...)).pull``."""
    transport = SwarmTransport(node, tracker, server, max_peers=max_peers,
                               batch_chunks=batch_chunks)
    ic = ImageClient(transport,
                     store=node.client.store, indexes=node.client.indexes,
                     tag_trees=node.client.tag_trees,
                     cdc_params=node.client.store.cdc_params,
                     cdmt_params=node.client.cdmt_params,
                     batch_chunks=batch_chunks, pipeline_depth=1)
    return ic.pull(lineage, tag)
