"""EdgePier-style peer swarm (arXiv:2109.12983 applied to CDMT delivery).

Clients that finished provisioning a lineage register with a
:class:`SwarmTracker`; later pullers resolve their missing chunk set via the
registry's CDMT index as usual, but fetch the chunk *payloads* from peers
first — the central registry only serves the remainder (chunks no reachable
peer holds).  Every peer exchange uses the same WANT/CHUNK_BATCH wire frames
as the registry path, so peer traffic and registry egress are measured in the
same units and the offload fraction is exact.

The index and recipe still come from the registry: they are KB-sized and
carry the authentication root, so the registry stays the source of truth
while payload bandwidth spreads over the swarm (chunk batches are
fingerprint-verified on decode, so a peer cannot forge content).
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import cdc
from repro.core.cdmt import CDMTParams, DEFAULT_PARAMS
from repro.core.pushpull import Client

from . import wire
from .cache import DEFAULT_CAPACITY, TieredChunkCache
from .delta import DeliveryError, DeliveryStats, iter_missing
from .server import RegistryServer


@dataclasses.dataclass
class SwarmStats(DeliveryStats):
    """Delivery accounting split by source."""
    peer_chunk_bytes: int = 0      # CHUNK_BATCH bytes served by peers
    registry_chunk_bytes: int = 0  # CHUNK_BATCH bytes served by the registry
    chunks_from_peers: int = 0
    peer_rounds: int = 0

    @property
    def peer_offload_fraction(self) -> float:
        total = self.peer_chunk_bytes + self.registry_chunk_bytes
        return self.peer_chunk_bytes / total if total else 0.0


class SwarmNode:
    """A client that can also *serve* its chunks to other swarm members."""

    def __init__(self, name: str,
                 cdc_params: cdc.CDCParams = cdc.DEFAULT_PARAMS,
                 cdmt_params: CDMTParams = DEFAULT_PARAMS,
                 cache_bytes: int = DEFAULT_CAPACITY):
        self.name = name
        self.client = Client(cdc_params=cdc_params, cdmt_params=cdmt_params)
        self.cache = TieredChunkCache(self.client.store.chunks, cache_bytes)
        self.served_bytes = 0
        self.served_chunks = 0
        self._lock = threading.Lock()

    # ------------------------------------------------------------ peer server

    def serve_want(self, want_frame: bytes) -> bytes:
        """Answer a WANT with the subset of chunks this node holds (one
        CHUNK_BATCH frame; absent fps are omitted, the requester falls back
        to other peers / the registry for them)."""
        fps = wire.decode_want(want_frame)
        batch: Dict[bytes, bytes] = {}
        for fp in fps:
            if self.cache.has(fp):
                batch[fp] = self.cache.get(fp)
        frame = wire.encode_chunk_batch(batch)
        with self._lock:
            self.served_bytes += len(frame)
            self.served_chunks += len(batch)
        return frame


class SwarmTracker:
    """Who has which version (EdgePier's DHT, reduced to a table).

    Providers are tracked per ``(lineage, tag)``: a peer that finished
    provisioning v7 is a *complete* source for v7's chunks, while peers on
    other tags of the same lineage still hold the shared prefix — so lookups
    return exact-tag holders first, then same-lineage holders as a second
    tier.
    """

    def __init__(self):
        self._providers: Dict[Tuple[str, str], List[SwarmNode]] = {}
        self._lock = threading.Lock()
        self._rr = itertools.count()

    def register(self, lineage: str, tag: str, node: SwarmNode) -> None:
        with self._lock:
            nodes = self._providers.setdefault((lineage, tag), [])
            if node not in nodes:
                nodes.append(node)

    def providers(self, lineage: str, tag: str,
                  exclude: Optional[SwarmNode] = None,
                  limit: int = 4) -> List[SwarmNode]:
        """Up to ``limit`` providers — exact-tag holders first, same-lineage
        holders after, each tier rotated round-robin so concurrent pullers
        spread load across the swarm."""
        with self._lock:
            exact = [n for n in self._providers.get((lineage, tag), ())
                     if n is not exclude]
            rest: List[SwarmNode] = []
            for (lin, t), nodes in self._providers.items():
                if lin == lineage and t != tag:
                    rest.extend(n for n in nodes
                                if n is not exclude and n not in exact
                                and n not in rest)
            rot = next(self._rr)
        out: List[SwarmNode] = []
        for tier in (exact, rest):
            if tier:
                start = rot % len(tier)
                out.extend(tier[start:] + tier[:start])
        return out[:limit]


def swarm_pull(node: SwarmNode, server: RegistryServer, tracker: SwarmTracker,
               lineage: str, tag: str, batch_chunks: int = 64,
               max_peers: int = 4) -> SwarmStats:
    """Pull ``lineage:tag``: index + recipe from the registry, chunk payloads
    peers-first, registry for the remainder.  Registers ``node`` as a
    provider on success."""
    client = node.client
    idx_frame = server.get_index(lineage, tag)
    server_idx = wire.decode_index(idx_frame)
    recipe_frame = server.get_recipe(lineage, tag)
    recipe = wire.decode_recipe(recipe_frame)
    stats = SwarmStats(op="swarm_pull", lineage=lineage, tag=tag,
                       index_bytes=len(idx_frame),
                       recipe_bytes=len(recipe_frame),
                       chunks_total=len(recipe.fps),
                       raw_bytes=recipe.total_size)

    local_idx = client.indexes.get(lineage)
    to_fetch = [fp for fp in iter_missing(local_idx, server_idx, stats)
                if not client.store.chunks.has(fp)]
    received: Dict[bytes, bytes] = {}
    peers = tracker.providers(lineage, tag, exclude=node, limit=max_peers)

    for start in range(0, len(to_fetch), batch_chunks):
        wanted = [fp for fp in to_fetch[start:start + batch_chunks]
                  if fp not in received]
        # 1) swarm first: ask each peer for what is still missing
        for peer in peers:
            if not wanted:
                break
            want = wire.encode_want(wanted)
            stats.want_bytes += len(want)
            frame = peer.serve_want(want)
            stats.peer_rounds += 1
            got = wire.decode_chunk_batch(frame)
            # the frame crossed the wire either way — empty replies count too
            stats.peer_chunk_bytes += len(frame)
            stats.chunk_bytes += len(frame)
            if got:
                stats.chunks_from_peers += len(got)
                stats.chunks_moved += len(got)
                received.update(got)
                wanted = [fp for fp in wanted if fp not in got]
        # 2) registry fallback for the remainder
        if wanted:
            want = wire.encode_want(wanted)
            stats.want_bytes += len(want)
            frames = server.handle_want(want)
            stats.rounds += 1
            for f in frames:
                got = wire.decode_chunk_batch(f)
                stats.registry_chunk_bytes += len(f)
                stats.chunk_bytes += len(f)
                stats.chunks_moved += len(got)
                received.update(got)

    undelivered = [fp for fp in to_fetch if fp not in received]
    if undelivered:
        raise DeliveryError(
            f"swarm pull {lineage}:{tag}: {len(undelivered)} chunk(s) "
            f"served by neither peers nor registry "
            f"(first: {undelivered[0].hex()[:12]})")
    # verify=False: peer and registry payloads were fingerprint-checked by
    # decode_chunk_batch as they arrived
    client.store.ingest_chunks(f"{lineage}:{tag}", recipe.fps, received,
                               recipe.sizes, verify=False)
    client.indexes[lineage] = server_idx
    # freshly provisioned ⇒ this node can now serve the version
    tracker.register(lineage, tag, node)
    return stats
