"""Delta session — pipelines Algorithm 2 compare with chunk transfer.

A naive pull is strictly sequential: download the whole index, finish the
whole BFS compare, send one giant want-list, wait for one giant response.
The session protocol overlaps the phases instead:

  1. the INDEX frame is downloaded and decoded (KB-sized — paper Sec. IV);
  2. the compare BFS (:func:`iter_missing`) *streams* missing leaves;
  3. every ``batch_chunks`` leaves, a WANT frame is dispatched to the server
     on a transfer thread pool while the BFS keeps walking — with
     ``pipeline_depth`` requests in flight, chunk bytes move concurrently
     with comparison work (and with the other batches);
  4. arriving CHUNK_BATCH frames are decoded (fingerprint-verified) and
     ingested as they land.

All byte counters are actual serialized frame lengths.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Iterator, List, Optional, Sequence

from repro.core.cdmt import CDMT, iter_missing_leaves
from repro.core.errors import DeliveryError
from repro.core.pushpull import Client, WireStats

from . import wire
from .server import RegistryServer

__all__ = ["DeliveryError", "DeliveryStats", "DeltaSession", "iter_missing"]


@dataclasses.dataclass
class DeliveryStats(WireStats):
    """Actual-wire-bytes accounting for one delivery session.

    Extends the core :class:`WireStats` (same byte categories, same
    ``savings_vs_raw``) with the session protocol's extra traffic: WANT
    frames and round-trip count.  ``total_wire_bytes`` therefore includes
    ``want_bytes``.
    """
    want_bytes: int = 0            # WANT frames uploaded
    rounds: int = 0                # WANT round-trips issued

    @property
    def total_wire_bytes(self) -> int:
        return (self.index_bytes + self.recipe_bytes + self.want_bytes
                + self.chunk_bytes)


def iter_missing(client: Optional[CDMT], server: CDMT,
                 stats: Optional[DeliveryStats] = None) -> Iterator[bytes]:
    """Streaming Algorithm 2 (see :func:`repro.core.cdmt.iter_missing_leaves`
    — the single BFS implementation), wiring comparisons into ``stats``."""
    on_compare = None
    if stats is not None:
        def on_compare():
            stats.comparisons += 1
    return iter_missing_leaves(client, server, on_compare=on_compare)


class DeltaSession:
    """One client's pipelined pull/push session against a RegistryServer."""

    def __init__(self, client: Client, server: RegistryServer,
                 batch_chunks: int = 64, pipeline_depth: int = 4):
        self.client = client
        self.server = server
        self.batch_chunks = batch_chunks
        self.pipeline_depth = max(1, pipeline_depth)

    # ------------------------------------------------------------------ pull

    def pull(self, lineage: str, tag: str) -> DeliveryStats:
        """Pipelined pull of ``lineage:tag``; returns exact wire accounting."""
        idx_frame = self.server.get_index(lineage, tag)
        server_idx = wire.decode_index(idx_frame)
        recipe_frame = self.server.get_recipe(lineage, tag)
        recipe = wire.decode_recipe(recipe_frame)
        stats = DeliveryStats(op="pull", lineage=lineage, tag=tag,
                              index_bytes=len(idx_frame),
                              recipe_bytes=len(recipe_frame),
                              chunks_total=len(recipe.fps),
                              raw_bytes=recipe.total_size)

        local_idx = self.client.indexes.get(lineage)
        received: Dict[bytes, bytes] = {}
        requested: List[bytes] = []

        def fetch(fps: List[bytes]):
            want = wire.encode_want(fps)
            frames = self.server.handle_want(want)
            return want, frames

        with ThreadPoolExecutor(max_workers=self.pipeline_depth) as pool:
            pending = deque()
            batch: List[bytes] = []
            for fp in iter_missing(local_idx, server_idx, stats):
                # global dedup: a chunk may live locally under another lineage
                if self.client.store.chunks.has(fp):
                    continue
                requested.append(fp)
                batch.append(fp)
                if len(batch) >= self.batch_chunks:
                    pending.append(pool.submit(fetch, batch))
                    batch = []
                    # bounded pipeline: drain the oldest once depth is reached
                    while len(pending) > self.pipeline_depth:
                        self._drain(pending.popleft(), received, stats)
            if batch:
                pending.append(pool.submit(fetch, batch))
            while pending:
                self._drain(pending.popleft(), received, stats)

        undelivered = [fp for fp in requested if fp not in received]
        if undelivered:
            raise DeliveryError(
                f"pull {lineage}:{tag}: registry omitted "
                f"{len(undelivered)} requested chunk(s) "
                f"(first: {undelivered[0].hex()[:12]})")
        # verify=False: every payload in `received` was already fingerprint-
        # checked by decode_chunk_batch as it came off the wire
        self.client.store.ingest_chunks(f"{lineage}:{tag}", recipe.fps,
                                        received, recipe.sizes, verify=False)
        self.client.indexes[lineage] = server_idx
        return stats

    def _drain(self, fut, received: Dict[bytes, bytes],
               stats: DeliveryStats) -> None:
        want, frames = fut.result()
        stats.rounds += 1
        stats.want_bytes += len(want)
        for f in frames:
            stats.chunk_bytes += len(f)
            chunks = wire.decode_chunk_batch(f)
            stats.chunks_moved += len(chunks)
            received.update(chunks)

    # ------------------------------------------------------------------ push

    def push(self, lineage: str, tag: str,
             parent_version: Optional[int] = None) -> DeliveryStats:
        """Wire push: Alg. 2 against the registry head, ship only missing
        chunks, framed + verified server-side (root match)."""
        recipe = self.client.store.recipes[f"{lineage}:{tag}"]
        local_idx = self.client.index_for_tag(lineage, tag)
        stats = DeliveryStats(op="push", lineage=lineage, tag=tag,
                              chunks_total=len(recipe.fps),
                              raw_bytes=recipe.total_size)

        remote_frame = self.server.get_latest_index(lineage)
        remote_idx = None
        if remote_frame is not None:
            stats.index_bytes += len(remote_frame)
            remote_idx = wire.decode_index(remote_frame)

        missing = list(iter_missing(remote_idx, local_idx, stats))
        payload = {fp: self.client.store.chunks.get(fp) for fp in missing}

        hdr = wire.encode_push_header(wire.PushHeader(
            lineage=lineage, tag=tag, root=local_idx.root,
            parent_version=parent_version,
            params=self.client.cdmt_params))
        recipe_frame = wire.encode_recipe(recipe)
        chunk_frames: List[bytes] = []
        fps = list(payload)
        for start in range(0, len(fps), self.batch_chunks):
            part = {fp: payload[fp] for fp in fps[start:start + self.batch_chunks]}
            chunk_frames.append(wire.encode_chunk_batch(part))

        self.server.handle_push(hdr, recipe_frame, chunk_frames)
        # upload accounting: exactly the frames that crossed the wire — the
        # registry rebuilds the index from the recipe, so no INDEX frame is
        # uploaded (the claimed root rides in the header)
        stats.index_bytes += len(hdr)
        stats.recipe_bytes = len(recipe_frame)
        stats.chunk_bytes = sum(len(f) for f in chunk_frames)
        stats.chunks_moved = len(payload)
        stats.rounds = len(chunk_frames)
        return stats
