"""Delta session — compatibility shim over the unified client.

``DeltaSession`` predates :class:`repro.delivery.client.ImageClient`; it now
simply binds the wrapped client's local state to a
:class:`~repro.delivery.transport.WireTransport` and delegates, so the
pipelined pull (bounded in-flight WANT batches on a transfer pool) and the
framed push live in exactly one place.  ``DeliveryStats`` is an alias of the
unified :class:`~repro.delivery.plan.TransferReport` — same byte categories,
same ``savings_vs_raw``, plus per-source legs.

New code should use ``ImageClient(WireTransport(server))`` directly (and get
``plan_pull``/``execute``/``upgrade`` too).
"""

from __future__ import annotations

from typing import Iterator, Optional

from repro.core.cdmt import CDMT, iter_missing_leaves
from repro.core.errors import DeliveryError
from repro.core.pushpull import Client

from .client import ImageClient
from .plan import TransferReport
from .server import RegistryServer
from .transport import WireTransport

__all__ = ["DeliveryError", "DeliveryStats", "DeltaSession", "iter_missing"]

DeliveryStats = TransferReport      # deprecation alias (pre-unification name)


def iter_missing(client: Optional[CDMT], server: CDMT,
                 stats: Optional[TransferReport] = None) -> Iterator[bytes]:
    """Streaming Algorithm 2 (see :func:`repro.core.cdmt.iter_missing_leaves`
    — the single BFS implementation), wiring comparisons into ``stats``."""
    on_compare = None
    if stats is not None:
        def on_compare():
            stats.comparisons += 1
    return iter_missing_leaves(client, server, on_compare=on_compare)


class DeltaSession:
    """One client's pipelined pull/push session against a RegistryServer."""

    def __init__(self, client: Client, server: RegistryServer,
                 batch_chunks: int = 64, pipeline_depth: int = 4):
        self.client = client
        self.server = server
        self.batch_chunks = batch_chunks
        self.pipeline_depth = max(1, pipeline_depth)
        self._ic = ImageClient(
            WireTransport(server, batch_chunks=batch_chunks),
            store=client.store, indexes=client.indexes,
            tag_trees=client.tag_trees,
            cdc_params=client.store.cdc_params,
            cdmt_params=client.cdmt_params,
            batch_chunks=batch_chunks, pipeline_depth=pipeline_depth)

    def pull(self, lineage: str, tag: str) -> TransferReport:
        """Pipelined pull of ``lineage:tag``; returns exact wire accounting."""
        return self._ic.pull(lineage, tag)

    def push(self, lineage: str, tag: str,
             parent_version: Optional[int] = None) -> TransferReport:
        """Wire push: Alg. 2 against the registry head, ship only missing
        chunks, framed + verified server-side (root match)."""
        return self._ic.push(lineage, tag, parent_version=parent_version)
