"""Pull plans and unified transfer accounting for the delivery API.

The redesigned client splits every pull into an inspectable pair:

  * :meth:`repro.delivery.client.ImageClient.plan_pull` runs Algorithm 2
    against the transport's index and returns a :class:`PullPlan` — which
    fingerprints must move, what they should cost on the wire, and how many
    node comparisons the diff took — **without moving a single chunk**;
  * :meth:`repro.delivery.client.ImageClient.execute` streams the plan in
    batches and returns a :class:`TransferReport`.

:class:`TransferReport` is the one stats object for every transport (it
unifies the former ``WireStats`` / ``DeliveryStats`` / ``SwarmStats``
split): top-level counters carry the totals, and ``sources`` breaks chunk
traffic down per origin (``registry``, ``peer:<name>``, …) so multi-source
pulls — swarm offload, failover — are accounted exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.core.cdmt import CDMT
from repro.core.pushpull import WireStats
from repro.core.store import Recipe


@dataclasses.dataclass
class SourceLeg:
    """Chunk traffic attributed to one source during a transfer.

    ``source`` is ``"registry"`` for the authoritative backend (in-process
    or wire) and ``"peer:<name>"`` for swarm providers.  ``failures`` counts
    requests this source failed to answer (dead peer, I/O error) — each one
    is a failover the client absorbed.
    """
    source: str
    chunks: int = 0
    chunk_bytes: int = 0        # CHUNK_BATCH frame bytes from this source
    want_bytes: int = 0         # WANT frame bytes sent to this source
    rounds: int = 0             # request round-trips to this source
    failures: int = 0

    def absorb(self, other: "SourceLeg") -> None:
        assert other.source == self.source
        self.chunks += other.chunks
        self.chunk_bytes += other.chunk_bytes
        self.want_bytes += other.want_bytes
        self.rounds += other.rounds
        self.failures += other.failures


def _is_peer(source: str) -> bool:
    return source.startswith("peer:")


@dataclasses.dataclass
class TransferReport(WireStats):
    """Unified per-transfer accounting — one shape for every transport.

    Extends the byte categories of the core :class:`WireStats` with the
    session-protocol traffic (WANT frames, round-trips) and a per-source
    breakdown.  The legacy names still import — ``DeliveryStats`` and
    ``SwarmStats`` are deprecation aliases of this class — and every field
    the old three classes exposed is available here (the swarm-specific
    counters are now derived from ``sources``).
    """
    transport: str = ""
    want_bytes: int = 0            # WANT / has-chunks control frames
    rounds: int = 0                # registry round-trips
    failovers: int = 0             # source failures absorbed mid-transfer
    sources: Dict[str, SourceLeg] = dataclasses.field(default_factory=dict)

    @property
    def total_wire_bytes(self) -> int:
        return (self.index_bytes + self.recipe_bytes + self.want_bytes
                + self.chunk_bytes)

    # ------------------------------------------------------------- per-source

    def leg(self, source: str) -> SourceLeg:
        got = self.sources.get(source)
        if got is None:
            got = self.sources[source] = SourceLeg(source=source)
        return got

    def merge_leg(self, leg: SourceLeg) -> None:
        """Fold one source leg into the totals and the per-source table."""
        self.leg(leg.source).absorb(leg)
        self.chunk_bytes += leg.chunk_bytes
        self.want_bytes += leg.want_bytes
        self.chunks_moved += leg.chunks
        self.failovers += leg.failures
        if _is_peer(leg.source):
            return
        self.rounds += leg.rounds

    # ------------------------------------- legacy SwarmStats-derived counters

    @property
    def peer_chunk_bytes(self) -> int:
        return sum(l.chunk_bytes for l in self.sources.values()
                   if _is_peer(l.source))

    @property
    def registry_chunk_bytes(self) -> int:
        return sum(l.chunk_bytes for l in self.sources.values()
                   if not _is_peer(l.source))

    @property
    def chunks_from_peers(self) -> int:
        return sum(l.chunks for l in self.sources.values()
                   if _is_peer(l.source))

    @property
    def peer_rounds(self) -> int:
        return sum(l.rounds for l in self.sources.values()
                   if _is_peer(l.source))

    @property
    def peer_offload_fraction(self) -> float:
        total = self.peer_chunk_bytes + self.registry_chunk_bytes
        return self.peer_chunk_bytes / total if total else 0.0


@dataclasses.dataclass
class PullPlan:
    """Everything a pull will do, decided before any chunk moves.

    Produced by ``ImageClient.plan_pull``: the transport supplied the index
    and recipe (both KB-sized), Algorithm 2 diffed the index against the
    client's local tree, and the local store was consulted for cross-lineage
    dedup.  ``missing`` is the exact fetch list ``execute`` will stream;
    the ``expected_*`` fields are exact for single-source transports and a
    lower bound for swarm (empty peer replies add a few frame-header bytes).
    """
    lineage: str
    tag: str
    transport: str
    index: CDMT = dataclasses.field(repr=False)
    recipe: Recipe = dataclasses.field(repr=False)
    missing: List[bytes] = dataclasses.field(repr=False)
    chunks_total: int = 0
    already_local: int = 0         # diffed-as-missing but found in the store
    raw_bytes: int = 0             # full artifact size (naive transfer cost)
    expected_chunk_bytes: int = 0  # payload bytes expected to move
    expected_wire_bytes: int = 0   # index + recipe + framed chunk batches
    comparisons: int = 0           # Algorithm-2 node comparisons
    index_bytes: int = 0
    recipe_bytes: int = 0

    @property
    def chunks_to_fetch(self) -> int:
        return len(self.missing)

    @property
    def expected_savings_vs_raw(self) -> float:
        if not self.raw_bytes:
            return 0.0
        return 1.0 - self.expected_wire_bytes / self.raw_bytes
