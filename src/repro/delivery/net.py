"""Real TCP delivery — the bytes this module reports crossed a socket.

Until now every transport "wire" byte crossed a Python function call; this
module is the seam the ROADMAP left open ("real network transports").  Two
pieces:

  * :class:`SocketRegistryServer` — a threaded TCP acceptor over the
    existing thread-safe :class:`~repro.delivery.server.RegistryServer`
    handlers.  One thread per connection; each request is a length-prefixed
    envelope (``wire.encode_request``: op, lineage, tag, body frames) and
    each response a status header plus length-prefixed frames.  WANT
    answers are **streamed**: the response header commits the frame count
    (known from the want length alone), then each CHUNK_BATCH is written as
    it is built, so the server's store reads overlap the client's decode of
    earlier batches.  Failures cross the wire as ERROR frames (protocol
    data), never as a silently dropped connection — except a failure *after*
    response streaming started, where the only honest signal left is a
    close (the client surfaces it as ``DeliveryError``).
  * :class:`SocketTransport` — a conforming
    :class:`~repro.delivery.transport.Transport` over real TCP.  A small
    connection pool lets ``ImageClient.execute``'s pipelined batches run
    concurrent WANT exchanges that genuinely overlap on the network.  Every
    byte it reports is a socket byte: request envelopes are accounted as
    control/want traffic, response envelopes ride in the matching byte
    category, and ``quote_chunk_batches`` lets ``plan_pull`` quote the full
    socket cost of a pull — envelope overhead included — to the byte.

  * :class:`JournalFollower` — keeps a standby registry in sync with a
    primary over the same envelope protocol: ``JOURNAL_SHIP`` streams
    checksummed journal records from a resume offset, referenced chunk
    payloads ride the ordinary WANT path, and ``REPL_ACK`` reports applied
    progress back so the primary can publish standby lag.

Server-side errors re-raise client-side as the matching exception
(``DeliveryError`` / ``PushRejected`` / ``WireError``); transport-level
failures (connection refused/reset, truncated stream, timeouts) surface as
``DeliveryError`` so a mid-pull server death fails the pull cleanly before
anything is committed to the local store.

Concurrency contract
    ``SocketRegistryServer`` runs one daemon thread per connection plus the
    acceptor; every request is answered through the wrapped
    ``RegistryServer``'s handlers, which serialize registry mutations
    behind ``_registry_lock`` and meter everything through the shared
    ``MetricsRegistry`` lock — so any number of connections may pull,
    push, and ship concurrently.
    ``SocketTransport`` is thread-safe: pooled connections are checked out
    per exchange (``ImageClient.execute``'s pipelined batches genuinely
    overlap on the network), and a connection whose stream state is in
    doubt (I/O error, wire error) is closed, never re-pooled.
    ``JournalFollower`` applies records from exactly one thread (its own,
    or the caller of ``sync_once``) — standby registries have a single
    writer, like primaries.

Crash-recovery contract
    The server owns no state of its own: everything durable lives in the
    wrapped ``Registry`` (journal + chunk log, see
    :mod:`repro.core.journal`), so killing the process at any point costs
    at most the in-flight requests — clients see a truncated stream and
    raise ``DeliveryError`` with nothing committed locally.  A standby that
    crashes recovers its replication position from its own journal (records
    applied == offset), re-requests from there, and duplicate or torn
    shipped records are skipped / re-verified rather than re-applied —
    see ``Registry.apply_replicated``.
"""

from __future__ import annotations

import dataclasses
import socket
import threading
import time
from typing import BinaryIO, Dict, List, Optional, Sequence, Tuple

from repro.core import faults
from repro.core.cdmt import CDMT, CDMTParams
from repro.core.errors import DeliveryError, JournalError
from repro.core.registry import PushRejected, Registry, record_chunk_fps
from repro.core.store import Recipe
from repro.obs import MetricsRegistry, MetricsSnapshot

from . import wire
from .plan import SourceLeg
from .server import RegistryServer
from .transport import (REGISTRY_SOURCE, FetchResult, PushOutcome,
                        TransportMeter)

__all__ = ["JournalFollower", "SocketRegistryServer", "SocketServerStats",
           "SocketTransport"]

DEFAULT_TIMEOUT = 30.0


class _ConnectionClosed(Exception):
    """The peer closed (or the stream truncated) mid-exchange."""


class _StaleConn(Exception):
    """A *reused* pooled connection died before the server answered — the
    classic keep-alive race (the server idle-reaped or restarted while the
    connection sat in the pool).  Nothing was answered, so the exchange is
    safe to redial and retry once instead of surfacing ``DeliveryError``."""


def _read_exact(f: BinaryIO, n: int) -> bytes:
    data = f.read(n)
    if data is None or len(data) < n:
        raise _ConnectionClosed(f"stream closed (wanted {n} bytes, got "
                                f"{0 if not data else len(data)})")
    return data


def _read_uvarint(f: BinaryIO) -> Tuple[int, int]:
    """``(value, bytes_consumed)`` — LEB128 off a buffered stream."""
    result = 0
    shift = 0
    for i in range(10):
        b = _read_exact(f, 1)[0]
        result |= (b & 0x7F) << shift
        if not b & 0x80:
            return result, i + 1
        shift += 7
    raise wire.WireError("uvarint too long (>10 bytes)")


def _read_str(f: BinaryIO) -> Tuple[str, int]:
    n, nb = _read_uvarint(f)
    if n > wire.MAX_ROUTING_BYTES:
        raise wire.WireError(f"routing string of {n} bytes exceeds "
                             f"{wire.MAX_ROUTING_BYTES}")
    return _read_exact(f, n).decode("utf-8"), nb + n


def _read_frame(f: BinaryIO) -> Tuple[bytes, int]:
    """One length-prefixed frame off the stream: ``(frame, bytes_read)``.
    The length is sanity-bounded before allocation — a corrupt (or hostile)
    prefix must not make this endpoint buffer an arbitrary amount."""
    size, nb = _read_uvarint(f)
    if size > wire.MAX_FRAME_BYTES:
        raise wire.WireError(f"frame of {size} bytes exceeds "
                             f"{wire.MAX_FRAME_BYTES}")
    return _read_exact(f, size), nb + size


# ---------------------------------------------------------------- server


@dataclasses.dataclass
class SocketServerStats:
    """Socket-level accounting (the frame-level meters live on the wrapped
    :class:`~repro.delivery.server.ServerStats`; the difference between the
    two is exactly the envelope overhead).

    An adapter view: the numbers live in the server's
    :class:`~repro.obs.MetricsRegistry` (``socket_*`` series), which closes
    the old read-modify-write hazard of unsynchronized ``+=`` across
    connection threads — every increment goes through the registry's lock.
    """
    connections: int = 0
    requests: int = 0
    errors: int = 0                # requests answered with an ERROR frame
    ingress_bytes: int = 0         # request envelopes read off sockets
    egress_bytes: int = 0          # response envelopes written to sockets

    def snapshot(self) -> "SocketServerStats":
        return dataclasses.replace(self)


class SocketRegistryServer:
    """Threaded TCP front door over a :class:`RegistryServer`.

    ``port=0`` (the default) binds an ephemeral port; read ``address`` after
    construction.  The acceptor starts immediately; use as a context manager
    or call :meth:`stop` to shut down (close the listener, then every live
    connection).
    """

    def __init__(self, server: RegistryServer, host: str = "127.0.0.1",
                 port: int = 0, backlog: int = 64,
                 io_timeout: float = DEFAULT_TIMEOUT,
                 idle_timeout: Optional[float] = None):
        self.server = server
        # mid-request read budget: once a request header byte arrives the
        # rest must follow within this window, so a stalled or hostile
        # client cannot pin a connection thread forever
        self.io_timeout = io_timeout
        # idle-between-requests budget: None preserves the historical
        # unbounded window; a number reaps connections that sit quiet that
        # long between requests (pooled clients redial transparently — see
        # SocketTransport's stale-connection retry)
        self.idle_timeout = idle_timeout
        # socket_* series land in the wrapped server's registry, so one
        # Op.METRICS scrape covers envelope accounting, frame-level server
        # meters, cache behavior, and replication state together
        self.metrics = server.metrics
        m = self.metrics
        self._m_connections = m.counter(
            "socket_connections_total", "TCP connections accepted").labels()
        self._m_open = m.gauge(
            "socket_open_connections", "currently open connections").labels()
        self._m_requests = m.counter(
            "socket_requests_total", "request envelopes served").labels()
        self._m_errors = m.counter(
            "socket_errors_total",
            "requests answered with an ERROR frame").labels()
        self._m_ingress = m.counter(
            "socket_ingress_bytes_total",
            "request envelope bytes read off sockets").labels()
        self._m_egress = m.counter(
            "socket_egress_bytes_total",
            "response envelope bytes written to sockets").labels()
        self._m_reaped = m.counter(
            "socket_idle_reaped_total",
            "connections closed by the idle reaper").labels()
        self._closing = False  # guarded-by: external(single-writer stop(); lock-free reads are benign loop exits)
        self._conns: Dict[int, socket.socket] = {}  # guarded-by: _conns_lock
        self._threads: set = set()  # guarded-by: _conns_lock
        self._conns_lock = threading.Lock()
        self._listener = socket.create_server((host, port), backlog=backlog)
        self.address: Tuple[str, int] = self._listener.getsockname()[:2]
        self._acceptor = threading.Thread(target=self._accept_loop,
                                          name="socket-registry-accept",
                                          daemon=True)
        self._acceptor.start()

    # ------------------------------------------------------------ lifecycle

    def __enter__(self) -> "SocketRegistryServer":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def stop(self) -> None:
        self._closing = True
        # closing a listener does NOT wake a thread blocked in accept() on
        # every platform: shutdown() does on Linux (accept raises EINVAL),
        # and the throwaway self-connection covers platforms where a
        # listener shutdown is a no-op — without this, every stop() ate the
        # full acceptor join timeout
        try:
            self._listener.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            with socket.create_connection(self.address, timeout=0.5):
                pass
        except OSError:
            pass
        try:
            self._listener.close()
        except OSError:
            pass
        with self._conns_lock:
            conns = list(self._conns.values())
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        self._acceptor.join(timeout=5)
        with self._conns_lock:
            threads = list(self._threads)
        for t in threads:
            t.join(timeout=5)

    @property
    def stats(self) -> SocketServerStats:
        """Adapter view over the ``socket_*`` metric series — field names
        unchanged from the original counter dataclass."""
        return SocketServerStats(
            connections=self._m_connections.value(),
            requests=self._m_requests.value(),
            errors=self._m_errors.value(),
            ingress_bytes=self._m_ingress.value(),
            egress_bytes=self._m_egress.value())

    def snapshot(self) -> SocketServerStats:
        return self.stats

    # ------------------------------------------------------------- acceptor

    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                conn, _addr = self._listener.accept()
            except OSError:
                return                       # listener closed: shutting down
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._conns_lock:
                self._conns[id(conn)] = conn
            self._m_connections.inc()
            self._m_open.inc()
            t = threading.Thread(target=self._serve, args=(conn,),
                                 name="socket-registry-conn", daemon=True)
            with self._conns_lock:
                self._threads.add(t)
            t.start()

    # ----------------------------------------------------------- connection

    def _serve(self, conn: socket.socket) -> None:
        rfile = conn.makefile("rb")
        try:
            while not self._closing:
                req = self._read_request(conn, rfile)
                if req is None:
                    return                   # clean EOF between requests
                op, lineage, tag, frames, req_bytes = req
                self._m_requests.inc()
                self._m_ingress.inc(req_bytes)
                self._answer(conn, op, lineage, tag, frames)
        except (_ConnectionClosed, OSError):
            return                           # peer vanished / we are closing
        except wire.WireError as e:
            # malformed request envelope: the stream offset is unknowable,
            # so answer best-effort with an ERROR frame and drop the conn
            self._m_errors.inc()
            try:
                self._send(conn, wire.encode_response(
                    wire.STATUS_ERROR,
                    [wire.encode_error(wire.ErrorCode.WIRE, str(e))]))
            except OSError:
                pass
            return
        finally:
            try:
                rfile.close()
            except OSError:
                pass
            try:
                conn.close()
            except OSError:
                pass
            with self._conns_lock:
                self._conns.pop(id(conn), None)
                self._threads.discard(threading.current_thread())
            self._m_open.dec()

    def _read_request(self, conn: socket.socket, rfile: BinaryIO
                      ) -> Optional[Tuple[wire.Op, str, str,
                                          List[bytes], int]]:
        """One request envelope off the stream, or None on EOF at a request
        boundary (the client hung up cleanly) or idle reap.  The wait for
        the *first* byte is bounded by ``idle_timeout`` when configured
        (unbounded otherwise — pooled client connections idle between
        requests); once a request starts, the rest must arrive within
        ``io_timeout`` or the connection is dropped."""
        if self.idle_timeout is not None:
            conn.settimeout(self.idle_timeout)
        try:
            first = rfile.read(1)
        except socket.timeout:
            # nothing consumed (the buffer was empty at a request
            # boundary), so this close is as clean as an EOF
            self._m_reaped.inc()
            return None
        if not first:
            return None
        conn.settimeout(self.io_timeout)     # a request is now in flight
        try:
            hdr = first + _read_exact(rfile, 3)
            nbytes = 4
            op = wire.check_request_header(hdr)
            lineage, nb = _read_str(rfile)
            nbytes += nb
            tag, nb = _read_str(rfile)
            nbytes += nb
            n_frames, nb = _read_uvarint(rfile)
            nbytes += nb
            if n_frames > wire.MAX_ENVELOPE_FRAMES:
                raise wire.WireError(f"request carries {n_frames} frames, "
                                     f"limit {wire.MAX_ENVELOPE_FRAMES}")
            frames: List[bytes] = []
            for _ in range(n_frames):
                f, nb = _read_frame(rfile)
                nbytes += nb
                frames.append(f)
        finally:
            conn.settimeout(None)            # back to idle between requests
        return op, lineage, tag, frames, nbytes

    def _send(self, conn: socket.socket, data: bytes) -> None:
        conn.sendall(data)
        self._m_egress.inc(len(data))

    def _answer(self, conn: socket.socket, op: wire.Op, lineage: str,
                tag: str, frames: List[bytes]) -> None:
        streamed = False
        try:
            if op is wire.Op.WANT:
                self._expect_frames(op, frames, 1)
                n, frame_iter = self.server.want_plan(frames[0])
                self._send(conn, wire.encode_response_header(
                    wire.STATUS_OK, n))
                streamed = True              # header out: count is committed
                for f in frame_iter:
                    self._send(conn, wire.encode_uvarint(len(f)) + f)
                return
            if op is wire.Op.SNAPSHOT_SHIP:
                # streamed like WANT: the frame count is known up front
                # (header + one RECORD per collapsed state record), so the
                # server's record encode overlaps the standby's decode
                self._expect_frames(op, frames, 1)
                n, frame_iter = self.server.snapshot_plan(frames[0])
                self._send(conn, wire.encode_response_header(
                    wire.STATUS_OK, n))
                streamed = True              # header out: count is committed
                for f in frame_iter:
                    self._send(conn, wire.encode_uvarint(len(f)) + f)
                return
            out = self._dispatch(op, lineage, tag, frames)
        except (_ConnectionClosed, OSError):
            raise  # raises-ok: dead client socket — serve_forever tears the connection down; nothing crosses the API surface
        except Exception as e:
            if streamed:
                # the frame count is already on the wire; any "error frame"
                # now would be decoded as chunk data.  Close: the client
                # sees a truncated stream and raises DeliveryError.
                raise _ConnectionClosed(str(e)) from e
            code = (wire.ErrorCode.PUSH_REJECTED
                    if isinstance(e, PushRejected)
                    else wire.ErrorCode.WIRE if isinstance(e, wire.WireError)
                    else wire.ErrorCode.DELIVERY
                    if isinstance(e, DeliveryError)
                    else wire.ErrorCode.INTERNAL)
            msg = str(e) or type(e).__name__
            self._m_errors.inc()
            self._send(conn, wire.encode_response(
                wire.STATUS_ERROR, [wire.encode_error(code, msg)]))
            return
        self._send(conn, wire.encode_response(wire.STATUS_OK, out))

    @staticmethod
    def _expect_frames(op: wire.Op, frames: Sequence[bytes],
                       n: int) -> None:
        expect_frames(op, frames, n)

    def _dispatch(self, op: wire.Op, lineage: str, tag: str,
                  frames: List[bytes]) -> List[bytes]:
        return dispatch_request(self.server, op, lineage, tag, frames)


def expect_frames(op: wire.Op, frames: Sequence[bytes], n: int) -> None:
    if len(frames) != n:
        raise wire.WireError(
            f"{op.name} request carries {len(frames)} body frame(s), "
            f"expected {n}")


def dispatch_request(server: RegistryServer, op: wire.Op, lineage: str,
                     tag: str, frames: Sequence[bytes]) -> List[bytes]:
    """Route one non-streamed request envelope to the matching
    :class:`RegistryServer` handler — the op table both socket front ends
    (threaded and async) share.  ``Op.WANT`` is *not* here: both servers
    stream it through :meth:`RegistryServer.want_plan` so the response
    header can commit the frame count before any chunk is read."""
    if op is wire.Op.INDEX:
        expect_frames(op, frames, 0)
        return [server.get_index(lineage, tag)]
    if op is wire.Op.LATEST_INDEX:
        expect_frames(op, frames, 0)
        frame = server.get_latest_index(lineage)
        return [] if frame is None else [frame]
    if op is wire.Op.RECIPE:
        expect_frames(op, frames, 0)
        return [server.get_recipe(lineage, tag)]
    if op is wire.Op.HAS:
        expect_frames(op, frames, 1)
        return [server.handle_has(frames[0])]
    if op is wire.Op.TAGS:
        expect_frames(op, frames, 1)
        return [server.handle_tags(frames[0])]
    if op is wire.Op.INFO:
        expect_frames(op, frames, 0)
        return [wire.encode_info(server.max_batch_chunks)]
    if op is wire.Op.METRICS:
        expect_frames(op, frames, 0)
        return [server.handle_metrics()]
    if op is wire.Op.JOURNAL_SHIP:
        expect_frames(op, frames, 1)
        return server.handle_ship(frames[0])
    if op is wire.Op.REPL_ACK:
        expect_frames(op, frames, 1)
        return [server.handle_repl_ack(frames[0])]
    if op is wire.Op.PUSH:
        if len(frames) < 2:
            raise wire.WireError(
                f"PUSH request carries {len(frames)} body frame(s), "
                f"expected PUSH_HDR + RECIPE + CHUNK_BATCH*")
        receipt = server.handle_push(frames[0], frames[1], frames[2:])
        return [wire.encode_receipt(receipt)]
    raise wire.WireError(f"unhandled request op {op!r}")


# -------------------------------------------------------------- transport


class _Conn:
    """One pooled client connection: socket + buffered reader."""

    def __init__(self, address: Tuple[str, int], timeout: float):
        self.sock = socket.create_connection(address, timeout=timeout)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.rfile = self.sock.makefile("rb")
        self.reused = False      # came out of the pool (server may have
        self.idle_since = 0.0    # reaped it while idle) / checkin time

    def send(self, data: bytes) -> None:
        self.sock.sendall(data)

    def close(self) -> None:
        try:
            self.rfile.close()
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


class SocketTransport:
    """:class:`Transport` over real TCP to a :class:`SocketRegistryServer`.

    Byte accounting is end-to-end socket bytes: ``get_index`` /
    ``get_recipe`` / ``has_chunks`` report request + response envelopes in
    full; ``fetch_chunks`` records the WANT request envelope as
    ``want_bytes`` and the streamed response envelope as ``chunk_bytes`` on
    its source leg, matching the wire transport's split so reports stay
    comparable across transports.  Construction performs one INFO exchange
    to learn the server's response batch split, which makes
    ``quote_chunk_batches`` (and therefore ``plan_pull``) exact.
    """

    name = "socket"
    verifies_payloads = True       # decode_chunk_batch hashes every payload

    def __init__(self, address: Tuple[str, int], batch_chunks: int = 64,
                 timeout: float = DEFAULT_TIMEOUT, pool_size: int = 8,
                 pool_ttl: float = 60.0,
                 metrics: Optional[MetricsRegistry] = None):
        self.address = (address[0], int(address[1]))
        self.batch_chunks = max(1, batch_chunks)
        self.timeout = timeout
        # pool bounds: at most pool_size idle connections are kept (a burst
        # of pipelined batches cannot leak sockets — excess checkins close),
        # and one idle longer than pool_ttl is closed at checkout instead
        # of being handed out half-dead
        self.pool_size = pool_size
        self.pool_ttl = pool_ttl
        self._pool: List[_Conn] = []  # guarded-by: _pool_lock
        self._pool_lock = threading.Lock()
        self._closed = False  # guarded-by: _pool_lock
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._meter = TransportMeter(self.metrics, self.name)
        self._m_pool = self.metrics.gauge(
            "transport_pool_connections",
            "idle pooled connections", ("transport",)).labels(self.name)
        # one control exchange: the server's response split, so pull plans
        # quote the streamed CHUNK_BATCH framing (and its envelope) exactly
        # (unmetered, like scrape_metrics — neither contributes to any
        # TransferReport, so metered bytes stay report-exact)
        _, frames, _ = self._exchange(wire.Op.INFO, "", "")
        self.response_batch_chunks = wire.decode_info(frames[0])

    # ------------------------------------------------------------ lifecycle

    def close(self) -> None:
        # the closed flag and the pool swap must be one atomic step: a
        # concurrent _checkin that saw _closed=False must not be able to
        # slip its connection into the pool after we drained it
        with self._pool_lock:
            self._closed = True
            conns, self._pool = self._pool, []
        for c in conns:
            c.close()
        self._m_pool.set(0)

    def __enter__(self) -> "SocketTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------------------- pool

    def _checkout(self) -> _Conn:
        now = time.monotonic()
        while True:
            with self._pool_lock:
                if self._closed:
                    raise DeliveryError("socket transport is closed")
                conn = self._pool.pop() if self._pool else None
                n = len(self._pool)
            if conn is None:
                return self._dial()
            self._m_pool.set(n)
            if now - conn.idle_since > self.pool_ttl:
                conn.close()         # TTL-expired: almost certainly reaped
                continue
            conn.reused = True
            return conn

    def _dial(self) -> _Conn:
        try:
            return _Conn(self.address, self.timeout)
        except OSError as e:
            raise DeliveryError(
                f"socket transport: cannot connect to "
                f"{self.address[0]}:{self.address[1]} ({e})") from e

    def _checkin(self, conn: _Conn) -> None:
        conn.idle_since = time.monotonic()
        with self._pool_lock:
            if not self._closed and len(self._pool) < self.pool_size:
                self._pool.append(conn)
                n = len(self._pool)
            else:
                n = -1
        if n < 0:
            conn.close()             # pool full (or transport closed)
        else:
            self._m_pool.set(n)

    # ------------------------------------------------------------- exchange

    def _exchange(self, op: wire.Op, lineage: str, tag: str,
                  frames: Sequence[bytes] = ()
                  ) -> Tuple[int, List[bytes], int]:
        """One request/response round-trip.  Returns ``(request_bytes,
        response_frames, response_bytes)``; server-side errors re-raise as
        the matching exception, transport failures as ``DeliveryError``.
        A reused pooled connection that proves dead before the server
        answers is redialed and the exchange retried once (see
        :class:`_StaleConn`); registry pushes deduplicate, so even the
        theoretical processed-but-unanswered race is benign."""
        req = wire.encode_request(op, lineage, tag, frames)
        try:
            status, out, resp_bytes = self._exchange_on(
                self._checkout(), op, req)
        except _StaleConn:
            status, out, resp_bytes = self._exchange_on(
                self._dial(), op, req)
        if status == wire.STATUS_ERROR:
            self._raise_remote(out)
        return len(req), out, resp_bytes

    def _exchange_on(self, conn: _Conn, op: wire.Op, req: bytes
                     ) -> Tuple[int, List[bytes], int]:
        answered = False
        try:
            conn.send(req)
            status, n, resp_bytes = self._read_header(conn)
            answered = True
            out: List[bytes] = []
            for _ in range(n):
                f, nb = _read_frame(conn.rfile)
                resp_bytes += nb
                out.append(f)
        except (_ConnectionClosed, OSError) as e:
            conn.close()
            if conn.reused and not answered:
                raise _StaleConn(str(e)) from e
            raise DeliveryError(
                f"socket transport: {op.name} to {self.address[0]}:"
                f"{self.address[1]}: connection lost ({e})") from e
        except wire.WireError:
            conn.close()                     # stream state unknown: drop it
            raise
        self._checkin(conn)
        return status, out, resp_bytes

    @staticmethod
    def _read_header(conn: _Conn) -> Tuple[int, int, int]:
        status = wire.check_response_header(_read_exact(conn.rfile, 4))
        n, nb = _read_uvarint(conn.rfile)
        return status, n, 4 + nb

    @staticmethod
    def _raise_remote(frames: Sequence[bytes]) -> None:
        if not frames:
            raise DeliveryError("remote error with no ERROR frame")
        code, msg = wire.decode_error(frames[0])
        if code is wire.ErrorCode.PUSH_REJECTED:
            raise PushRejected(msg)
        if code is wire.ErrorCode.WIRE:
            raise wire.WireError(msg)
        raise DeliveryError(msg)

    # ------------------------------------------------------------ transport

    # api-boundary
    def get_index(self, lineage: str, tag: str) -> Tuple[CDMT, int]:
        t0 = time.perf_counter()
        req_b, frames, resp_b = self._exchange(wire.Op.INDEX, lineage, tag)
        self._meter.rec("index", t0, index=req_b + resp_b)
        return wire.decode_index(frames[0]), req_b + resp_b

    # api-boundary
    def get_latest_index(self, lineage: str) -> Tuple[Optional[CDMT], int]:
        t0 = time.perf_counter()
        req_b, frames, resp_b = self._exchange(wire.Op.LATEST_INDEX,
                                               lineage, "")
        self._meter.rec("index", t0, index=req_b + resp_b)
        if not frames:
            return None, req_b + resp_b
        return wire.decode_index(frames[0]), req_b + resp_b

    # api-boundary
    def get_recipe(self, lineage: str, tag: str) -> Tuple[Recipe, int]:
        t0 = time.perf_counter()
        req_b, frames, resp_b = self._exchange(wire.Op.RECIPE, lineage, tag)
        self._meter.rec("recipe", t0, recipe=req_b + resp_b)
        return wire.decode_recipe(frames[0]), req_b + resp_b

    # api-boundary
    def fetch_chunks(self, lineage: str, tag: str,
                     fps: Sequence[bytes]) -> FetchResult:
        """One WANT exchange; response frames are decoded *as they arrive*,
        so with pipelined batches (several pooled connections in flight) the
        hash-verify of one batch overlaps the socket reads of the next."""
        t0 = time.perf_counter()
        want = wire.encode_want(fps)
        req = wire.encode_request(wire.Op.WANT, lineage, tag, [want])
        try:
            chunks, resp_bytes, error_frames = self._fetch_on(
                self._checkout(), req)
        except _StaleConn:
            chunks, resp_bytes, error_frames = self._fetch_on(
                self._dial(), req)
        if error_frames is not None:
            self._raise_remote(error_frames)
        leg = SourceLeg(source=REGISTRY_SOURCE, chunks=len(chunks),
                        chunk_bytes=resp_bytes, want_bytes=len(req),
                        rounds=1)
        self._meter.rec_legs(t0, [leg])
        return FetchResult(chunks=chunks, legs=[leg])

    def _fetch_on(self, conn: _Conn, req: bytes
                  ) -> Tuple[Dict[bytes, bytes], int,
                             Optional[List[bytes]]]:
        chunks: Dict[bytes, bytes] = {}
        error_frames: Optional[List[bytes]] = None
        answered = False
        try:
            conn.send(req)
            status, n, resp_bytes = self._read_header(conn)
            answered = True
            if status == wire.STATUS_ERROR:
                error_frames = []
            for _ in range(n):
                f, nb = _read_frame(conn.rfile)
                resp_bytes += nb
                if error_frames is not None:
                    error_frames.append(f)
                else:
                    chunks.update(wire.decode_chunk_batch(f))
        except (_ConnectionClosed, OSError) as e:
            conn.close()
            if conn.reused and not answered:
                raise _StaleConn(str(e)) from e
            raise DeliveryError(
                f"socket transport: WANT to {self.address[0]}:"
                f"{self.address[1]}: connection lost mid-stream ({e})"
            ) from e
        except wire.WireError:
            conn.close()
            raise
        self._checkin(conn)
        return chunks, resp_bytes, error_frames

    # api-boundary
    def push(self, lineage: str, tag: str, recipe: Recipe,
             chunks: Dict[bytes, bytes], *,
             parent_version: Optional[int] = None,
             claimed_root: Optional[bytes] = None,
             claimed_params: Optional[CDMTParams] = None) -> PushOutcome:
        t0 = time.perf_counter()
        hdr = wire.encode_push_header(wire.PushHeader(
            lineage=lineage, tag=tag, root=claimed_root,
            parent_version=parent_version, params=claimed_params))
        recipe_frame = wire.encode_recipe(recipe)
        chunk_frames: List[bytes] = []
        fps = list(chunks)
        for start in range(0, len(fps), self.batch_chunks):
            part = {fp: chunks[fp]
                    for fp in fps[start:start + self.batch_chunks]}
            chunk_frames.append(wire.encode_chunk_batch(part))
        req_b, frames, resp_b = self._exchange(
            wire.Op.PUSH, lineage, tag, [hdr, recipe_frame] + chunk_frames)
        receipt = wire.decode_receipt(frames[0])
        # split the socket bytes by category: each body frame owns its
        # envelope length prefix; the fixed header, PUSH_HDR share, and the
        # receipt ride in header_bytes — the three sum to every socket byte
        recipe_share = wire.uvarint_len(len(recipe_frame)) + len(recipe_frame)
        chunk_share = sum(wire.uvarint_len(len(f)) + len(f)
                          for f in chunk_frames)
        outcome = PushOutcome(
            receipt=receipt,
            header_bytes=req_b - recipe_share - chunk_share + resp_b,
            recipe_bytes=recipe_share,
            chunk_bytes=chunk_share,
            rounds=1 if chunks else 0)
        self._meter.rec("push", t0, index=outcome.header_bytes,
                        recipe=outcome.recipe_bytes,
                        chunk=outcome.chunk_bytes)
        return outcome

    # api-boundary
    def has_chunks(self, fps: Sequence[bytes]) -> Tuple[List[bytes], int]:
        t0 = time.perf_counter()
        req_b, frames, resp_b = self._exchange(wire.Op.HAS, "", "",
                                               [wire.encode_has(fps)])
        self._meter.rec("has", t0, want=req_b + resp_b)
        return wire.decode_missing(frames[0]), req_b + resp_b

    # api-boundary
    def tags(self, lineage: str) -> List[str]:
        t0 = time.perf_counter()
        _, frames, _ = self._exchange(wire.Op.TAGS, lineage, "",
                                      [wire.encode_tags_request(lineage)])
        self._meter.rec("tags", t0)
        return wire.decode_tag_list(frames[0])

    # api-boundary
    def notify_pulled(self, lineage: str, tag: str) -> None:
        pass

    # ------------------------------------------------------------- scraping

    def scrape_metrics(self) -> MetricsSnapshot:
        """One ``Op.METRICS`` exchange: the live server's full metrics
        snapshot, decoded.  Scrape traffic is deliberately unmetered on the
        client side so ``transport_bytes_total`` stays report-exact."""
        _, frames, _ = self._exchange(wire.Op.METRICS, "", "")
        payload = wire.decode_metrics(frames[0])
        return MetricsSnapshot.from_json(payload.decode("utf-8"))

    # ---------------------------------------------------------- replication

    def ship_journal(self, replica: str, epoch: int, start: int,
                     limit: int = 512
                     ) -> Tuple[int, int, List[Tuple[int, bytes, bytes]]]:
        """One JOURNAL_SHIP exchange: ``(primary_epoch, primary_head,
        records)`` where ``records`` are checksum-verified ``(rtype,
        payload, raw)`` triples from offset ``start`` (at most ``limit``).
        A corrupt (torn) shipped record raises :class:`WireError` before
        anything is returned — nothing half-verified reaches replay."""
        _, frames, _ = self._exchange(
            wire.Op.JOURNAL_SHIP, "", "",
            [wire.encode_ship(replica, epoch, start, limit)])
        _, srv_epoch, head = wire.decode_repl_ack(frames[0])
        records = [wire.decode_record_frame(f) for f in frames[1:]]
        return srv_epoch, head, records

    def ack_journal(self, replica: str, epoch: int,
                    offset: int) -> Tuple[int, int]:
        """Report applied progress; returns the primary's
        ``(epoch, head)``."""
        _, frames, _ = self._exchange(
            wire.Op.REPL_ACK, "", "",
            [wire.encode_repl_ack(replica, epoch, offset)])
        _, srv_epoch, head = wire.decode_repl_ack(frames[0])
        return srv_epoch, head

    def replication_status(self) -> Tuple[int, int]:
        """The remote registry's ``(epoch, head)`` — a cheap liveness and
        freshness probe (a SHIP with a record budget of 0)."""
        epoch, head, _ = self.ship_journal("", 0, 0, 0)
        return epoch, head

    def fetch_snapshot(self, replica: str = "standby"
                       ) -> Tuple[int, int, List[Tuple[int, bytes, bytes]]]:
        """One SNAPSHOT_SHIP exchange: the primary's collapsed state as
        ``(epoch, head, (rtype, payload, raw) records)``, streamed frame
        by frame like WANT.  Every record is checksum-verified on decode
        before anything is returned — a torn snapshot stream raises
        :class:`WireError`, nothing half-verified reaches bootstrap."""
        _, frames, _ = self._exchange(
            wire.Op.SNAPSHOT_SHIP, "", "",
            [wire.encode_snapshot(replica, 0, 0)])
        if not frames:
            raise wire.WireError("SNAPSHOT_SHIP response carried no frames")
        _, epoch, head = wire.decode_snapshot(frames[0])
        return epoch, head, [wire.decode_record_frame(f)
                             for f in frames[1:]]

    # -------------------------------------------------------------- quoting

    def quote_chunk_batches(self, sizes: Sequence[int]) -> int:
        """Exact socket bytes of the streamed response to one WANT of
        payloads ``sizes`` — CHUNK_BATCH frames at the server's split, plus
        the response envelope around them.  ``plan_pull`` calls this per
        request batch, making a socket plan's quote byte-exact."""
        lens = wire.chunk_batch_frame_lens(sizes, self.response_batch_chunks)
        return wire.response_envelope_bytes(lens)


# ------------------------------------------------------------- replication


def _resync_needed(e: BaseException) -> bool:
    """True when the primary's answer means ordinary replay can never
    succeed and a snapshot bootstrap is the prescribed recovery: an epoch
    mismatch (GC sweep rolled the log) or a resume offset behind the
    trimmed log base.  Divergence — the standby *ahead* of the primary's
    head — is deliberately excluded: wiping a standby that holds records
    the primary lost is an operator decision, never automatic."""
    msg = str(e)
    if "diverged" in msg:
        return False
    return ("epoch mismatch" in msg or "full resync" in msg
            or "full-resync" in msg or "behind the log base" in msg)


class JournalFollower:
    """Keeps a standby :class:`Registry` in sync with a primary by
    following the primary's replication log.

    ``primary`` is any transport exposing ``ship_journal`` / ``ack_journal``
    / ``fetch_chunks`` (a :class:`SocketTransport` for a real standby, a
    ``WireTransport`` for in-process tests).  One sync round per record
    batch:

      1. ship records from the standby's own position — ``(epoch, head)``
         of ``registry.replication``, which counts exactly the records it
         has applied and survives a standby restart via journal replay (a
         fresh standby adopts the primary's epoch on first contact,
         durably) — so the follower itself is stateless;
      2. per record: fetch any referenced chunk payloads the standby is
         missing over the ordinary WANT path (payloads are fingerprint-
         verified on decode), store them, then
         :meth:`Registry.apply_replicated` — which skips duplicates, so a
         crash between apply and ack (or a torn ship re-sent whole) replays
         idempotently;
      3. ack the new head, so the primary can report standby lag.

    A record whose checksum fails decodes as :class:`WireError` *before*
    step 2 — a torn ship never half-applies.  :meth:`follow` runs
    :meth:`catch_up` in a daemon thread, absorbing transport and
    divergence errors (primary temporarily down, split-brain) into
    ``last_error`` and retrying.

    Role model: attaching a follower marks the standby registry
    **read-only** (``receive_push`` / ``put_metadata`` raise
    :class:`PushRejected` — writes belong on the primary); the operator
    action :meth:`promote` stops following and lifts the flag.  When
    ordinary replay is impossible — the primary's epoch rolled (GC
    sweep), or the standby's resume offset fell behind the primary's
    trimmed log base — :meth:`catch_up` performs an automated
    **wipe-and-resync**: fetch the primary's collapsed state over
    ``Op.SNAPSHOT_SHIP`` (:meth:`bootstrap_from_primary`), adopt it
    wholesale, and resume ordinary shipping from the snapshot's offset.
    ``auto_resync=False`` restores the old refuse-and-stall behavior
    (``last_error`` persists until the operator intervenes); either way
    every detected epoch mismatch increments
    ``replication_epoch_mismatch_total``.  :meth:`sync_once` itself still
    raises on mismatch — resync is a follower policy, not a transport
    behavior.
    """

    def __init__(self, registry: Registry, primary, name: str = "standby",
                 batch_records: int = 512, chunk_batch: int = 64,
                 poll_interval: float = 0.2, auto_resync: bool = True):
        self.registry = registry
        self.primary = primary
        self.name = name
        self.batch_records = max(1, batch_records)
        self.chunk_batch = max(1, chunk_batch)
        self.poll_interval = poll_interval
        self.auto_resync = auto_resync
        # attaching a follower defines the registry's role: a standby is
        # read-only until promoted (writes route to the primary and arrive
        # here as shipped records)
        registry.read_only = True
        self.records_applied = 0    # guarded-by: external(applier thread is the only writer; racy reads are progress hints)
        self.duplicates_skipped = 0  # guarded-by: external(applier thread is the only writer)
        self.chunks_fetched = 0     # guarded-by: external(applier thread is the only writer)
        self.last_error: Optional[BaseException] = None  # guarded-by: external(atomic reference swap by the applier thread)
        self._stop = threading.Event()  # guarded-by: _lifecycle_lock
        self._thread: Optional[threading.Thread] = None  # guarded-by: _lifecycle_lock
        self._lifecycle_lock = threading.Lock()
        # follower counters land in the standby registry's metrics, next to
        # its replication_apply_seconds histogram — one scrape of a standby
        # shows records applied, duplicates skipped, and chunk backfill
        m = registry.metrics
        self._m_applied = m.counter(
            "replication_records_applied_total",
            "shipped records applied by this standby").labels()
        self._m_dupes = m.counter(
            "replication_duplicates_skipped_total",
            "shipped records skipped as already applied").labels()
        self._m_chunks = m.counter(
            "replication_chunks_fetched_total",
            "chunk payloads backfilled over WANT before replay").labels()
        self._m_epoch_mismatch = m.counter(
            "replication_epoch_mismatch_total",
            "ships refused because the primary's epoch rolled").labels()
        self._m_bootstraps = m.counter(
            "replication_bootstraps_total",
            "snapshot bootstraps performed (fresh join or "
            "wipe-and-resync)").labels()

    # ----------------------------------------------------------------- sync

    def lag(self) -> int:
        """Records the primary has committed that this standby has not."""
        _, head = self.primary.replication_status()
        return max(0, head - self.registry.replication.head())

    def sync_once(self) -> int:
        """Catch up to the primary's current head; returns records applied.

        The standby's **own persisted** ``(epoch, head)`` is the resume
        position — never a freshly probed epoch, which would let a restart
        silently resume old-epoch offsets against a newer-epoch primary.  A
        truly fresh standby (nothing applied, epoch 0) adopts the primary's
        current epoch durably before its first ship."""
        log = self.registry.replication
        if log.head() == 0 and log.epoch == 0:
            p_epoch, _ = self.primary.replication_status()
            if p_epoch != 0:
                self.registry.set_replication_epoch(p_epoch)
        applied = 0
        while True:
            start = log.head()
            epoch, head, records = self.primary.ship_journal(
                self.name, log.epoch, start, self.batch_records)
            for i, (rtype, payload, raw) in enumerate(records):
                self._fetch_referenced_chunks(start + i, rtype, payload)
                if self.registry.apply_replicated(rtype, payload,
                                                  expected_seq=start + i,
                                                  raw=raw):
                    applied += 1
                    self.records_applied += 1
                    self._m_applied.inc()
                else:
                    self.duplicates_skipped += 1
                    self._m_dupes.inc()
            new_head = log.head()
            self.primary.ack_journal(self.name, epoch, new_head)
            if new_head >= head:
                return applied

    def catch_up(self) -> int:
        """:meth:`sync_once`, falling back to a snapshot bootstrap when
        ordinary replay is impossible: the primary refused the ship (its
        epoch rolled past ours) or the resume offset fell behind its
        trimmed log base.  Returns records applied (bootstrap state
        records included).  With ``auto_resync=False`` the error re-raises
        untouched — the historical refuse-and-stall behavior — but the
        epoch-mismatch counter ticks either way, so a stalled standby is
        visible on any metrics scrape."""
        try:
            return self.sync_once()
        except (DeliveryError, JournalError) as e:
            if "epoch mismatch" in str(e):
                self._m_epoch_mismatch.inc()
            if not (self.auto_resync and _resync_needed(e)):
                raise
            applied = self.bootstrap_from_primary()
            # resume ordinary shipping from the snapshot's offset — records
            # the primary committed while the snapshot streamed
            return applied + self.sync_once()

    def bootstrap_from_primary(self) -> int:
        """Wipe-and-resync from the primary's collapsed state snapshot.

        One ``SNAPSHOT_SHIP`` fetch (checksum-verified on decode), then
        referenced chunk payloads over the ordinary WANT path, then
        :meth:`Registry.bootstrap_from_snapshot` — which re-verifies every
        commit into a scratch registry and persists before installing, so
        a crash at any point either leaves the old state recoverable or
        the bootstrap restarts idempotently.  Finally the snapshot's
        ``head`` is acked so the primary tracks this replica from the
        resume offset on.  Returns the number of state records adopted.
        """
        faults.fire("follower.before_bootstrap")
        epoch, head, records = self.primary.fetch_snapshot(self.name)
        for i, (rtype, payload, _raw) in enumerate(records):
            self._fetch_referenced_chunks(i, rtype, payload)
        applied = self.registry.bootstrap_from_snapshot(epoch, head, records)
        self.records_applied += applied
        self._m_applied.inc(applied)
        self._m_bootstraps.inc()
        faults.fire("follower.before_ack")
        self.primary.ack_journal(self.name, epoch, head)
        return applied

    def promote(self) -> None:
        """Operator action: stop following and lift the standby's
        read-only flag — this registry now accepts writes directly (the
        failover counterpart of attaching the follower)."""
        self.stop()
        self.registry.read_only = False

    def _fetch_referenced_chunks(self, seq: int, rtype: int,
                                 payload: bytes) -> None:
        """Chunks must land before the record is applied — a standby must
        never index a version whose payloads it cannot serve."""
        missing = self.registry.store.missing(record_chunk_fps(rtype,
                                                               payload))
        if not missing:
            return
        got: Dict[bytes, bytes] = {}
        for s in range(0, len(missing), self.chunk_batch):
            res = self.primary.fetch_chunks("", "",
                                            missing[s:s + self.chunk_batch])
            got.update(res.chunks)
        still = [fp for fp in missing if fp not in got]
        if still:
            raise DeliveryError(
                f"replication: primary cannot serve {len(still)} chunk(s) "
                f"referenced by record {seq} "
                f"(first: {still[0].hex()[:12]})")
        for fp, data in got.items():
            self.registry.store.chunks.put(fp, data)
        self.chunks_fetched += len(got)
        self._m_chunks.inc(len(got))

    # ------------------------------------------------------------ background

    def follow(self) -> "JournalFollower":
        """Sync continuously in a daemon thread until :meth:`stop`.

        At most one applier thread ever runs: a second ``follow`` while the
        first is alive is a no-op, and if a previous :meth:`stop` timed out
        with its thread still draining a blocked exchange, ``follow``
        refuses rather than start a concurrent applier (standby registries
        are single-writer).  Each generation gets its own stop event, so a
        lingering old thread can never be revived by a new start.

        The alive-check and the thread start are one atomic step under
        ``_lifecycle_lock``: without it, two concurrent ``follow()`` calls
        could both observe no live thread and both start appliers,
        violating the single-writer contract."""
        with self._lifecycle_lock:
            if self._thread is not None and self._thread.is_alive():
                if self._stop.is_set():
                    raise DeliveryError(
                        "journal follower is still stopping (previous "
                        "thread draining a blocked exchange) — retry "
                        "after it exits")
                return self
            stop = threading.Event()
            self._stop = stop

            def loop():
                while not stop.is_set():
                    try:
                        self.catch_up()
                        self.last_error = None
                    except (DeliveryError, wire.WireError, JournalError,
                            OSError) as e:
                        # primary down / mid-restart / diverged: record and
                        # retry — the thread must never die silently
                        self.last_error = e
                    stop.wait(self.poll_interval)

            self._thread = threading.Thread(target=loop,
                                            name="journal-follower",
                                            daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        with self._lifecycle_lock:
            self._stop.set()
            thread = self._thread
        if thread is None:
            return
        thread.join(timeout=5)
        with self._lifecycle_lock:
            if not thread.is_alive() and self._thread is thread:
                self._thread = None   # else: keep it visible so follow()
                                      # refuses to double-start


def serve_registry(registry: Registry, host: str = "127.0.0.1",
                   port: int = 0, **server_kw) -> SocketRegistryServer:
    """Convenience: wrap a bare :class:`Registry` in a frame-level
    :class:`RegistryServer` and put a TCP front door on it."""
    return SocketRegistryServer(RegistryServer(registry, **server_kw),
                                host=host, port=port)
