"""repro: CDMT container/artifact delivery + multi-pod JAX LM framework."""
__version__ = "0.1.0"
