"""Deterministic synthetic data pipeline with host sharding."""
from repro.data.pipeline import (DataConfig, TokenPipeline, make_train_iterator)
