"""Deterministic synthetic token pipeline, sharded by host.

Production framing: every (host, step) pair maps to a disjoint, *stateless*
slice of a virtual token stream — ``batch(step, shard)`` is a pure function.
That statelessness is what fault tolerance and straggler mitigation rely on:

* restart: resume at step k re-generates exactly the batches the failed run
  would have seen (no data-loader state in the checkpoint beyond ``step``);
* elastic rescale: re-slicing the same virtual stream over a different host
  count keeps the *global* batch sequence identical;
* straggler reassignment: a slow host's shard indices can be handed to a
  fast host, which regenerates them locally (no data movement).

The "dataset" is a seeded Markov-ish token generator — structured enough
that the LM loss visibly decreases within a few hundred steps (examples/),
cheap enough to generate at wire speed on 1000 hosts.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    seed: int = 0
    # synthetic stream structure
    n_patterns: int = 64          # repeated motifs the LM can learn
    pattern_len: int = 16


class TokenPipeline:
    """Stateless batch generator: ``batch_for(step, host)`` is pure."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0, \
            "global batch must divide evenly over hosts"
        self.cfg = cfg
        self.per_host = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(cfg.seed)
        # motif table shared by all hosts (same seed)
        self._patterns = rng.integers(
            0, cfg.vocab, size=(cfg.n_patterns, cfg.pattern_len), dtype=np.int32)

    # -- virtual stream ------------------------------------------------------

    def _sequence(self, global_row: int, step: int) -> np.ndarray:
        """One (seq_len+1,) token row — pure function of (row, step, seed)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_521 + global_row)
        n_tok = cfg.seq_len + 1
        out = np.empty(n_tok, dtype=np.int32)
        i = 0
        while i < n_tok:
            if rng.random() < 0.8:             # motif: learnable structure
                pat = self._patterns[rng.integers(cfg.n_patterns)]
                take = min(len(pat), n_tok - i)
                out[i:i + take] = pat[:take]
                i += take
            else:                              # noise
                take = min(int(rng.integers(1, 8)), n_tok - i)
                out[i:i + take] = rng.integers(0, cfg.vocab, size=take)
                i += take
        return out

    # -- public API ----------------------------------------------------------

    def shard_rows(self, step: int, host: int,
                   reassignment: Optional[Dict[int, int]] = None) -> List[int]:
        """Global row ids host ``host`` owns at ``step``.  ``reassignment``
        maps straggler host → replacement host (runtime/straggler.py)."""
        owner = host
        if reassignment:
            # a host also covers rows of hosts reassigned TO it
            rows: List[int] = []
            for h in range(self.cfg.n_hosts):
                eff = reassignment.get(h, h)
                if eff == owner:
                    rows.extend(range(h * self.per_host, (h + 1) * self.per_host))
            return rows
        return list(range(owner * self.per_host, (owner + 1) * self.per_host))

    def batch_for(self, step: int, host: int = 0,
                  rows: Optional[List[int]] = None) -> Dict[str, np.ndarray]:
        """Materialize this host's slice of the global batch at ``step``."""
        cfg = self.cfg
        if rows is None:
            rows = self.shard_rows(step, host)
        seqs = np.stack([self._sequence(r, step) for r in rows])
        return {
            "tokens": seqs[:, :-1].astype(np.int32),
            "targets": seqs[:, 1:].astype(np.int32),
            "mask": np.ones((len(rows), cfg.seq_len), np.float32),
        }


def make_train_iterator(cfg: DataConfig, host: int = 0,
                        start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    pipe = TokenPipeline(cfg)
    step = start_step
    while True:
        yield pipe.batch_for(step, host)
        step += 1
