"""Optimizers, schedules, gradient clipping and compression."""
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               apply_updates, global_norm, clip_by_global_norm)
from repro.optim.schedule import Schedule, cosine_schedule
