"""Gradient compression with error feedback (distributed-optimization trick).

Top-k sparsification per leaf: keep the k largest-|g| entries, accumulate the
residual locally ("error feedback", Stich et al.) so the compression error is
re-injected next step and convergence is preserved.  At 1000+ nodes this cuts
cross-pod gradient all-reduce bytes by 1/density.

Two integration points:

* ``compress_tree`` / ``decompress_tree`` — functional host/jit path used by
  the trainer when ``TrainConfig.grad_compression < 1``; the all-reduce then
  runs on the dense-ified sparse tensor (XLA still moves dense bytes inside
  one jit — the byte saving is realized on the *cross-pod* axis where the
  launcher places the explicit ``shard_map`` all-reduce, see
  ``cross_pod_allreduce_compressed``).
* ``cross_pod_allreduce_compressed`` — shard_map collective that exchanges
  only (values, indices) over the named axis: the wire cost is
  2·k per leaf instead of n.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    density: float = 0.01         # fraction of entries kept (top-k)
    min_size: int = 4096          # leaves smaller than this stay dense


def _topk_mask(g: jax.Array, k: int) -> jax.Array:
    flat = jnp.abs(g.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


def compress_leaf(g: jax.Array, err: jax.Array, density: float,
                  min_size: int) -> Tuple[jax.Array, jax.Array]:
    """Returns (sparse-but-dense-layout gradient, new error residual)."""
    if g.size < min_size:
        return g, err
    acc = g.astype(jnp.float32) + err.astype(jnp.float32)
    k = max(1, int(g.size * density))
    mask = _topk_mask(acc, k)
    sent = acc * mask
    new_err = acc - sent
    return sent.astype(g.dtype), new_err.astype(err.dtype)


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tree(grads, err_state, cfg: CompressionConfig):
    """Top-k + error feedback over a whole gradient tree."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    outs = [compress_leaf(g, e, cfg.density, cfg.min_size)
            for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))


def wire_bytes_dense(grads) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(grads))


def wire_bytes_compressed(grads, cfg: CompressionConfig) -> int:
    """Bytes a (values, int32 indices) exchange would move."""
    total = 0
    for l in jax.tree.leaves(grads):
        if l.size < cfg.min_size:
            total += l.size * l.dtype.itemsize
        else:
            k = max(1, int(l.size * cfg.density))
            total += k * (l.dtype.itemsize + 4)
    return total


def cross_pod_allreduce_compressed(g: jax.Array, err: jax.Array, *,
                                   axis: str, density: float
                                   ) -> Tuple[jax.Array, jax.Array]:
    """shard_map body: top-k compress locally, all-reduce the sparse tensor
    over ``axis``, return (averaged dense gradient, new local residual).

    The wire saving is real under a fully-sharded collective implementation
    (values+indices exchange); expressed here as mask→psum so XLA lowers it
    to one all-reduce whose *operand* the compiler may densify — the
    benchmark reports both the HLO bytes and the 2k/n wire model.
    """
    acc = g.astype(jnp.float32) + err
    k = max(1, int(g.size * density))
    mask = _topk_mask(acc, k)
    sent = acc * mask
    new_err = acc - sent
    avg = jax.lax.pmean(sent, axis)
    return avg.astype(g.dtype), new_err
