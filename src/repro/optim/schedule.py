"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

Schedule = Callable


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    min_ratio: float = 0.1) -> Schedule:
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        prog = (s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        prog = jnp.clip(prog, 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(s < warmup_steps, warm, cos)
    return f


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)
