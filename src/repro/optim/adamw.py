"""AdamW with decoupled weight decay and configurable state dtype.

Functional, optax-shaped (init/update), but self-contained: the framework
controls the exact memory layout of optimizer state because m/v dominate the
per-chip HBM budget at 70B+ scale (cfg.opt_state_dtype = bf16 halves it).

State is a dict pytree mirroring the param tree — it checkpoints through the
same CDMT dedup path as params (DESIGN.md §2: optimizer state is the most
self-similar part of consecutive checkpoints).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 lr: Optional[jax.Array] = None):
    """One AdamW step.  Returns (updates, new_state); updates are negative
    deltas ready for ``apply_updates``.  All math f32; state stored at
    ``cfg.state_dtype``."""
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    b1, b2 = cfg.b1, cfg.b2
    lr = cfg.lr if lr is None else lr

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / (1 - b1 ** cf)
        vhat = vf / (1 - b2 ** cf)
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (-lr * step).astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_p = jax.tree.leaves(params)
    outs = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    updates = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_state = {
        "m": jax.tree.unflatten(tdef, [o[1] for o in outs]),
        "v": jax.tree.unflatten(tdef, [o[2] for o in outs]),
        "count": count,
    }
    return updates, new_state


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
