"""Versioned CDMT maintenance (paper Sec. V-A).

Two forms of versioning, both kept inside ONE index per artifact lineage:

* **Layering (COW)** — successive committed versions of the *same* branch
  (paper: new file versions in upper layers).  Realized as a per-node
  *modification history*: each logical node slot records (version → fp), so a
  traversal at version v resolves each slot to its hash at v.  Access slowdown
  is O(log m) in the number of modifications, as the paper analyzes — we store
  the history sorted and bisect.
* **Branching** — user-visible forks (tagged images / fine-tune forks).
  Realized by **node-copying**: because node ids are content-addressed, a new
  version's tree shares every unchanged subtree with its parent by
  construction; only the changed root-to-leaf paths materialize new nodes.
  The lineage keeps an **array of roots** (paper: "array of roots where each
  root corresponds to a 'taggable' container branch").

The shared ``node_store`` dict is the hashmap ``hm`` of Algorithm 1 — it is
what makes node-copying free.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .cdmt import CDMT, CDMTNode, CDMTParams, DEFAULT_PARAMS, compare


@dataclasses.dataclass
class VersionRecord:
    version: int
    tag: str
    root: bytes
    parent: Optional[int]          # parent version number (branch point)
    n_leaves: int
    new_nodes: int                 # nodes materialized by this version


class VersionedCDMT:
    """A lineage of CDMT versions over a shared node store."""

    def __init__(self, params: CDMTParams = DEFAULT_PARAMS):
        self.params = params
        self.node_store: Dict[bytes, CDMTNode] = {}
        self.roots: List[VersionRecord] = []           # array of roots
        self._by_tag: Dict[str, int] = {}
        # layering modification history: slot-path -> sorted [(version, fp)]
        self.mod_history: Dict[bytes, List[Tuple[int, bytes]]] = {}

    # ------------------------------------------------------------------ write

    def commit(self, leaf_fps: Sequence[bytes], tag: str,
               parent: Optional[int] = None,
               tree: Optional[CDMT] = None) -> VersionRecord:
        """Commit a new version (push of a committed image).  Node-copying:
        only nodes absent from the shared store are created.

        ``tree`` lets a caller that already built this version's CDMT with
        identical params (e.g. registry push verification) donate it instead
        of rebuilding; its nodes are merged content-addressed, preserving
        the ``new_nodes`` accounting.
        """
        if tree is None:
            before = len(self.node_store)
            tree = CDMT.build(leaf_fps, params=self.params,
                              node_store=self.node_store)
            created = len(self.node_store) - before
        else:
            created = 0
            for fp, node in tree.nodes.items():
                if fp not in self.node_store:
                    self.node_store[fp] = node
                    created += 1
        version = len(self.roots)
        if parent is None and self.roots:
            parent = self.roots[-1].version
        rec = VersionRecord(version=version, tag=tag, root=tree.root,
                            parent=parent, n_leaves=len(leaf_fps),
                            new_nodes=created)
        self.roots.append(rec)
        self._by_tag[tag] = version
        # layering history: record the root evolution per branch head
        hist = self.mod_history.setdefault(b"root:" + tag.split("@")[0].encode(), [])
        hist.append((version, tree.root))
        return rec

    # ------------------------------------------------------------------- read

    def get_version(self, version: int) -> CDMT:
        """Reconstruct the CDMT of a version in time linear in tree size
        (paper Sec. I: 'a given version ... obtained in linear time')."""
        rec = self.roots[version]
        t = CDMT(params=self.params)
        if rec.root is None:
            return t
        stack = [rec.root]
        seen: Set[bytes] = set()
        while stack:
            fp = stack.pop()
            if fp in seen:
                continue
            seen.add(fp)
            node = self.node_store[fp]
            t.nodes[fp] = node
            stack.extend(node.children)
        t.root = rec.root
        t.levels = _levels_from_root(t)
        return t

    def get_tag(self, tag: str) -> CDMT:
        return self.get_version(self._by_tag[tag])

    def resolve_at(self, slot: bytes, version: int) -> Optional[bytes]:
        """Layering lookup: the fp a slot held at ``version`` — O(log m)."""
        hist = self.mod_history.get(slot)
        if not hist:
            return None
        idx = bisect.bisect_right(hist, (version, b"\xff" * 32)) - 1
        return hist[idx][1] if idx >= 0 else None

    def diff(self, old_version: Optional[int], new_version: int) -> Set[bytes]:
        """Leaf fps in ``new`` missing from ``old`` (Algorithm 2)."""
        old = self.get_version(old_version) if old_version is not None else None
        new = self.get_version(new_version)
        return compare(old, new)[0]

    # ------------------------------------------------------------- accounting

    def total_nodes(self) -> int:
        return len(self.node_store)

    def version_records(self) -> List[VersionRecord]:
        return list(self.roots)


def _levels_from_root(t: CDMT) -> List[List[bytes]]:
    """Recover bottom-up levels for a tree reconstructed from a node store."""
    if t.root is None:
        return []
    levels_down: List[List[bytes]] = [[t.root]]
    while True:
        nxt: List[bytes] = []
        for fp in levels_down[-1]:
            nxt.extend(t.nodes[fp].children)
        if not nxt:
            break
        levels_down.append(nxt)
    return list(reversed(levels_down))
