"""Versioned CDMT maintenance (paper Sec. V-A).

Two forms of versioning, both kept inside ONE index per artifact lineage:

* **Layering (COW)** — successive committed versions of the *same* branch
  (paper: new file versions in upper layers).  Realized as a per-node
  *modification history*: each logical node slot records (version → fp), so a
  traversal at version v resolves each slot to its hash at v.  Access slowdown
  is O(log m) in the number of modifications, as the paper analyzes — we store
  the history sorted and bisect.
* **Branching** — user-visible forks (tagged images / fine-tune forks).
  Realized by **node-copying**: because node ids are content-addressed, a new
  version's tree shares every unchanged subtree with its parent by
  construction; only the changed root-to-leaf paths materialize new nodes.
  The lineage keeps an **array of roots** (paper: "array of roots where each
  root corresponds to a 'taggable' container branch").

The shared ``node_store`` dict is the hashmap ``hm`` of Algorithm 1 — it is
what makes node-copying free.

Commits are **incremental**: :meth:`VersionedCDMT.commit` builds the new
version's tree with :meth:`CDMT.build_incremental` against the parent
version's tree, re-hashing only content-defined subtrees whose leaves
changed.  :meth:`VersionedCDMT.build_next` exposes the same build *without
mutating the lineage* (new nodes land in a copy-on-write overlay) so a
registry can verify a claimed root before committing anything.

Tag semantics: a tag binds exactly one root, forever.  Re-committing a tag
with the same root is idempotent (returns the existing record — what makes
journal replay after a partial compaction safe); re-committing it with a
different root raises ``ValueError`` instead of silently rebinding the tag
and leaving a duplicate in ``tags()``.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .cdmt import (BuildStats, CDMT, CDMTNode, CDMTParams, DEFAULT_PARAMS,
                   OverlayNodeStore, compare)

_TREE_CACHE_MAX = 4     # reconstructed-version cache (head + recent parents)


@dataclasses.dataclass
class VersionRecord:
    version: int
    tag: str
    root: bytes
    parent: Optional[int]          # parent version number (branch point)
    n_leaves: int
    new_nodes: int                 # nodes materialized by this version


class VersionedCDMT:
    """A lineage of CDMT versions over a shared node store."""

    def __init__(self, params: CDMTParams = DEFAULT_PARAMS):
        self.params = params
        self.node_store: Dict[bytes, CDMTNode] = {}  # guarded-by: external(lineages live inside a Registry; RegistryServer._registry_lock serializes access)
        self.roots: List[VersionRecord] = []           # guarded-by: external(RegistryServer._registry_lock)
        self._by_tag: Dict[str, int] = {}  # guarded-by: external(RegistryServer._registry_lock)
        # layering modification history: slot-path -> sorted [(version, fp)].
        # Rebuilt deterministically from journaled commit records on
        # recovery, so branch-at-version queries survive restart (see
        # resolve_at / Registry.branch_root_at and the durability tests).
        self.mod_history: Dict[bytes, List[Tuple[int, bytes]]] = {}  # guarded-by: external(RegistryServer._registry_lock)
        # small cache of reconstructed trees; the head stays warm so the next
        # incremental commit never pays an O(n) reconstruction
        self._tree_cache: Dict[int, CDMT] = {}

    # ------------------------------------------------------------------ write

    def build_next(self, leaf_fps: Sequence[bytes],
                   parent: Optional[int] = None
                   ) -> Tuple[CDMT, Dict[bytes, CDMTNode], BuildStats]:
        """Build the tree a commit of ``leaf_fps`` would produce — WITHOUT
        mutating the lineage.  New nodes land in a copy-on-write overlay over
        ``node_store``; returns ``(tree, overlay_nodes, stats)``.  On a
        verification failure the caller simply drops the overlay and the
        lineage is untouched; on success it hands both back to
        :meth:`commit`, which merges O(new nodes) and rebuilds nothing."""
        stats = BuildStats()
        overlay = OverlayNodeStore(self.node_store)
        if parent is None:
            parent = self.head_version()
        parent_tree = None
        if parent is not None and leaf_fps:
            parent_tree = self.get_version(parent)
        if parent_tree is not None and parent_tree.root is not None:
            tree = CDMT.build_incremental(parent_tree, leaf_fps,
                                          params=self.params,
                                          node_store=overlay, stats=stats)
        else:
            tree = CDMT.build(leaf_fps, params=self.params,
                              node_store=overlay, stats=stats)
        return tree, overlay.overlay, stats

    def commit(self, leaf_fps: Sequence[bytes], tag: str,
               parent: Optional[int] = None,
               tree: Optional[CDMT] = None,
               new_nodes: Optional[Dict[bytes, CDMTNode]] = None
               ) -> VersionRecord:
        """Commit a new version (push of a committed image).  Node-copying:
        only nodes absent from the shared store are created, and the build
        is incremental against the parent version's tree.

        ``tree`` lets a caller that already built this version's CDMT with
        identical params (e.g. registry push verification via
        :meth:`build_next`) donate it instead of rebuilding; with
        ``new_nodes`` (the overlay from :meth:`build_next`) the merge is
        O(new nodes) instead of O(tree).
        """
        if parent is None and self.roots:
            parent = self.roots[-1].version
        if tree is None:
            tree, new_nodes, _ = self.build_next(leaf_fps, parent)
        existing = self._by_tag.get(tag)
        if existing is not None:
            rec = self.roots[existing]
            if rec.root == tree.root:
                return rec                 # idempotent re-commit of the tag
            raise ValueError(
                f"tag {tag!r} is already bound to version {existing} with a "
                f"different root — re-binding would orphan it; commit under "
                f"a new tag")
        created = 0
        merge = new_nodes if new_nodes is not None else tree.nodes
        for fp, node in merge.items():
            if fp not in self.node_store:
                self.node_store[fp] = node
                created += 1
        version = len(self.roots)
        rec = VersionRecord(version=version, tag=tag, root=tree.root,
                            parent=parent, n_leaves=len(leaf_fps),
                            new_nodes=created)
        self.roots.append(rec)
        self._by_tag[tag] = version
        # layering history: record the root evolution per branch head
        hist = self.mod_history.setdefault(b"root:" + tag.split("@")[0].encode(), [])
        hist.append((version, tree.root))
        self._remember(version, tree)
        return rec

    # ------------------------------------------------------------------- read

    def head_version(self) -> Optional[int]:
        return self.roots[-1].version if self.roots else None

    def version_of(self, tag: str) -> Optional[int]:
        return self._by_tag.get(tag)

    def get_version(self, version: int) -> CDMT:
        """The CDMT of a version: cached for recent versions, otherwise
        reconstructed in time linear in tree size (paper Sec. I: 'a given
        version ... obtained in linear time').  Returned trees are shared —
        treat them as immutable."""
        cached = self._tree_cache.get(version)
        if cached is not None:
            return cached
        tree = self._reconstruct(version)
        self._remember(version, tree)
        return tree

    def _reconstruct(self, version: int) -> CDMT:
        rec = self.roots[version]
        t = CDMT(params=self.params)
        if rec.root is None:
            return t
        stack = [rec.root]
        seen: Set[bytes] = set()
        while stack:
            fp = stack.pop()
            if fp in seen:
                continue
            seen.add(fp)
            node = self.node_store[fp]
            t.nodes[fp] = node
            stack.extend(node.children)
        t.root = rec.root
        t.levels = _levels_from_root(t)
        return t

    def _remember(self, version: int, tree: CDMT) -> None:
        self._tree_cache[version] = tree
        while len(self._tree_cache) > _TREE_CACHE_MAX:
            self._tree_cache.pop(next(iter(self._tree_cache)))

    def get_tag(self, tag: str) -> CDMT:
        return self.get_version(self._by_tag[tag])

    def resolve_at(self, slot: bytes, version: int) -> Optional[bytes]:
        """Layering lookup: the fp a slot held at ``version`` — O(log m)."""
        hist = self.mod_history.get(slot)
        if not hist:
            return None
        idx = bisect.bisect_right(hist, (version, b"\xff" * 32)) - 1
        return hist[idx][1] if idx >= 0 else None

    def branch_root_at(self, branch: str, version: int) -> Optional[bytes]:
        """Branch-at-version query: the root the branch head ``branch`` had
        at ``version`` (tags follow the ``branch@rev`` convention; the part
        before ``@`` names the branch).  ``None`` if the branch had no
        commit at or before ``version``.

        Durable by construction: ``mod_history`` is re-derived from the
        journaled commit records on recovery, so the answer is identical
        before and after a restart or a snapshot compaction.
        """
        return self.resolve_at(b"root:" + branch.encode("utf-8"), version)

    def branch_history(self, branch: str) -> List[Tuple[int, bytes]]:
        """Full ``[(version, root)]`` evolution of one branch head, in
        version order (a copy; safe to hold across later commits)."""
        return list(self.mod_history.get(
            b"root:" + branch.encode("utf-8"), []))

    def diff(self, old_version: Optional[int], new_version: int) -> Set[bytes]:
        """Leaf fps in ``new`` missing from ``old`` (Algorithm 2)."""
        old = self.get_version(old_version) if old_version is not None else None
        new = self.get_version(new_version)
        return compare(old, new)[0]

    # ------------------------------------------------------------- accounting

    def total_nodes(self) -> int:
        return len(self.node_store)

    def version_records(self) -> List[VersionRecord]:
        return list(self.roots)

    def tags(self) -> List[str]:
        return [r.tag for r in self.roots]


def _levels_from_root(t: CDMT) -> List[List[bytes]]:
    """Recover bottom-up levels for a tree reconstructed from a node store."""
    if t.root is None:
        return []
    levels_down: List[List[bytes]] = [[t.root]]
    while True:
        nxt: List[bytes] = []
        for fp in levels_down[-1]:
            nxt.extend(t.nodes[fp].children)
        if not nxt:
            break
        levels_down.append(nxt)
    return list(reversed(levels_down))
