"""Chunk-granular push/pull protocols (paper Sec. V, items 1–2).

The client holds a ``DedupStore`` + its own CDMT per lineage; the registry is
``repro.core.registry.Registry``.  Both operations exchange the KB-sized CDMT
index first, run Algorithm 2 locally, and move only the missing chunks.

As of the unified delivery API, :class:`Client` is a thin compatibility shim:
all compare/transfer/accounting logic lives in
:class:`repro.delivery.client.ImageClient`, which this class drives through a
:class:`repro.delivery.transport.LocalTransport` bound to the target
registry.  ``WireStats`` remains the base accounting dataclass; the values
returned by :meth:`Client.push`/:meth:`Client.pull` are
:class:`repro.delivery.plan.TransferReport` instances (a ``WireStats``
subclass adding per-source legs), so existing callers keep working.

Layering note: ``repro.delivery`` depends on this module at import time
(``plan``/``delta`` import :class:`WireStats`/:class:`Client`), so the
delivery imports here happen lazily inside methods — the one deliberate
upward reference from core to the delivery layer.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from . import cdc
from .cdmt import CDMT, CDMTParams, DEFAULT_PARAMS
from .registry import Registry
from .store import DedupStore, Recipe


@dataclasses.dataclass
class WireStats:
    op: str
    lineage: str
    tag: str
    chunk_bytes: int = 0          # payload chunks moved
    index_bytes: int = 0          # CDMT index moved
    recipe_bytes: int = 0         # recipe (fp list) moved
    chunks_moved: int = 0
    chunks_total: int = 0         # chunks in the artifact
    raw_bytes: int = 0            # full artifact size (what naive transfer costs)
    comparisons: int = 0

    @property
    def total_wire_bytes(self) -> int:
        return self.chunk_bytes + self.index_bytes + self.recipe_bytes

    @property
    def savings_vs_raw(self) -> float:
        return 1.0 - self.total_wire_bytes / self.raw_bytes if self.raw_bytes else 0.0


class Client:
    """A client node: local dedup store + local CDMT per lineage.

    Compatibility shim over :class:`repro.delivery.client.ImageClient` —
    each ``push``/``pull`` binds the shared local state to a
    ``LocalTransport`` for the given registry and delegates.
    """

    def __init__(self, cdc_params: cdc.CDCParams = cdc.DEFAULT_PARAMS,
                 cdmt_params: CDMTParams = DEFAULT_PARAMS,
                 directory: Optional[str] = None):
        from repro.delivery.client import ImageClient   # lazy: layering note
        self._ic = ImageClient(None, cdc_params=cdc_params,
                               cdmt_params=cdmt_params, directory=directory)
        self.store: DedupStore = self._ic.store
        self.cdmt_params = cdmt_params
        self.indexes: Dict[str, CDMT] = self._ic.indexes  # lineage -> CDMT
        self.tag_trees: Dict[str, CDMT] = self._ic.tag_trees
        self.log: List[WireStats] = []

    def _bound(self, registry: Registry):
        from repro.delivery.transport import LocalTransport  # lazy: layering
        return self._ic.bind(LocalTransport(registry))

    # ---------------------------------------------------------------- commit

    def commit(self, lineage: str, tag: str, data: bytes) -> Recipe:
        """Chunk + locally store a new artifact version, build local CDMT."""
        return self._ic.commit(lineage, tag, data)

    def index_for_tag(self, lineage: str, tag: str) -> CDMT:
        """The CDMT for a committed tag — served from the per-tag tree cache
        (built incrementally against the head on a cold non-head tag)."""
        return self._ic.index_for_tag(lineage, tag)

    # ------------------------------------------------------------------ push

    def push(self, registry: Registry, lineage: str, tag: str,
             parent_version: Optional[int] = None) -> WireStats:
        """Push the last committed version of ``lineage``.

        New image  → ship all chunks + index (paper push case 1).
        Committed  → fetch registry's latest CDMT, Alg. 2 diff, ship only
                     changed chunks + the new index (paper push case 2).
        """
        stats = self._bound(registry).push(lineage, tag,
                                           parent_version=parent_version)
        self.log.append(stats)
        return stats

    # ------------------------------------------------------------------ pull

    def pull(self, registry: Registry, lineage: str, tag: str) -> WireStats:
        """Pull a version: download its CDMT, Alg. 2 against local CDMT,
        fetch only missing chunks, reconstruct via the recipe."""
        stats = self._bound(registry).pull(lineage, tag)
        self.log.append(stats)
        return stats

    def materialize(self, lineage: str, tag: str) -> bytes:
        return self.store.restore(f"{lineage}:{tag}")


def naive_pull_bytes(recipe: Recipe) -> int:
    """What a no-index pull costs: every chunk moves (the >40% baseline)."""
    return recipe.total_size


def merkle_pull_chunk_bytes(client_tree, server_tree, recipe: Recipe,
                            store: DedupStore) -> Tuple[int, int]:
    """Chunk bytes a *plain Merkle* index would move: leaves not detected as
    shared (chunk-shift makes this large) — used by bench_pushpull_io."""
    from .merkle import compare_trees
    shared, comps = compare_trees(client_tree, server_tree)
    moved = 0
    for fp, size in zip(recipe.fps, recipe.sizes):
        if fp not in shared:
            moved += size
    return moved, comps
