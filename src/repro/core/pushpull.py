"""Chunk-granular push/pull protocols (paper Sec. V, items 1–2).

The client holds a ``DedupStore`` + its own CDMT per lineage; the registry is
``repro.core.registry.Registry``.  Both operations exchange the KB-sized CDMT
index first, run Algorithm 2 locally, and move only the missing chunks.

Every call returns a ``WireStats`` so benchmarks (Table II / the ≥40% network
saving claim) and the checkpoint layer can account exact bytes moved.

Byte accounting routes through :mod:`repro.delivery.wire`: ``index_bytes`` /
``recipe_bytes`` / ``chunk_bytes`` are the lengths of the *actually
serialized* frames (round-trippable), not structural estimates.

Layering note: ``repro.delivery`` depends on this module at import time
(``delta``/``swarm`` wrap :class:`Client`), so the wire-format sizing used
here is imported lazily inside ``push``/``pull`` — this is the one
deliberate upward reference from core to the delivery layer, kept to the
sizing helpers only.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from . import cdc, hashing
from .cdmt import CDMT, CDMTParams, DEFAULT_PARAMS, compare
from .registry import Registry
from .store import DedupStore, Recipe


@dataclasses.dataclass
class WireStats:
    op: str
    lineage: str
    tag: str
    chunk_bytes: int = 0          # payload chunks moved
    index_bytes: int = 0          # CDMT index moved
    recipe_bytes: int = 0         # recipe (fp list) moved
    chunks_moved: int = 0
    chunks_total: int = 0         # chunks in the artifact
    raw_bytes: int = 0            # full artifact size (what naive transfer costs)
    comparisons: int = 0

    @property
    def total_wire_bytes(self) -> int:
        return self.chunk_bytes + self.index_bytes + self.recipe_bytes

    @property
    def savings_vs_raw(self) -> float:
        return 1.0 - self.total_wire_bytes / self.raw_bytes if self.raw_bytes else 0.0


class Client:
    """A client node: local dedup store + local CDMT per lineage."""

    def __init__(self, cdc_params: cdc.CDCParams = cdc.DEFAULT_PARAMS,
                 cdmt_params: CDMTParams = DEFAULT_PARAMS,
                 directory: Optional[str] = None):
        self.store = DedupStore(directory, cdc_params)
        self.cdmt_params = cdmt_params
        self.indexes: Dict[str, CDMT] = {}        # lineage -> local CDMT
        self.log: List[WireStats] = []

    # ---------------------------------------------------------------- commit

    def commit(self, lineage: str, tag: str, data: bytes) -> Recipe:
        """Chunk + locally store a new artifact version, build local CDMT."""
        recipe = self.store.ingest(f"{lineage}:{tag}", data)
        self.indexes[lineage] = CDMT.build(recipe.fps, params=self.cdmt_params)
        return recipe

    def index_for_tag(self, lineage: str, tag: str) -> CDMT:
        """The CDMT for a committed tag.  The cached per-lineage index is the
        *head's* tree; pushing an older tag rebuilds its tree from the
        recipe (leaf sequence fully determines it)."""
        recipe = self.store.recipes[f"{lineage}:{tag}"]
        local_idx = self.indexes.get(lineage)
        if local_idx is not None and local_idx.leaf_fps() == list(recipe.fps):
            return local_idx
        return CDMT.build(recipe.fps, params=self.cdmt_params)

    # ------------------------------------------------------------------ push

    def push(self, registry: Registry, lineage: str, tag: str,
             parent_version: Optional[int] = None) -> WireStats:
        """Push the last committed version of ``lineage``.

        New image  → ship all chunks + index (paper push case 1).
        Committed  → fetch registry's latest CDMT, Alg. 2 diff, ship only
                     changed chunks + the new index (paper push case 2).
        """
        from repro.delivery import wire

        recipe = self.store.recipes[f"{lineage}:{tag}"]
        local_idx = self.index_for_tag(lineage, tag)
        stats = WireStats(op="push", lineage=lineage, tag=tag,
                          chunks_total=len(recipe.fps),
                          raw_bytes=recipe.total_size)

        remote_idx = registry.latest_index(lineage)
        if remote_idx is not None:
            stats.index_bytes += wire.index_wire_bytes(remote_idx)   # download
        missing, comps = compare(remote_idx, local_idx)
        stats.comparisons = comps

        payload = {fp: self.store.chunks.get(fp) for fp in missing}
        stats.chunks_moved = len(payload)
        # nothing to ship ⇒ no CHUNK_BATCH frame crosses the wire at all
        stats.chunk_bytes = wire.chunk_batch_wire_bytes(payload) if payload else 0
        stats.recipe_bytes = wire.recipe_wire_bytes(recipe)
        stats.index_bytes += wire.index_wire_bytes(local_idx)        # upload

        registry.receive_push(lineage, tag, recipe, payload,
                              parent_version=parent_version,
                              claimed_root=local_idx.root,
                              claimed_params=self.cdmt_params)
        self.log.append(stats)
        return stats

    # ------------------------------------------------------------------ pull

    def pull(self, registry: Registry, lineage: str, tag: str) -> WireStats:
        """Pull a version: download its CDMT, Alg. 2 against local CDMT,
        fetch only missing chunks, reconstruct via the recipe."""
        from repro.delivery import wire

        server_idx = registry.index_for_tag(lineage, tag)
        recipe = registry.recipe_for(lineage, tag)
        stats = WireStats(op="pull", lineage=lineage, tag=tag,
                          chunks_total=len(recipe.fps),
                          raw_bytes=recipe.total_size,
                          index_bytes=wire.index_wire_bytes(server_idx),
                          recipe_bytes=wire.recipe_wire_bytes(recipe))

        local_idx = self.indexes.get(lineage)
        missing, comps = compare(local_idx, server_idx)
        stats.comparisons = comps
        # Even chunks outside the lineage index may exist locally (global dedup
        # across lineages) — the store check is free and chunk-granular.
        to_fetch = [fp for fp in missing if not self.store.chunks.has(fp)]
        payload = registry.serve_chunks(to_fetch)
        stats.chunks_moved = len(payload)
        # nothing to fetch ⇒ no CHUNK_BATCH frame crosses the wire at all
        stats.chunk_bytes = wire.chunk_batch_wire_bytes(payload) if payload else 0

        self.store.ingest_chunks(f"{lineage}:{tag}", recipe.fps, payload,
                                 recipe.sizes)
        self.indexes[lineage] = server_idx
        self.log.append(stats)
        return stats

    def materialize(self, lineage: str, tag: str) -> bytes:
        return self.store.restore(f"{lineage}:{tag}")


def naive_pull_bytes(recipe: Recipe) -> int:
    """What a no-index pull costs: every chunk moves (the >40% baseline)."""
    return recipe.total_size


def merkle_pull_chunk_bytes(client_tree, server_tree, recipe: Recipe,
                            store: DedupStore) -> Tuple[int, int]:
    """Chunk bytes a *plain Merkle* index would move: leaves not detected as
    shared (chunk-shift makes this large) — used by bench_pushpull_io."""
    from .merkle import compare_trees
    shared, comps = compare_trees(client_tree, server_tree)
    moved = 0
    for fp, size in zip(recipe.fps, recipe.sizes):
        if fp not in shared:
            moved += size
    return moved, comps
