"""Core library: the paper's contribution.

Content-defined chunking (CDC), the baseline Merkle tree, the Content-Defined
Merkle Tree (CDMT) index, node-copy versioning, deduplicated storage, the
registry, and chunk-granular push/pull protocols.
"""

from . import cdc, cdmt, hashing, merkle, pushpull, registry, store, versioning
from .cdc import CDCParams, chunk_boundaries, chunk_bytes
from .cdmt import CDMT, CDMTParams, compare, diff_chunks
from .merkle import MerkleTree
from .pushpull import Client, WireStats
from .registry import Registry, SweepReport
from .store import DedupStore, Recipe
from .versioning import VersionedCDMT

__all__ = [
    "cdc", "cdmt", "hashing", "merkle", "pushpull", "registry", "store",
    "versioning", "CDCParams", "chunk_boundaries", "chunk_bytes", "CDMT",
    "CDMTParams", "compare", "diff_chunks", "MerkleTree", "Client",
    "WireStats", "Registry", "SweepReport", "DedupStore", "Recipe",
    "VersionedCDMT",
]
