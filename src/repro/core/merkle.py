"""Baseline k-ary Merkle tree (the paper's comparison point, Sec. III-B/C).

A *complete* k-ary tree over a list of leaf fingerprints: internal node id =
blake2b over the concatenation of its (up to) k children's ids.  This is the
structure the paper shows to be brittle under the **chunk-shift problem**
(Sec. III-C): when CDC splits or merges a chunk, every node to the right of
the edit changes child-positions, so nearly all internal node ids change and
tree comparison degenerates to "everything differs".

We keep it deliberately faithful (position-sensitive, fixed fan-out) so the
benchmarks reproduce Fig. 8's contrast with CDMT.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from . import hashing


@dataclasses.dataclass
class MerkleNode:
    fp: bytes                       # fingerprint (node id)
    children: Tuple[bytes, ...]     # child fingerprints ('' for leaves)
    is_leaf: bool

    @property
    def key(self) -> bytes:
        return self.fp


class MerkleTree:
    """Complete k-ary Merkle tree over leaf fingerprints."""

    def __init__(self, k: int = 4):
        self.k = k
        self.nodes: Dict[bytes, MerkleNode] = {}
        self.root: Optional[bytes] = None
        self.levels: List[List[bytes]] = []   # bottom-up, levels[0] = leaves

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, leaf_fps: Sequence[bytes], k: int = 4) -> "MerkleTree":
        t = cls(k=k)
        if not leaf_fps:
            return t
        level = []
        for fp in leaf_fps:
            node = MerkleNode(fp=fp, children=(), is_leaf=True)
            t.nodes[fp] = node
            level.append(fp)
        t.levels.append(list(level))
        while len(level) > 1:
            nxt: List[bytes] = []
            for i in range(0, len(level), k):
                kids = tuple(level[i:i + k])
                fp = hashing.node_fingerprint(kids)
                t.nodes[fp] = MerkleNode(fp=fp, children=kids, is_leaf=False)
                nxt.append(fp)
            t.levels.append(list(nxt))
            level = nxt
        t.root = level[0]
        return t

    # -- queries -------------------------------------------------------------

    def node_set(self) -> Set[bytes]:
        return set(self.nodes.keys())

    def leaf_fps(self) -> List[bytes]:
        return list(self.levels[0]) if self.levels else []

    def height(self) -> int:
        return len(self.levels)

    def authentication_path(self, leaf_index: int) -> List[bytes]:
        """Siblings of every node on the leaf→root path (Sec. III-B, Fig. 1)."""
        path: List[bytes] = []
        idx = leaf_index
        for lvl in range(len(self.levels) - 1):
            group = idx // self.k * self.k
            for j in range(group, min(group + self.k, len(self.levels[lvl]))):
                if j != idx:
                    path.append(self.levels[lvl][j])
            idx //= self.k
        return path


def compare_trees(a: MerkleTree, b: MerkleTree) -> Tuple[Set[bytes], int]:
    """Common-node detection by id intersection with top-down pruning.

    Returns (set of *leaf* fps of ``b`` detected as shared with ``a``,
    number of node comparisons performed).  A subtree of ``b`` whose root id
    appears anywhere in ``a`` is entirely shared (Merkle property) and is
    pruned without descending.
    """
    if b.root is None:
        return set(), 0
    a_ids = a.node_set()
    shared: Set[bytes] = set()
    comparisons = 0
    stack = [b.root]
    while stack:
        fp = stack.pop()
        comparisons += 1
        node = b.nodes[fp]
        if fp in a_ids:
            # whole subtree shared: collect its leaves without comparing.
            sub = [fp]
            while sub:
                sfp = sub.pop()
                snode = b.nodes[sfp]
                if snode.is_leaf:
                    shared.add(sfp)
                else:
                    sub.extend(snode.children)
            continue
        if not node.is_leaf:
            stack.extend(node.children)
    return shared, comparisons


def common_node_ratio(a: MerkleTree, b: MerkleTree) -> float:
    """|shared internal+leaf node ids| / |nodes of b| — the Fig. 8 metric."""
    if not b.nodes:
        return 1.0
    inter = a.node_set() & b.node_set()
    return len(inter) / len(b.nodes)


def positional_compare(a: MerkleTree, b: MerkleTree):
    """The paper's Merkle comparison semantics (Sec. III-B/C): nodes are
    compared via authentication paths, i.e. POSITIONALLY — node (level, i)
    of ``b`` against node (level, i) of ``a``.  A chunk shift misaligns
    every position right of the edit, so those chunks are reported changed
    even when their hashes exist elsewhere in ``a`` (the "falsely claims
    all chunk nodes as changed" failure).

    Returns (set of b's leaf fps detected shared, comparisons performed).
    Pruning: when positions match, the whole subtree is skipped.
    """
    if b.root is None:
        return set(), 0
    if a.root is None:
        return set(), 1
    shared = set()
    comparisons = 0
    # walk top-down by (level, index) pairs; levels are bottom-up lists
    la, lb = len(a.levels), len(b.levels)
    stack = [(lb - 1, 0)]                      # (level in b, index)
    while stack:
        lvl, idx = stack.pop()
        comparisons += 1
        a_lvl = lvl + (la - lb)                # align roots
        fp_b = b.levels[lvl][idx]
        fp_a = None
        if 0 <= a_lvl < la and idx < len(a.levels[a_lvl]):
            fp_a = a.levels[a_lvl][idx]
        if fp_a == fp_b:
            # identical subtree at identical position: all leaves shared
            sub = [fp_b]
            while sub:
                f = sub.pop()
                n = b.nodes[f]
                if n.is_leaf:
                    shared.add(f)
                else:
                    sub.extend(n.children)
            continue
        node = b.nodes[fp_b]
        if not node.is_leaf:
            base = idx * b.k
            for j, _ in enumerate(node.children):
                stack.append((lvl - 1, base + j))
    return shared, comparisons
