"""Content-defined chunking (CDC).

Implements the paper's block-level deduplication substrate (Sec. III-A):
variable-length chunks whose boundaries are defined by the *content* (a
rolling hash over a small window matching a bit pattern), so that byte
insertions/deletions only perturb the chunks local to the edit ("byte-shift"
resistance).

Two rolling hashes are provided:

* ``gear`` (default) — FastCDC-style gear hash: ``h = (h << 1) + G[byte]``
  with a fixed random 256-entry table ``G``.  The gear hash has *bounded
  memory*: after 32 shifts a byte's contribution leaves the 32-bit register,
  which is exactly what makes it blocked-parallelizable on TPU
  (see ``repro.kernels.gear_cdc``).
* ``rabin`` — Rabin polynomial fingerprint over a sliding window (the paper's
  choice, Sec. VI-D), kept as the paper-faithful reference.

Both are deterministic across processes (fixed seed) — a hard requirement:
client and registry must agree on chunk boundaries byte-for-byte.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Gear table: fixed pseudo-random 256 x uint32, shared by host + TPU kernels.
# ---------------------------------------------------------------------------

_GEAR_SEED = 0x9E3779B9


def gear_table() -> np.ndarray:
    """The 256-entry gear table (uint32), deterministic across processes."""
    rng = np.random.default_rng(_GEAR_SEED)
    return rng.integers(0, 2**32, size=256, dtype=np.uint32)


_GEAR = gear_table()

# Bits of gear-hash memory: h_i depends on at most the last 32 bytes.
GEAR_WINDOW = 32


@dataclasses.dataclass(frozen=True)
class CDCParams:
    """Chunking parameters.

    ``mask_bits`` sets the boundary rule: a boundary is declared at byte i
    when ``hash_i & ((1 << mask_bits) - 1) == 0`` — expected chunk size
    ``2**mask_bits`` bytes (the paper's "last k bits of the hash are 0").
    ``min_size``/``max_size`` bound pathological content (paper Sec. III-A
    implies bounds via the pattern; FastCDC makes them explicit).
    """

    mask_bits: int = 12               # expected chunk size 4 KiB
    min_size: int = 512
    max_size: int = 65536
    algorithm: str = "gear"           # "gear" | "rabin"

    @property
    def mask(self) -> int:
        return (1 << self.mask_bits) - 1

    @property
    def avg_size(self) -> int:
        return 1 << self.mask_bits


DEFAULT_PARAMS = CDCParams()


# ---------------------------------------------------------------------------
# Gear rolling hash — vectorised boundary scan (numpy host path).
#
# The recurrence h_i = (2*h_{i-1} + g_i) mod 2^32 unrolls to
#     h_i = sum_{j=0}^{31} 2^j * g_{i-j}          (mod 2^32)
# i.e. a convolution of the gear-mapped byte stream with [1, 2, 4, ... 2^31].
# That identity is what both this host path and the Pallas kernel exploit.
# ---------------------------------------------------------------------------


def gear_hash_stream(data: bytes | np.ndarray) -> np.ndarray:
    """Rolling gear hash h_i for every byte position (uint32 array)."""
    buf = np.frombuffer(data, dtype=np.uint8) if isinstance(data, (bytes, bytearray, memoryview)) else np.asarray(data, dtype=np.uint8)
    if buf.size == 0:
        return np.zeros(0, dtype=np.uint32)
    g = _GEAR[buf].astype(np.uint64)
    n = buf.size
    # Convolution with powers of two over a window of 32: do it as 32 shifted
    # adds (vectorised; 32 passes over the array, still ~GB/s on host).
    h = np.zeros(n, dtype=np.uint64)
    for j in range(min(GEAR_WINDOW, 64)):
        # contribution of byte i-j with weight 2^j
        if j == 0:
            h += g
        else:
            h[j:] += g[:-j] << np.uint64(j)
    return (h & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def _gear_boundaries(buf: np.ndarray, params: CDCParams) -> List[int]:
    """Boundary *end offsets* (exclusive) honoring min/max size."""
    n = buf.size
    if n == 0:
        return []
    h = gear_hash_stream(buf)
    candidate = np.flatnonzero((h & np.uint32(params.mask)) == 0) + 1  # cut AFTER byte i
    ends: List[int] = []
    start = 0
    ci = 0
    m = candidate.size
    while start < n:
        lo = start + params.min_size
        hi = start + params.max_size
        # first candidate cut >= lo
        ci = int(np.searchsorted(candidate, lo, side="left"))
        if ci < m and candidate[ci] <= hi and candidate[ci] < n:
            cut = int(candidate[ci])
        else:
            cut = min(hi, n)
        ends.append(cut)
        start = cut
    return ends


# ---------------------------------------------------------------------------
# Rabin fingerprint (paper-faithful reference; slow scalar loop, numpy-rolled)
# ---------------------------------------------------------------------------

_RABIN_PRIME = np.uint64(1099511628211)     # FNV-ish multiplier
_RABIN_WINDOW = 48


def _rabin_boundaries(buf: np.ndarray, params: CDCParams) -> List[int]:
    """Rabin-style polynomial rolling hash boundaries (reference path)."""
    n = buf.size
    if n == 0:
        return []
    w = _RABIN_WINDOW
    # h_i = sum_{j<w} p^j * b_{i-j}  (mod 2^64): compute with w shifted adds.
    b = buf.astype(np.uint64)
    h = np.zeros(n, dtype=np.uint64)
    pj = np.uint64(1)
    with np.errstate(over="ignore"):
        for j in range(w):
            if j == 0:
                h += b
            else:
                h[j:] += b[:-j] * pj
            pj = pj * _RABIN_PRIME
    mask = np.uint64(params.mask)
    candidate = np.flatnonzero((h & mask) == 0) + 1
    ends: List[int] = []
    start = 0
    m = candidate.size
    while start < n:
        lo = start + params.min_size
        hi = start + params.max_size
        ci = int(np.searchsorted(candidate, lo, side="left"))
        if ci < m and candidate[ci] <= hi and candidate[ci] < n:
            cut = int(candidate[ci])
        else:
            cut = min(hi, n)
        ends.append(cut)
        start = cut
    return ends


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def chunk_boundaries(data: bytes | np.ndarray, params: CDCParams = DEFAULT_PARAMS) -> List[int]:
    """End offsets (exclusive) of every chunk in ``data``."""
    buf = np.frombuffer(data, dtype=np.uint8) if isinstance(data, (bytes, bytearray, memoryview)) else np.asarray(data, dtype=np.uint8)
    if params.algorithm == "gear":
        return _gear_boundaries(buf, params)
    if params.algorithm == "rabin":
        return _rabin_boundaries(buf, params)
    raise ValueError(f"unknown CDC algorithm {params.algorithm!r}")


def chunk_bytes(data: bytes, params: CDCParams = DEFAULT_PARAMS) -> Iterator[bytes]:
    """Yield the chunks of ``data`` (concatenation reproduces ``data``)."""
    start = 0
    for end in chunk_boundaries(data, params):
        yield data[start:end]
        start = end


def chunk_spans(data: bytes | np.ndarray, params: CDCParams = DEFAULT_PARAMS) -> List[tuple]:
    """(start, end) spans of every chunk."""
    ends = chunk_boundaries(data, params)
    starts = [0] + ends[:-1]
    return list(zip(starts, ends))


def boundaries_from_mask(mask: np.ndarray, params: CDCParams) -> List[int]:
    """Turn a per-byte candidate-boundary mask (from the Pallas kernel) into
    min/max-size-honoring chunk end offsets.  Host-side serial pass — this is
    the only part of CDC that is inherently sequential, and it operates on a
    sparse candidate list, not the byte stream."""
    n = mask.size
    candidate = np.flatnonzero(mask) + 1
    ends: List[int] = []
    start = 0
    m = candidate.size
    while start < n:
        lo = start + params.min_size
        hi = start + params.max_size
        ci = int(np.searchsorted(candidate, lo, side="left"))
        if ci < m and candidate[ci] <= hi and candidate[ci] < n:
            cut = int(candidate[ci])
        else:
            cut = min(hi, n)
        ends.append(cut)
        start = cut
    return ends
