"""Container/artifact registry (paper Sec. V).

Hosts all versions of each artifact lineage plus **one CDMT index per
lineage** (maintained with node-copying as new versions are pushed).  The
registry never re-chunks on push — the client ships chunk fps + new chunks +
the new CDMT leaf sequence; the registry rebuilds/extends the versioned index
(cheap: Fig. 10 shows indexing ≪ hashing) and verifies the root matches the
client's claim, which doubles as the authentication mechanism.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .cdmt import CDMT, CDMTParams, DEFAULT_PARAMS
from .store import DedupStore, Recipe
from .versioning import VersionedCDMT, VersionRecord


@dataclasses.dataclass
class PushReceipt:
    lineage: str
    tag: str
    version: int
    chunks_received: int
    bytes_received: int
    index_bytes: int
    root: bytes


class Registry:
    """A registry: global chunk store + per-lineage versioned CDMT."""

    def __init__(self, directory: Optional[str] = None,
                 cdmt_params: CDMTParams = DEFAULT_PARAMS):
        self.store = DedupStore(directory)
        self.cdmt_params = cdmt_params
        self.lineages: Dict[str, VersionedCDMT] = {}
        self.recipes: Dict[Tuple[str, str], Recipe] = {}   # (lineage, tag)
        self.metadata: Dict[Tuple[str, str], bytes] = {}   # small blobs (manifests)

    # -- server-side API (what the wire protocol calls) -----------------------

    def lineage(self, name: str) -> VersionedCDMT:
        if name not in self.lineages:
            self.lineages[name] = VersionedCDMT(params=self.cdmt_params)
        return self.lineages[name]

    def latest_index(self, lineage: str) -> Optional[CDMT]:
        lin = self.lineages.get(lineage)
        if lin is None or not lin.roots:
            return None
        return lin.get_version(lin.roots[-1].version)

    def index_for_tag(self, lineage: str, tag: str) -> CDMT:
        return self.lineage(lineage).get_tag(tag)

    def has_chunks(self, fps: Iterable[bytes]) -> List[bytes]:
        """Which of ``fps`` the registry is missing."""
        return self.store.missing(fps)

    def receive_push(self, lineage: str, tag: str, recipe: Recipe,
                     chunks: Dict[bytes, bytes],
                     parent_version: Optional[int] = None) -> PushReceipt:
        """Accept a push: store new chunks, extend the versioned CDMT."""
        nbytes = 0
        nchunks = 0
        for fp, data in chunks.items():
            if self.store.chunks.put(fp, data):
                nchunks += 1
                nbytes += len(data)
        self.recipes[(lineage, tag)] = recipe
        self.store.recipes[f"{lineage}:{tag}"] = recipe
        rec = self.lineage(lineage).commit(recipe.fps, tag=tag, parent=parent_version)
        idx = self.lineage(lineage).get_version(rec.version)
        return PushReceipt(lineage=lineage, tag=tag, version=rec.version,
                           chunks_received=nchunks, bytes_received=nbytes,
                           index_bytes=idx.index_size_bytes(), root=rec.root)

    def serve_chunks(self, fps: Sequence[bytes]) -> Dict[bytes, bytes]:
        return {fp: self.store.chunks.get(fp) for fp in fps}

    def recipe_for(self, lineage: str, tag: str) -> Recipe:
        return self.recipes[(lineage, tag)]

    def tags(self, lineage: str) -> List[str]:
        lin = self.lineages.get(lineage)
        return [r.tag for r in lin.roots] if lin else []

    # -- small metadata blobs (checkpoint manifests etc.) ---------------------

    def put_metadata(self, lineage: str, tag: str, blob: bytes) -> None:
        self.metadata[(lineage, tag)] = blob

    def get_metadata(self, lineage: str, tag: str) -> bytes:
        return self.metadata[(lineage, tag)]
